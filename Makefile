# Developer entry points. `hypothesis` is an OPTIONAL dev dependency: the
# property tests use it when installed and fall back to deterministic fixed
# examples (tests/_hypothesis_compat.py) when not.

PY ?= python

.PHONY: test test-fast test-slow bench ci lint plan-demo calibrate-smoke trace-demo

test:            ## tier-1 gate: full suite, stop on first failure
	$(PY) -m pytest -x -q

test-fast:       ## quick signal (<60s): skip the slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

test-slow:       ## the slow tier only (marked end-to-end tests)
	$(PY) -m pytest -x -q -m "slow"

bench:           ## paper-claim checks; nonzero exit on mismatch
	PYTHONPATH=src $(PY) -m benchmarks.run

lint:            ## ruff (when installed) + the repro.analysis static gate
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src benchmarks examples; \
	else \
		echo "lint: ruff not installed, skipping (config pinned in pyproject.toml)"; \
	fi
	PYTHONPATH=src $(PY) -m repro.analysis src/repro

calibrate-smoke: ## measure this box + fit achievable ceilings (<60s, CPU)
	PYTHONPATH=src $(PY) -m repro.measure.calibrate --backend cpu --smoke --devices 4

# The fast tier is wall-clock budgeted inside ci.sh (FAST_BUDGET_S, default
# 75s) and reports its slowest tests via --durations=10: a test that belongs
# in the slow tier fails CI instead of silently bloating tier-1.
ci: 	         ## what CI runs: tests, calibration smoke, benchmarks
	bash scripts/ci.sh

plan-demo:
	PYTHONPATH=src $(PY) examples/plan_demo.py

trace-demo:      ## traced+explained planner run -> artifacts/traces/ (perfetto-loadable)
	mkdir -p artifacts/traces
	PYTHONPATH=src $(PY) -m repro.launch.plan --arch qwen2-7b \
		--hardware tpu_v5e --chips 16 --batch 8 --seq 128 --zero auto \
		--explain --trace artifacts/traces/plan_demo.trace.json
	PYTHONPATH=src $(PY) -m repro.obs --validate artifacts/traces/plan_demo.trace.json
