"""Generate EXPERIMENTS.md tables from dry-run artifacts (single source of
truth: the CellReport JSONs under artifacts/dryrun)."""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.core import TPU_V5E, analyze, ascii_plot
from repro.core.report import (CellReport, dryrun_table, load_reports,
                               roofline_table)
from repro.core.ridgeline import WorkUnit

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")


def reports(mesh: Optional[str] = None, variant: str = "baseline"
            ) -> List[CellReport]:
    reps = [r for r in load_reports(ARTIFACTS) if r.variant == variant]
    if mesh:
        reps = [r for r in reps if r.mesh == mesh]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    reps.sort(key=lambda r: (r.arch, order.get(r.shape, 9), r.mesh))
    return reps


def variants_of(arch: str, shape: str, mesh: str) -> List[CellReport]:
    return [r for r in load_reports(ARTIFACTS)
            if (r.arch, r.shape, r.mesh) == (arch, shape, mesh)]


def reports_all() -> List[CellReport]:
    return load_reports(ARTIFACTS)


def emit_roofline_md(mesh: str = "16x16") -> str:
    return roofline_table(reports(mesh))


def emit_dryrun_md(mesh: str) -> str:
    return dryrun_table(reports(mesh))


def emit_ridgeline_plot(mesh: str = "16x16", shape: str = "train_4k") -> str:
    reps = [r for r in reports(mesh) if r.shape == shape]
    analyses = [analyze(WorkUnit(r.arch, r.flops, r.mem_bytes, r.wire_bytes),
                        TPU_V5E) for r in reps]
    return ascii_plot(analyses, TPU_V5E)


def summary_stats(mesh: str = "16x16") -> Dict[str, float]:
    reps = reports(mesh)
    bottl: Dict[str, int] = {}
    for r in reps:
        bottl[r.bottleneck] = bottl.get(r.bottleneck, 0) + 1
    return {
        "cells": len(reps),
        "bottleneck_counts": bottl,
        "median_peak_fraction": sorted(
            r.peak_fraction for r in reps)[len(reps) // 2] if reps else 0.0,
        "max_mem_gib": max((r.peak_memory_per_device for r in reps),
                           default=0) / 2**30,
    }
