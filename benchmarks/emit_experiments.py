"""Regenerate the data-driven sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python -m benchmarks.emit_experiments > EXPERIMENTS.generated.md

The hand-written analysis sections live in EXPERIMENTS.md and embed these
tables; this script is the single source of truth for every number.
"""
from __future__ import annotations

import sys

from benchmarks import arch_table, paper_case_study as cs


def emit(out=sys.stdout):
    w = out.write

    w("## §Paper-validation (generated)\n\n")
    for name, fn in [("Fig 4a", cs.fig4a_intensity),
                     ("Fig 4b", cs.fig4b_roofline),
                     ("Fig 4c", cs.fig4c_allreduce_vs_compute),
                     ("Fig 6", cs.fig6_ridgeline)]:
        rows, derived = fn()
        w(f"**{name}** — derived: `{derived}`\n\n")

    w("\n## §Dry-run (generated)\n\n### Single pod 16x16 (256 chips)\n\n")
    w(arch_table.emit_dryrun_md("16x16"))
    w("\n\n### Multi-pod 2x16x16 (512 chips)\n\n")
    w(arch_table.emit_dryrun_md("2x16x16"))

    w("\n\n## §Perf variants (generated)\n\n")
    rows = [r for r in arch_table.reports_all()
            if r.variant != "baseline" or
            (r.arch, r.shape) in {("qwen2-7b", "train_4k"),
                                  ("qwen2-moe-a2.7b", "train_4k"),
                                  ("internvl2-26b", "prefill_32k")}]
    rows = [r for r in rows if r.mesh != "2x16x16"]
    rows.sort(key=lambda r: (r.arch, r.shape, r.variant != "baseline",
                             r.variant))
    w("| arch | shape | mesh | variant | t_C | t_M | t_N | bottleneck | "
      "runtime | peak | mem/dev (corr) |\n|---|---|---|---|---|---|---|---|---|---|---|\n")
    for r in rows:
        mem = (r.peak_memory_corrected or r.peak_memory_per_device) / 2**30
        w(f"| {r.arch} | {r.shape} | {r.mesh} | {r.variant} | "
          f"{r.t_compute:.2f}s | {r.t_memory:.2f}s | {r.t_network:.2f}s | "
          f"{r.bottleneck} | **{r.runtime:.2f}s** | "
          f"{100*r.peak_fraction:.1f}% | {mem:.1f} GiB |\n")

    w("\n\n## §Roofline (generated, single-pod)\n\n")
    w(arch_table.emit_roofline_md("16x16"))
    w("\n\n### Ridgeline plane, train_4k cells\n\n```\n")
    w(arch_table.emit_ridgeline_plot("16x16", "train_4k"))
    w("\n```\n")
    stats = arch_table.summary_stats("16x16")
    w(f"\nSummary: {stats}\n")


if __name__ == "__main__":
    emit()
