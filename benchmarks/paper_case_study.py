"""Paper §III case study: DLRM MLP on CLX — Figs 4a, 4b, 4c, 6a, 6b.

Each ``fig*`` function returns (rows, derived-claims) where rows are the
figure's data points.  Claims are checked against the paper's stated
numbers; ``benchmarks.run`` prints them as CSV and asserts them, and
EXPERIMENTS.md §Paper-validation is generated from here.

Since the sweep-engine PR, every figure is a thin call into
``repro.core.sweep`` over a vectorized batch grid, with the all-reduce wire
bytes priced by ``repro.distributed.collectives`` (ring algorithm at the
paper's large-n asymptote: exactly 2·payload per chip) instead of a
hardcoded factor.

Two term sources:
  * analytic — the paper's own accounting (models/mlp_dlrm.analytic_work_unit)
  * compiled — FLOPs/bytes of the real jitted train step via cost_analysis
    (single CPU device; network volume stays analytic)
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

import numpy as np

from repro.core import CLX, WorkUnit, analyze, ascii_plot, svg_plot
from repro.core import sweep as sweep_mod
from repro.distributed import collectives
from repro.models.mlp_dlrm import analytic_work_unit

WIDTH, LAYERS = 4096, 8
BATCHES = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)

#: the paper counts the ring all-reduce at its large-n asymptote 2·payload
PAPER_DP_GROUP = math.inf


def mlp_unit(batch: int, per_layer: bool = True) -> WorkUnit:
    layers = 1 if per_layer else LAYERS
    f, bm, bn = analytic_work_unit(batch, WIDTH, layers)
    return WorkUnit(f"mlp_b{batch}", f, bm, bn)


def batch_sweep(batches=BATCHES, per_layer: bool = True,
                net: bool = True) -> sweep_mod.SweepResult:
    """The whole batch grid in one vectorized Ridgeline pass."""
    b = np.asarray(batches, dtype=np.float64)
    layers = 1 if per_layer else LAYERS
    # single source of the paper's accounting: F is batch-linear, B_M is
    # batch-constant, and the gradient payload equals the weight bytes B_M
    flops_b1, mem_bytes, _ = analytic_work_unit(1, WIDTH, layers)
    flops = flops_b1 * b
    net_bytes = collectives.all_reduce_bytes(
        mem_bytes, PAPER_DP_GROUP, "ring") if net else 0.0
    return sweep_mod.sweep(flops, mem_bytes, net_bytes, CLX)


def fig4a_intensity() -> Tuple[List[Dict], Dict]:
    res = batch_sweep()
    rows = [{"batch": b, "arithmetic_intensity": float(res.y[i]),
             "clx_ridge": CLX.ridge_arithmetic}
            for i, b in enumerate(BATCHES)]
    crossing = min(b for i, b in enumerate(BATCHES)
                   if res.y[i] >= CLX.ridge_arithmetic)
    return rows, {"ridge_crossing_batch": crossing, "paper_claim": 32}


def fig4b_roofline() -> Tuple[List[Dict], Dict]:
    # the classic roofline is the Ridgeline's B_N -> 0 limit
    res = batch_sweep(net=False)
    labels = res.labels()
    rows = [{"batch": b, "intensity": float(res.y[i]),
             "attainable_gflops": float(res.attained_flops[i]) / 1e9,
             "bound": str(labels[i])}
            for i, b in enumerate(BATCHES)]
    first_compute = min(r["batch"] for r in rows if r["bound"] == "compute")
    return rows, {"first_compute_bound_batch": first_compute,
                  "paper_claim": 32}


def fig4c_allreduce_vs_compute() -> Tuple[List[Dict], Dict]:
    res = batch_sweep(per_layer=False)
    rows = [{"batch": b, "t_compute_ms": float(res.t_compute[i]) * 1e3,
             "t_allreduce_ms": float(res.t_network[i]) * 1e3}
            for i, b in enumerate(BATCHES)]
    # t_network is batch-constant and t_compute batch-linear, so the linear
    # interpolation in ridge_crossing is the *exact* analytic crossover:
    #   6 B* W^2 L / C = 8 W^2 L / N  ->  B* = (8/6)·C/N = 4/3·k* (= 466.7)
    b_star = sweep_mod.ridge_crossing(res, BATCHES, log_x=False)
    # paper (Fig 4c): "up to batch size 512 ... more time to do the
    # all-reduce"; it also places 512 "on the ridgeline" (xy=384 vs
    # k*=350, ~10% above) — so the claim is approximate by construction.
    # We accept the exact crossover within 10% of 512.
    return rows, {"crossover_batch": b_star,
                  "within_10pct_of_512": abs(b_star / 512 - 1) < 0.12,
                  "paper_claim": 512}


def fig6_ridgeline() -> Tuple[List[Dict], Dict]:
    batches = [b for b in BATCHES if b >= 256]
    res = batch_sweep(batches)                       # per-layer points (plane)
    res_full = batch_sweep(batches, per_layer=False)  # full-step runtimes
    labels = res.labels()
    rows = [{"batch": b, "x_mem_intensity": float(res.x[i]),
             "y_arith_intensity": float(res.y[i]),
             "region": str(labels[i]),
             "projected_runtime_ms": float(res_full.runtime[i]) * 1e3}
            for i, b in enumerate(batches)]
    trans = sweep_mod.transitions(res, batches)
    net_to_compute = [(batches[i - 1], batches[i]) for i, frm, to in trans
                      if frm == "network" and to == "compute"]
    derived = {
        "b256": rows[0]["region"], "b512": rows[1]["region"],
        "b1024": rows[2]["region"],
        "paper_claim": "256:network 512:~ridge 1024:compute",
        "xy_at_512": float(res.flops[1] / res.net_bytes[1]),
        "k_star": CLX.ridge_network,
        "network_to_compute_between": net_to_compute[0]
        if net_to_compute else None,
    }
    return rows, derived


def compiled_terms(batch: int) -> Dict[str, float]:
    """F/B_M from the real compiled train step (1 CPU device)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.hlo_analysis import cost_analysis_dict
    from repro.optim.optimizer import SGD
    from repro.train.loop import (TrainStepConfig, build_train_step,
                                  init_train_state)
    cfg = get_config("dlrm-mlp").replace(compute_dtype=jnp.float32)
    opt = SGD(learning_rate=1e-2)
    step = build_train_step(cfg, opt, TrainStepConfig())
    state_abs = jax.eval_shape(
        lambda k: init_train_state(k, cfg, opt),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch_abs = {"features": jax.ShapeDtypeStruct((batch, WIDTH), jnp.float32),
                 "click": jax.ShapeDtypeStruct((batch,), jnp.float32)}
    compiled = jax.jit(step).lower(state_abs, batch_abs).compile()
    cost = cost_analysis_dict(compiled)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state_abs.params))
    return {"flops": float(cost["flops"]),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "analytic_flops": 6.0 * batch * WIDTH * WIDTH * LAYERS,
            "net_bytes": float(collectives.all_reduce_bytes(
                4.0 * n_params, PAPER_DP_GROUP, "ring"))}


def write_plots(outdir: str) -> List[str]:
    import os
    os.makedirs(outdir, exist_ok=True)
    analyses = [analyze(mlp_unit(b), CLX) for b in BATCHES if b >= 64]
    paths = []
    p = os.path.join(outdir, "fig6_ridgeline.svg")
    with open(p, "w") as f:
        f.write(svg_plot(analyses, CLX))
    paths.append(p)
    p = os.path.join(outdir, "fig6_ridgeline.txt")
    with open(p, "w") as f:
        f.write(ascii_plot(analyses, CLX))
    paths.append(p)
    return paths
