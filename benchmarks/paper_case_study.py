"""Paper §III case study: DLRM MLP on CLX — Figs 4a, 4b, 4c, 6a, 6b.

Each ``fig*`` function returns (rows, derived-claims) where rows are the
figure's data points.  Claims are checked against the paper's stated
numbers; ``benchmarks.run`` prints them as CSV and asserts them, and
EXPERIMENTS.md §Paper-validation is generated from here.

Two term sources:
  * analytic — the paper's own accounting (models/mlp_dlrm.analytic_work_unit)
  * compiled — FLOPs/bytes of the real jitted train step via cost_analysis
    (single CPU device; network volume stays analytic = 2·params·4B, the
    ring all-reduce wire bytes the paper assumes)
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import CLX, Resource, WorkUnit, analyze, ascii_plot, svg_plot
from repro.models.mlp_dlrm import analytic_work_unit

WIDTH, LAYERS = 4096, 8
BATCHES = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def mlp_unit(batch: int, per_layer: bool = True) -> WorkUnit:
    layers = 1 if per_layer else LAYERS
    f, bm, bn = analytic_work_unit(batch, WIDTH, layers)
    return WorkUnit(f"mlp_b{batch}", f, bm, bn)


def fig4a_intensity() -> Tuple[List[Dict], Dict]:
    rows = [{"batch": b,
             "arithmetic_intensity": mlp_unit(b).arithmetic_intensity,
             "clx_ridge": CLX.ridge_arithmetic}
            for b in BATCHES]
    crossing = min(b for b in BATCHES
                   if mlp_unit(b).arithmetic_intensity >= CLX.ridge_arithmetic)
    return rows, {"ridge_crossing_batch": crossing, "paper_claim": 32}


def fig4b_roofline() -> Tuple[List[Dict], Dict]:
    from repro.core import roofline
    rows = []
    for b in BATCHES:
        w = mlp_unit(b)
        pt = roofline.point(w.name, w.flops, w.mem_bytes, CLX)
        rows.append({"batch": b, "intensity": pt.intensity,
                     "attainable_gflops": pt.attainable_flops / 1e9,
                     "bound": pt.bound})
    first_compute = min(r["batch"] for r in rows if r["bound"] == "compute")
    return rows, {"first_compute_bound_batch": first_compute,
                  "paper_claim": 32}


def fig4c_allreduce_vs_compute() -> Tuple[List[Dict], Dict]:
    rows = []
    for b in BATCHES:
        a = analyze(mlp_unit(b, per_layer=False), CLX)
        rows.append({"batch": b, "t_compute_ms": a.t_compute * 1e3,
                     "t_allreduce_ms": a.t_network * 1e3})
    # exact analytic crossover: 6 B* W^2 L / C = 8 W^2 L / N
    #   -> B* = (8/6) * C/N = 4/3 * k*  (= 466.7 on CLX)
    b_star = (8.0 / 6.0) * CLX.ridge_network
    # paper (Fig 4c): "up to batch size 512 ... more time to do the
    # all-reduce"; it also places 512 "on the ridgeline" (xy=384 vs
    # k*=350, ~10% above) — so the claim is approximate by construction.
    # We accept the exact crossover within 10% of 512.
    return rows, {"crossover_batch": b_star,
                  "within_10pct_of_512": abs(b_star / 512 - 1) < 0.12,
                  "paper_claim": 512}


def fig6_ridgeline() -> Tuple[List[Dict], Dict]:
    analyses = [analyze(mlp_unit(b), CLX) for b in BATCHES if b >= 256]
    rows = [{"batch": int(a.work.name.split("_b")[1]),
             "x_mem_intensity": a.x, "y_arith_intensity": a.y,
             "region": a.bottleneck.value,
             "projected_runtime_ms": analyze(
                 mlp_unit(int(a.work.name.split('_b')[1]), per_layer=False),
                 CLX).runtime * 1e3}
            for a in analyses]
    derived = {
        "b256": rows[0]["region"], "b512": rows[1]["region"],
        "b1024": rows[2]["region"],
        "paper_claim": "256:network 512:~ridge 1024:compute",
        "xy_at_512": analyses[1].work.network_intensity,
        "k_star": CLX.ridge_network,
    }
    return rows, derived


def compiled_terms(batch: int) -> Dict[str, float]:
    """F/B_M from the real compiled train step (1 CPU device)."""
    from repro.configs import get_config
    from repro.optim.optimizer import SGD
    from repro.train.loop import (TrainStepConfig, build_train_step,
                                  init_train_state)
    cfg = get_config("dlrm-mlp").replace(compute_dtype=jnp.float32)
    opt = SGD(learning_rate=1e-2)
    step = build_train_step(cfg, opt, TrainStepConfig())
    state_abs = jax.eval_shape(
        lambda k: init_train_state(k, cfg, opt),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch_abs = {"features": jax.ShapeDtypeStruct((batch, WIDTH), jnp.float32),
                 "click": jax.ShapeDtypeStruct((batch,), jnp.float32)}
    compiled = jax.jit(step).lower(state_abs, batch_abs).compile()
    cost = compiled.cost_analysis()
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state_abs.params))
    return {"flops": float(cost["flops"]),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "analytic_flops": 6.0 * batch * WIDTH * WIDTH * LAYERS,
            "net_bytes": 2.0 * 4.0 * n_params}


def write_plots(outdir: str) -> List[str]:
    import os
    os.makedirs(outdir, exist_ok=True)
    analyses = [analyze(mlp_unit(b), CLX) for b in BATCHES if b >= 64]
    paths = []
    p = os.path.join(outdir, "fig6_ridgeline.svg")
    with open(p, "w") as f:
        f.write(svg_plot(analyses, CLX))
    paths.append(p)
    p = os.path.join(outdir, "fig6_ridgeline.txt")
    with open(p, "w") as f:
        f.write(ascii_plot(analyses, CLX))
    paths.append(p)
    return paths
