"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's key
claim, checked against the paper), writes figure artifacts under
``artifacts/figures``, and persists the whole run as ``BENCH_ridgeline.json``
at the repo root — sweep-engine throughput plus the current calibration
error summary — so later PRs have a perf baseline to diff against.
Paper-claim mismatches EXIT NONZERO.
"""
from __future__ import annotations

import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def _sweep_throughput(cells: int = 1 << 20) -> float:
    """Sweep-engine cells/second on a cells-sized broadcast grid."""
    import numpy as np

    from repro.core import CLX
    from repro.core import sweep as sweep_mod
    side = int(cells ** 0.5)
    flops = np.linspace(1e9, 1e13, side)[:, None]
    net = np.linspace(1e6, 1e10, side)[None, :]
    sweep_mod.sweep(flops, 1e9, net, CLX)           # warm the allocator
    t0 = time.perf_counter()
    res = sweep_mod.sweep(flops, 1e9, net, CLX)
    dt = time.perf_counter() - t0
    return res.runtime.size / dt


def _calibration_summary():
    """Error summary of the current calibration registry (None if empty)."""
    from repro.core.hardware import calibration_dir, list_hardware
    names = [n for n, src in list_hardware().items() if src == "calibrated"]
    if not names:
        return None
    out = {}
    for name in names:
        with open(os.path.join(calibration_dir(), f"{name}.json")) as f:
            d = json.load(f)
        # the tiny decode step is the structurally-hardest validation point
        # (ROADMAP's ~40% under-prediction gap); pin its error explicitly so
        # the regression test can watch it without re-parsing measurements
        decode = next((m for m in d.get("validation_measurements", [])
                       if m.get("meta", {}).get("kind") == "serve_step"),
                      None)
        out[name] = {
            "base": d.get("base"),
            "schema": d.get("schema"),
            "estimator": d.get("estimator"),
            "peak_flops": d["peak_flops"],
            "hbm_bw": d["hbm_bw"],
            "net_bw": d["net_bw"],
            # fitted α terms (v2+; absent/zero in v1 entries) — the perf
            # trajectory of the 27.5% -> single-digit validation error
            # improvement tracks these alongside the ceilings
            "alpha_compute": d.get("alpha_compute", 0.0),
            "alpha_memory": d.get("alpha_memory", 0.0),
            "alpha_network": d.get("alpha_network", 0.0),
            # the v3 size-dependent achievable-PEAK curve (identity when
            # the α–β intercept explained the GEMM suite better)
            "compute_eff": d.get("compute_eff",
                                 {"f_half": 0.0, "p": 1.0, "eff_min": 0.0}),
            "extra_links": d.get("extra_links", {}),
            "link_alphas": d.get("link_alphas", {}),
            "sources": d.get("sources", {}),
            "fit": d.get("fit", {}),
            "validation": d.get("validation", {}),
            "decode_validation": None if decode is None else {
                "name": decode.get("name"),
                "rel_error": decode.get("rel_error"),
                "model_seconds": decode.get("model_seconds"),
                "measured_seconds": decode.get("best_seconds",
                                               decode.get("seconds")),
            },
        }
    return out


def main() -> int:
    from benchmarks import arch_table, paper_case_study as cs
    from repro.obs.metrics import REGISTRY, provenance

    rows = []
    ok = True

    # section wall-clocks (validation / planner / sweep / calibration) land
    # as gauges in the registry and in BENCH's "obs" block; explicit
    # enter/exit keeps the long section bodies at their natural indent
    _sec = REGISTRY.section("section.validation_s")
    _sec.__enter__()

    # --- paper §III case study -------------------------------------------------
    (r4a, d4a), us = _timed(cs.fig4a_intensity)
    rows.append(("fig4a_intensity", us,
                 f"ridge_crossing_batch={d4a['ridge_crossing_batch']}"))
    ok &= d4a["ridge_crossing_batch"] == d4a["paper_claim"]

    (r4b, d4b), us = _timed(cs.fig4b_roofline)
    rows.append(("fig4b_roofline", us,
                 f"first_compute_bound_batch={d4b['first_compute_bound_batch']}"))
    ok &= d4b["first_compute_bound_batch"] == d4b["paper_claim"]

    (r4c, d4c), us = _timed(cs.fig4c_allreduce_vs_compute)
    rows.append(("fig4c_allreduce", us,
                 f"crossover_batch={d4c['crossover_batch']:.0f}_vs_paper_512"))
    ok &= d4c["within_10pct_of_512"]

    (r6, d6), us = _timed(cs.fig6_ridgeline)
    rows.append(("fig6_ridgeline", us,
                 f"b256={d6['b256']};b1024={d6['b1024']};"
                 f"xy512={d6['xy_at_512']:.0f};k*={d6['k_star']:.0f};"
                 f"net_to_compute={d6['network_to_compute_between']}"))
    ok &= d6["b256"] == "network" and d6["b1024"] == "compute"
    # sweep-engine path: the network->compute ridge crossing must land
    # inside the paper's (256, 1024] bracket
    span = d6["network_to_compute_between"]
    ok &= span is not None and 256 <= span[0] and span[1] <= 1024
    _sec.__exit__(None, None, None)
    _sec = REGISTRY.section("section.planner_s")
    _sec.__enter__()

    # parallelism planner: ranked (dp, tp) meshes for the case-study MLP
    from repro.configs import get_config
    from repro.core.hardware import get_hardware
    from repro.launch import plan as plan_mod
    cfg_mlp = get_config("dlrm-mlp")
    plans, us = _timed(plan_mod.plan, cfg_mlp, get_hardware("tpu_v5e"), 16,
                       batch=512)
    rows.append(("planner_dlrm_16chips", us,
                 f"best={plans[0].mesh};step_ms={plans[0].runtime * 1e3:.2f};"
                 f"bottleneck={plans[0].bottleneck}"))
    # substantive planner claims: on v5e the TP-heavy mesh must beat pure DP
    # (smaller ring payload), and for a DP-friendly batch the best projected
    # step time must be monotone non-increasing in chip count (ISSUE #1)
    ok &= plans[0].runtime < max(p.runtime for p in plans if p.tp == 1)
    clx = get_hardware("clx")
    # the scaling curve is one vectorized grid pass now (ISSUE 5), not N
    # separate plan() calls — same monotonicity claim, fraction of the time
    from repro.launch import plan_grid as grid_mod
    chips_scaling = (1, 2, 4, 8, 16, 32, 64)
    sgrid, us = _timed(grid_mod.plan_grid, cfg_mlp, clx, chips_scaling,
                       [4096])
    scaling = sgrid.best_runtime_grid()[:, 0]
    rows.append(("planner_scaling_clx", us,
                 "ms=" + "/".join(f"{t * 1e3:.1f}" for t in scaling)))
    ok &= all(b <= a * (1 + 1e-9) for a, b in zip(scaling, scaling[1:]))

    # grid-scale planner: (dp × tp × pp) × microbatch × batch × chips in
    # broadcast passes; acceptance pins ≥ 1e5 candidates/s and ≥ 10× over
    # looping today's plan() per grid point (tests/test_plan_grid.py)
    chips_grid, batch_grid, max_pp = (4, 8, 16, 32, 64), \
        (256, 512, 1024, 2048, 4096), 8
    # the warm-up pass (allocator + enumeration caches) doubles as the
    # result grid; only the repeats below are timed
    ggrid = grid_mod.plan_grid(cfg_mlp, clx, chips_grid, batch_grid,
                               max_pp=max_pp)

    def _best_of(k, fn):
        best = float("inf")
        for _ in range(k):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    grid_s = _best_of(3, lambda: grid_mod.plan_grid(
        cfg_mlp, clx, chips_grid, batch_grid, max_pp=max_pp))
    loop_s = _best_of(3, lambda: [
        plan_mod.plan(cfg_mlp, clx, c, batch=b, max_pp=max_pp)
        for c in chips_grid for b in batch_grid])
    cands_per_s = ggrid.n_candidates / grid_s
    speedup = loop_s / grid_s
    planner_grid = {
        "chips_grid": list(chips_grid), "batch_grid": list(batch_grid),
        "max_pp": max_pp, "n_candidates": ggrid.n_candidates,
        "grid_ms": grid_s * 1e3, "loop_ms": loop_s * 1e3,
        "candidates_per_s": cands_per_s,
        "speedup_vs_plan_loop": speedup,
    }
    rows.append(("planner_grid_candidates_per_s", grid_s * 1e6,
                 f"candidates={ggrid.n_candidates};"
                 f"per_s={cands_per_s:.3g}"))
    rows.append(("planner_grid_speedup_vs_loop", loop_s * 1e6,
                 f"grid_ms={grid_s * 1e3:.2f};loop_ms={loop_s * 1e3:.2f};"
                 f"speedup={speedup:.1f}x"))
    ok &= cands_per_s >= 1e5 and speedup >= 10.0

    # memory-feasibility cut (ISSUE 6): the same grid against a
    # capacity-starved spec, ZeRO axis on — the mask must prune a real
    # fraction of the candidate set while the masked grid still clears
    # the 1e5-enumerated-candidates/s raw-speed pin
    import dataclasses as _dc
    clx_small = _dc.replace(clx, hbm_capacity_bytes=1e9)
    fgrid = grid_mod.plan_grid(cfg_mlp, clx_small, chips_grid, batch_grid,
                               max_pp=max_pp, zero_stages=(0, 1, 2, 3))
    feas_s = _best_of(3, lambda: grid_mod.plan_grid(
        cfg_mlp, clx_small, chips_grid, batch_grid, max_pp=max_pp,
        zero_stages=(0, 1, 2, 3)))
    feas_cands_per_s = fgrid.n_enumerated / feas_s
    planner_feasibility = {
        "chips_grid": list(chips_grid), "batch_grid": list(batch_grid),
        "max_pp": max_pp, "zero_stages": [0, 1, 2, 3],
        "hbm_capacity_bytes": clx_small.hbm_capacity_bytes,
        "n_enumerated": fgrid.n_enumerated,
        "n_candidates": fgrid.n_candidates,
        "prune_fraction": fgrid.pruned_fraction,
        "grid_ms": feas_s * 1e3,
        "candidates_per_s": feas_cands_per_s,
    }
    rows.append(("planner_feasibility_prune", feas_s * 1e6,
                 f"enumerated={fgrid.n_enumerated};"
                 f"pruned_frac={fgrid.pruned_fraction:.3f};"
                 f"per_s={feas_cands_per_s:.3g}"))
    ok &= feas_cands_per_s >= 1e5 and 0.0 < fgrid.pruned_fraction < 1.0

    # failure-aware goodput (ISSUE 10): the same grid with the Young/Daly
    # overlay priced in must still clear the 1e5 candidates/s pin — the
    # overlay is a handful of broadcast kernels over already-sized arrays
    from repro.resilience import FailureModel
    fm = FailureModel.from_mtbf_hours(2000.0)
    ogrid = grid_mod.plan_grid(cfg_mlp, clx, chips_grid, batch_grid,
                               max_pp=max_pp, goodput=True, failure=fm)
    good_s = _best_of(3, lambda: grid_mod.plan_grid(
        cfg_mlp, clx, chips_grid, batch_grid, max_pp=max_pp,
        goodput=True, failure=fm))
    good_cands_per_s = ogrid.n_candidates / good_s
    planner_goodput = {
        "chips_grid": list(chips_grid), "batch_grid": list(batch_grid),
        "max_pp": max_pp, "mtbf_hours": 2000.0,
        "n_candidates": ogrid.n_candidates,
        "grid_ms": good_s * 1e3,
        "candidates_per_s": good_cands_per_s,
        "overhead_vs_healthy": good_s / grid_s,
        "min_goodput": float(ogrid.goodput.min()),
    }
    rows.append(("planner_goodput_candidates_per_s", good_s * 1e6,
                 f"candidates={ogrid.n_candidates};"
                 f"per_s={good_cands_per_s:.3g};"
                 f"min_goodput={planner_goodput['min_goodput']:.3f}"))
    ok &= good_cands_per_s >= 1e5 and 0.0 < planner_goodput["min_goodput"] < 1.0

    # algorithm selection: with any per-hop latency the log-step tree must
    # win small payloads and a bandwidth-optimal ring large ones, with the
    # planner-reported flip sitting in between (qwen2-7b's dp axis payload
    # is MBs -> ring family; its per-sync act payload at small batch is
    # KBs -> tree, once α > 0)
    from repro.distributed import collectives as coll
    hw_alpha = get_hardware("tpu_v5e")
    alpha_n = 1e-5                       # representative ICI per-hop latency
    flip = coll.all_reduce_flip_payload(16, hw_alpha.net_bw, alpha_n)
    if flip is not None:
        p_flip, algo_small, algo_large = flip
        lo = coll.best_all_reduce(p_flip / 4, 16, hw_alpha.net_bw, alpha_n)[0]
        hi = coll.best_all_reduce(p_flip * 4, 16, hw_alpha.net_bw, alpha_n)[0]
        rows.append(("collective_algo_flip_n16", 0.0,
                     f"flip_bytes={p_flip:.3g};below={lo};above={hi}"))
        ok &= lo == algo_small == "tree" and hi == algo_large
    else:
        rows.append(("collective_algo_flip_n16", 0.0, "no_flip"))
        ok = False
    _sec.__exit__(None, None, None)
    _sec = REGISTRY.section("section.sweep_s")
    _sec.__enter__()

    terms, us = _timed(cs.compiled_terms, 512)
    ratio = terms["flops"] / terms["analytic_flops"]
    rows.append(("compiled_mlp_b512", us,
                 f"hlo_vs_analytic_flops={ratio:.3f}"))
    ok &= 0.9 < ratio < 1.3   # compiled step ~= 3-GEMM accounting (+optimizer)

    figdir = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "figures")
    paths, us = _timed(cs.write_plots, figdir)
    rows.append(("fig6_plots_written", us, ";".join(
        os.path.basename(p) for p in paths)))

    # --- arch zoo roofline tables (from dry-run artifacts, if present) ----------
    stats, us = _timed(arch_table.summary_stats, "16x16")
    if stats["cells"]:
        rows.append(("arch_roofline_16x16", us,
                     f"cells={stats['cells']};"
                     f"bottlenecks={stats['bottleneck_counts']};"
                     f"median_peak_frac={stats['median_peak_fraction']:.3f}"))
        stats2, us2 = _timed(arch_table.summary_stats, "2x16x16")
        rows.append(("arch_roofline_2x16x16", us2,
                     f"cells={stats2['cells']}"))

    # --- micro: core model + kernels ---------------------------------------------
    from repro.core import CLX, WorkUnit, analyze
    w = WorkUnit("probe", 1e12, 1e9, 1e8)
    # min-of-3: a single pass here mostly measures GC pauses against the
    # live jax heap, not the (µs-scale) model
    us = min(_timed(lambda: [analyze(w, CLX) for _ in range(1000)])[1]
             for _ in range(3))
    rows.append(("ridgeline_analyze_x1000", us, "core-model-throughput"))

    cells_per_s, us = _timed(_sweep_throughput)
    rows.append(("sweep_engine_1m_cells", us,
                 f"cells_per_s={cells_per_s:.3g}"))

    import jax
    from repro.kernels import ops
    a = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 512))
    ops.matmul(a, b)   # compile
    _, us = _timed(lambda: jax.block_until_ready(ops.matmul(a, b)))
    rows.append(("pallas_matmul_512_interpret", us, "interpret-mode"))
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 512, 4, 64))
    kk = jax.random.normal(jax.random.PRNGKey(3), (1, 512, 2, 64))
    ops.flash_attention(q, kk, kk)
    _, us = _timed(lambda: jax.block_until_ready(ops.flash_attention(q, kk, kk)))
    rows.append(("pallas_flash_512_interpret", us, "interpret-mode"))
    _sec.__exit__(None, None, None)

    # --- calibration trajectory (α–β fit quality per registry entry) -----------
    with REGISTRY.section("section.calibration_s"):
        calibration = _calibration_summary()
    for name, c in (calibration or {}).items():
        val = c.get("validation") or {}
        rows.append((f"calibration_{name}", 0.0,
                     f"val_median_err={val.get('median_abs_rel_error', 0):.3f};"
                     f"alpha_c={c['alpha_compute']:.2e};"
                     f"alpha_n={c['alpha_network']:.2e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # --- perf baseline for future PRs -----------------------------------------
    snap = REGISTRY.snapshot()
    bench_path = os.path.join(_REPO_ROOT, "BENCH_ridgeline.json")
    with open(bench_path, "w") as f:
        json.dump({
            "schema": "repro.bench/v1",
            "sweep_cells_per_s": cells_per_s,
            "planner_grid": planner_grid,
            "planner_feasibility": planner_feasibility,
            "planner_goodput": planner_goodput,
            "calibration": calibration,
            # who/where/when produced this baseline + per-section wall
            # clocks (regressions localize to a section before a bisect)
            "obs": {
                "provenance": provenance(),
                "sections": {k.removeprefix("section."): v
                             for k, v in snap["gauges"].items()
                             if k.startswith("section.")},
                "metrics": snap,
            },
            "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                     for n, us, d in rows],
            "paper_claims_ok": bool(ok),
        }, f, indent=1, sort_keys=True)
    print(f"# wrote {bench_path}", file=sys.stderr)

    if not ok:
        print("PAPER-CLAIM MISMATCH", file=sys.stderr)
        return 1
    print("# all paper claims reproduced", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
