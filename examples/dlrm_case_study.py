"""End-to-end reproduction of the paper's §III case study.

1. Sweeps the batch size of the DLRM MLP and places every point on the CLX
   Ridgeline plane (Figs 4a/4c/6a/6b) — printing the region table and the
   ASCII Ridgeline plot.
2. Actually trains the (reduced-width) MLP data-parallel on CPU to show the
   full substrate runs: BCE loss decreases on the synthetic CTR stream.
3. Demonstrates the paper's prescription: with int8 gradient compression the
   network term drops 4x and the network-bound region shrinks — points that
   were network-bound move toward compute-bound.

    PYTHONPATH=src python examples/dlrm_case_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import CLX, WorkUnit, analyze, ascii_plot
from repro.data.pipeline import DataConfig, make_stream
from repro.models.mlp_dlrm import analytic_work_unit
from repro.optim.compression import Int8Compressor
from repro.optim.optimizer import SGD
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state

WIDTH, LAYERS = 4096, 8


def sweep():
    print("=== Paper §III: DLRM MLP batch sweep on CLX "
          f"(x*={CLX.ridge_memory:.2f}, y*={CLX.ridge_arithmetic:.0f}, "
          f"k*={CLX.ridge_network:.0f}) ===")
    analyses = []
    print(f"{'batch':>6} {'I_A':>8} {'I_M':>6} {'I_N':>8} {'region':>8} "
          f"{'t_comp':>9} {'t_net':>9} {'bound_runtime':>13}")
    for b in (64, 128, 256, 512, 1024, 2048, 4096):
        f, bm, bn = analytic_work_unit(b, WIDTH, LAYERS)
        a = analyze(WorkUnit(f"b{b}", f, bm, bn), CLX)
        analyses.append(a)
        print(f"{b:>6} {a.y:>8.1f} {a.x:>6.2f} "
              f"{a.work.network_intensity:>8.0f} {a.bottleneck.value:>8} "
              f"{a.t_compute*1e3:>8.1f}ms {a.t_network*1e3:>8.1f}ms "
              f"{a.runtime*1e3:>11.1f}ms")
    print("\n" + ascii_plot(analyses, CLX, width=64, height=18))


def sweep_with_compression():
    print("\n=== Beyond paper: int8 error-feedback gradient compression "
          "(B_N / 4) ===")
    frac = Int8Compressor().wire_fraction
    moved = []
    for b in (64, 128, 256, 512):
        f, bm, bn = analytic_work_unit(b, WIDTH, LAYERS)
        before = analyze(WorkUnit(f"b{b}", f, bm, bn), CLX)
        after = analyze(WorkUnit(f"b{b}+int8", f, bm, bn * frac), CLX)
        print(f"batch {b:>5}: {before.bottleneck.value:>8} "
              f"({100*before.peak_fraction:.0f}% peak) -> "
              f"{after.bottleneck.value:>8} ({100*after.peak_fraction:.0f}%)")
        moved.append((before.bottleneck, after.bottleneck))
    assert any(b.value == "network" and a.value != "network"
               for b, a in moved), "compression should move some points"


def train():
    print("\n=== Training the (reduced) DLRM MLP data-parallel on CPU ===")
    cfg = get_reduced("dlrm-mlp").replace(compute_dtype=jnp.float32)
    opt = SGD(learning_rate=0.05, momentum=0.9)
    step = jax.jit(build_train_step(cfg, opt, TrainStepConfig()))
    stream = make_stream(cfg, DataConfig(seed=2, global_batch=256))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    losses = []
    for s in range(150):
        state, m = step(state, jax.tree.map(jnp.asarray, stream.batch(s)))
        losses.append(float(m["loss"]))
        if s % 30 == 0:
            print(f"  step {s:>4}  BCE {losses[-1]:.4f}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"BCE {first:.4f} -> {last:.4f}")
    assert last < first - 0.05
    print("OK")


if __name__ == "__main__":
    sweep()
    sweep_with_compression()
    train()
