"""Plan a parallelism layout before touching any hardware.

Three views of the same question — "how should I spread this model over a
chip budget?" — all answered analytically (no accelerator, no jax tracing):

  1. rank every feasible (dp, tp) mesh for the paper's DLRM MLP on 16 TPU
     v5e chips, per collective algorithm;
  2. sweep the batch axis against the best mesh to find where the step
     leaves the network region (the paper's Fig. 6 question, generalized);
  3. scaling curve: best projected step time vs chip count.

    PYTHONPATH=src python examples/plan_demo.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import sweep as sweep_mod
from repro.core.hardware import get_hardware
from repro.distributed import collectives
from repro.launch.plan import (best_step_time, format_plan_table,
                               param_counts, plan)


def main():
    cfg = get_config("dlrm-mlp")
    hw = get_hardware("tpu_v5e")
    chips, batch = 16, 512

    # 1. ranked meshes, all collective algorithms
    plans = plan(cfg, hw, chips, batch=batch,
                 algorithms=collectives.ALGORITHMS)
    print(f"== {cfg.name}, batch {batch}, {chips}x {hw.name} ==")
    print(format_plan_table(plans[:6]))
    best = plans[0]

    # 2. the paper's Fig. 6 question generalized: batch sweep of the same
    #    MLP, pure DP over 16 CLX sockets — where does the step leave the
    #    network region?
    clx = get_hardware("clx")
    batches = np.array([256, 512, 1024, 2048, 4096, 8192, 16384])
    n_total, _ = param_counts(cfg)
    flops = 6.0 * n_total * batches / 16
    net = collectives.dp_grad_sync_bytes(n_total * 4.0, 16, "ring")
    res = sweep_mod.sweep(flops, n_total * 4.0, net, clx)
    labels = res.labels()
    print("\n== batch sweep, dp16xtp1 on clx ==")
    for i, b in enumerate(batches):
        print(f"  batch {b:>5}: step {res.runtime[i] * 1e3:8.3f} ms  "
              f"-> {labels[i]}")
    for idx, frm, to in sweep_mod.transitions(res, batches):
        print(f"  {frm} -> {to} between batch {batches[idx - 1]} "
              f"and {batches[idx]}")

    # 3. scaling curve
    print("\n== best projected step time vs chips ==")
    floor = best_step_time(cfg, hw, 128, batch=4096)
    for n in (1, 2, 4, 8, 16, 32, 64, 128):
        t = best_step_time(cfg, hw, n, batch=4096)
        print(f"  {n:>4} chips: {t * 1e3:9.3f} ms  "
              + "#" * max(1, int(t / floor)))


if __name__ == "__main__":
    main()
