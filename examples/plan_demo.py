"""Plan a parallelism layout before touching any hardware.

Three views of the same question — "how should I spread this model over a
chip budget?" — all answered analytically (no accelerator, no jax tracing):

  1. rank every feasible (dp, tp) mesh for the paper's DLRM MLP on 16 TPU
     v5e chips, per collective algorithm;
  2. sweep the batch axis against the best mesh to find where the step
     leaves the network region (the paper's Fig. 6 question, generalized);
  3. scaling curve: best projected step time vs chip count — one
     vectorized ``plan_grid`` pass instead of N ``plan()`` calls;
  4. the pipeline axis: the chips × batch surface with pp ≤ 8 stages and
     1F1B microbatching, still a single broadcast pass.

    PYTHONPATH=src python examples/plan_demo.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import sweep as sweep_mod
from repro.core.hardware import get_hardware
from repro.distributed import collectives
from repro.launch.plan import (format_plan_table, param_counts, plan,
                               plan_grid)


def main():
    cfg = get_config("dlrm-mlp")
    hw = get_hardware("tpu_v5e")
    chips, batch = 16, 512

    # 1. ranked meshes, all collective algorithms
    plans = plan(cfg, hw, chips, batch=batch,
                 algorithms=collectives.ALGORITHMS)
    print(f"== {cfg.name}, batch {batch}, {chips}x {hw.name} ==")
    print(format_plan_table(plans[:6]))
    best = plans[0]

    # 2. the paper's Fig. 6 question generalized: batch sweep of the same
    #    MLP, pure DP over 16 CLX sockets — where does the step leave the
    #    network region?
    clx = get_hardware("clx")
    batches = np.array([256, 512, 1024, 2048, 4096, 8192, 16384])
    n_total, _ = param_counts(cfg)
    flops = 6.0 * n_total * batches / 16
    net = collectives.dp_grad_sync_bytes(n_total * 4.0, 16, "ring")
    res = sweep_mod.sweep(flops, n_total * 4.0, net, clx)
    labels = res.labels()
    print("\n== batch sweep, dp16xtp1 on clx ==")
    for i, b in enumerate(batches):
        print(f"  batch {b:>5}: step {res.runtime[i] * 1e3:8.3f} ms  "
              f"-> {labels[i]}")
    for idx, frm, to in sweep_mod.transitions(res, batches):
        print(f"  {frm} -> {to} between batch {batches[idx - 1]} "
              f"and {batches[idx]}")

    # 3. scaling curve — one vectorized grid pass, not N plan() calls
    print("\n== best projected step time vs chips (one plan_grid pass) ==")
    chips_axis = (1, 2, 4, 8, 16, 32, 64, 128)
    grid = plan_grid(cfg, hw, chips_axis, [4096])
    curve = grid.best_runtime_grid()[:, 0]
    floor = curve[-1]
    for n, t in zip(chips_axis, curve):
        print(f"  {n:>4} chips: {t * 1e3:9.3f} ms  "
              + "#" * max(1, int(t / floor)))

    # 4. open the pipeline axis: chips × batch surface with pp up to 8
    #    stages and 1F1B microbatching, still one broadcast pass
    print("\n== chips x batch surface with --pp 8 (best mesh per point) ==")
    surface = plan_grid(cfg, clx, (8, 16, 32, 64), (256, 1024, 4096),
                        max_pp=8)
    print(f"  {surface.n_candidates} candidates in one pass")
    for c in surface.chips_list:
        for b in surface.batch_list:
            p = surface.best(c, b)
            print(f"  {c:>3} chips, batch {b:>5}: {p.mesh:>14} "
                  f"m={p.microbatches:<4} {p.runtime * 1e3:8.3f} ms "
                  f"({p.bottleneck})")


if __name__ == "__main__":
    main()
