"""Quickstart: train a ~100M-class LM (SmolLM-135M family, reduced width for
CPU) for a few hundred steps with the full production stack: data pipeline,
AdamW + cosine schedule, fault-tolerant runner with periodic async
checkpoints, and a Ridgeline report of the compiled step at the end.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_reduced
from repro.core import TPU_V5E, WorkUnit, analyze
from repro.core.hlo_analysis import analyze_compiled
from repro.data.pipeline import DataConfig, make_stream
from repro.optim.optimizer import AdamW, warmup_cosine
from repro.train.fault_tolerance import ResilientRunner, RunnerConfig
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    # reduced config = same family, CPU-sized (full config needs the pod)
    cfg = get_reduced(args.arch).replace(compute_dtype=jnp.float32)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")

    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.steps))
    train_step = jax.jit(build_train_step(cfg, opt, TrainStepConfig()),
                         donate_argnums=(0,))
    stream = make_stream(cfg, DataConfig(
        seed=0, global_batch=args.batch, seq_len=args.seq))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")
    runner = ResilientRunner(
        train_step, Checkpointer(ckpt_dir, keep=2),
        RunnerConfig(ckpt_every=100, async_ckpt=True))
    state, history = runner.run(state, stream, n_steps=args.steps)

    first = np.mean([h["ce"] for h in history[:10]])
    last = np.mean([h["ce"] for h in history[-10:]])
    print(f"\nCE: {first:.3f} -> {last:.3f} over {len(history)} steps "
          f"(log V = {np.log(min(cfg.vocab_size, 512)):.3f})")

    # Ridgeline analysis of the compiled step (1 CPU device -> B_N = 0)
    batch_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.tree.map(jnp.asarray, stream.batch(0)))
    state_abs = jax.eval_shape(lambda s: s, state)
    compiled = jax.jit(build_train_step(cfg, opt, TrainStepConfig())).lower(
        state_abs, batch_abs).compile()
    costs = analyze_compiled(compiled, 1)
    wu = WorkUnit("quickstart_step", costs.flops, costs.mem_bytes,
                  costs.wire_bytes)
    print(analyze(wu, TPU_V5E).summary())
    assert last < first - 0.2, "training did not learn"
    print("OK")


if __name__ == "__main__":
    main()
