"""Use the Ridgeline as a TOOL on your own jitted function.

This is the paper's contribution packaged as a library: hand
``ridgeline_of`` any jit-compilable step + inputs, and it returns the
(F, B_M, B_N) work unit from the compiled artifact, classifies the
bottleneck on your hardware, and prints the prescription.

Here we analyze three programs with deliberately different bottlenecks on
TPU v5e constants: a GEMM (compute), a pointwise stencil (memory), and a
toy DP gradient exchange modelled analytically (network).

    PYTHONPATH=src python examples/ridgeline_analysis.py
"""
import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, WorkUnit, analyze, ascii_plot
from repro.core.hlo_analysis import analyze_compiled


def ridgeline_of(fn, *args, name: str = "fn", hw=TPU_V5E,
                 extra_net_bytes: float = 0.0):
    """Compile ``fn`` and place it on the Ridgeline plane of ``hw``."""
    abstract = [jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a) for a in args]
    compiled = jax.jit(fn).lower(*abstract).compile()
    costs = analyze_compiled(compiled, num_devices=1)
    wu = WorkUnit(name, costs.flops, costs.mem_bytes,
                  costs.wire_bytes + extra_net_bytes)
    return analyze(wu, hw)


def main():
    k = jax.random.PRNGKey(0)
    a = jax.random.normal(k, (4096, 4096), jnp.bfloat16)

    gemm = ridgeline_of(lambda x: x @ x, a, name="gemm_4096")
    stencil = ridgeline_of(
        lambda x: x[1:-1] + 0.5 * (x[:-2] + x[2:]), a, name="stencil")
    # toy DP worker: tiny local GEMM + full-gradient exchange (analytic B_N)
    small = jax.random.normal(k, (256, 256), jnp.bfloat16)
    dp = ridgeline_of(lambda x: x @ x, small, name="dp_worker",
                      extra_net_bytes=2 * 256 * 256 * 4)

    print("Ridgeline on TPU v5e "
          f"(x*={TPU_V5E.ridge_memory:.1f}, y*={TPU_V5E.ridge_arithmetic:.0f}"
          f", k*={TPU_V5E.ridge_network:.0f}):\n")
    for a_ in (gemm, stencil, dp):
        print(" ", a_.summary())
    print("\n" + ascii_plot([gemm, stencil, dp], TPU_V5E, width=64, height=16))

    assert gemm.bottleneck.value == "compute"
    assert stencil.bottleneck.value == "memory"
    assert dp.bottleneck.value == "network"
    print("\nOK — three programs, three regions")


if __name__ == "__main__":
    main()
