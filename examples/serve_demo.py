"""Serving demo: batched greedy decoding with the KV-cache / recurrent-state
engines, across three architecture families (dense KV cache, xLSTM constant
state, Hymba sliding-window hybrid).

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.optim.optimizer import AdamW
from repro.serve.engine import greedy_generate
from repro.train.loop import init_train_state


def demo(arch: str, steps: int = 24):
    cfg = get_reduced(arch).replace(compute_dtype=jnp.float32)
    params = init_train_state(jax.random.PRNGKey(0), cfg, AdamW()).params
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, steps=steps,
                          max_len=8 + steps)
    dt = time.perf_counter() - t0
    n_new = out.shape[1] - prompt.shape[1]
    print(f"{arch:<18} family={cfg.family:<7} batch=4  "
          f"+{n_new} tokens in {dt:.2f}s "
          f"({4 * n_new / dt:.0f} tok/s on 1 CPU core)")
    assert out.shape == (4, 8 + steps)
    return out


if __name__ == "__main__":
    for arch in ("smollm-135m", "xlstm-125m", "hymba-1.5b"):
        demo(arch)
    print("OK")
