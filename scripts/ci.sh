#!/usr/bin/env bash
# Tier-1 gate + calibration smoke + paper-claim checks — what `make ci` runs.
#   tests:      PYTHONPATH via pytest.ini (pythonpath = src .); the fast
#               tier (-m "not slow", <60s) runs first for quick signal,
#               then the slow end-to-end tier
#   calibrate:  tiny-shape CPU measurement pass (<60s); refreshes
#               artifacts/calibration so the bench below reports its errors
#   bench:      benchmarks/run.py exits nonzero on any paper-claim mismatch
#               and writes the BENCH_ridgeline.json perf baseline
set -euo pipefail
cd "$(dirname "$0")/.."

if printf '%s\n' "$@" | grep -q -- '^-m'; then
    # the caller picked their own marker expression: a second -m would
    # silently override the tier split, so run a single invocation
    python -m pytest -x -q "$@"
else
    # exit code 5 = "no tests collected": fine for either tier when the
    # caller's args (a file, -k pattern) select tests only in the other one
    python -m pytest -x -q -m "not slow" "$@" || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
    python -m pytest -x -q -m "slow" "$@" || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.measure.calibrate --backend cpu --smoke --devices 4
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run
