#!/usr/bin/env bash
# Tier-1 gate + calibration smoke + paper-claim checks — what `make ci` runs.
#   lint:       `make lint` (ruff when installed, plus the repro.analysis
#               units/contract/state gate); runs inside the fast-tier
#               wall-clock budget so it cannot silently grow, and a JSON
#               findings report is written to artifacts/analysis/ below
#   tests:      PYTHONPATH via pytest.ini (pythonpath = src .); the fast
#               tier (-m "not slow", budgeted below) runs first for quick
#               signal, then the slow end-to-end tier
#   budget:     the fast tier must stay under FAST_BUDGET_S wall-clock
#               seconds (default 75, ~60s of tests plus collection slack).
#               A fast tier that creeps past the budget fails CI: mark the
#               offending tests `slow` instead of silently bloating tier-1.
#               `--durations=10` prints the worst offenders on every run.
#   calibrate:  tiny-shape CPU measurement pass (<60s); refreshes
#               artifacts/calibration so the bench below reports its errors
#   bench:      benchmarks/run.py exits nonzero on any paper-claim mismatch
#               and writes the BENCH_ridgeline.json perf baseline (incl.
#               the grid-planner candidates/s + speedup rows that
#               tests/test_plan_grid.py regression-pins on the next run)
#   trace:      a traced fast-tier planner run writes a Chrome-trace
#               artifact to artifacts/traces/ and validates it against
#               the repro.obs schema (nesting, required fields)
set -euo pipefail
cd "$(dirname "$0")/.."

FAST_BUDGET_S=${FAST_BUDGET_S:-75}

if printf '%s\n' "$@" | grep -q -- '^-m'; then
    # the caller picked their own marker expression: a second -m would
    # silently override the tier split, so run a single invocation
    python -m pytest -x -q "$@"
else
    # exit code 5 = "no tests collected": fine for either tier when the
    # caller's args (a file, -k pattern) select tests only in the other one
    fast_t0=$(date +%s)
    make lint
    python -m pytest -x -q -m "not slow" --durations=10 "$@" \
        || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
    fast_s=$(( $(date +%s) - fast_t0 ))
    echo "fast tier: ${fast_s}s (budget ${FAST_BUDGET_S}s)"
    if [ "$fast_s" -gt "$FAST_BUDGET_S" ]; then
        echo "FAST TIER OVER BUDGET: ${fast_s}s > ${FAST_BUDGET_S}s —" \
             "mark the offenders above (see --durations) as slow" >&2
        exit 1
    fi
    python -m pytest -x -q -m "slow" "$@" || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.measure.calibrate --backend cpu --smoke --devices 4
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run

# machine-readable analyzer report for CI artifact upload; the `make lint`
# gate above already failed the build if this is non-empty
mkdir -p artifacts/analysis
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.analysis --json src/repro > artifacts/analysis/findings.json

mkdir -p artifacts/traces
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.plan --arch qwen2-7b --hardware tpu_v5e \
        --chips 16 --batch 8 --seq 128 --zero auto --explain \
        --trace artifacts/traces/ci_plan.trace.json > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.obs --validate artifacts/traces/ci_plan.trace.json
