#!/usr/bin/env bash
# Tier-1 gate + paper-claim checks, exactly what CI (and `make ci`) runs.
#   tests:  PYTHONPATH via pytest.ini (pythonpath = src .)
#   bench:  benchmarks/run.py exits nonzero on any paper-claim mismatch
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run
