#!/usr/bin/env bash
# Tier-1 gate + calibration smoke + paper-claim checks — what `make ci` runs.
#   tests:      PYTHONPATH via pytest.ini (pythonpath = src .)
#   calibrate:  tiny-shape CPU measurement pass (<60s); refreshes
#               artifacts/calibration so the bench below reports its errors
#   bench:      benchmarks/run.py exits nonzero on any paper-claim mismatch
#               and writes the BENCH_ridgeline.json perf baseline
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.measure.calibrate --backend cpu --smoke --devices 4
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run
