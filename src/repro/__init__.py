"""repro — Ridgeline (2D distributed roofline) reproduction & growth.

Layer map (jax-free unless noted):

  core         the Ridgeline model, hardware specs (datasheet + calibrated),
               vectorized sweeps, HLO cost parsing, report artifacts
  configs      the architecture zoo (ModelConfig registry)
  models       pure-jax functional model families              [jax]
  kernels      Pallas kernels + jnp reference oracles          [jax]
  distributed  sharding + analytic collective cost models
  train/serve  step construction and decode engine             [jax]
  optim/data/checkpoint  training substrate                    [jax]
  launch       dry-run lowering, parallelism planner CLI
  measure      wall-clock microbenchmarks + ceiling calibration

Every subpackage is a real package (no namespace fallback) so tooling that
walks ``repro.*`` — and ``python -m repro.<pkg>.<cli>`` — resolves them
deterministically.
"""
