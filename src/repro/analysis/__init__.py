"""`repro.analysis` — static checks for the cost model's physics.

Three passes over the `src/repro` AST, run as a CI gate
(``python -m repro.analysis [--json] [PATHS]``, exit 0 iff clean):

- **units** (:mod:`.units`, :mod:`.lint`): dimensional analysis — flops,
  bytes, seconds, and their rates must never be conflated.
- **contracts** (:mod:`.contracts`): ``@shape_contract`` broadcast-shape
  declarations on the vectorized kernels, statically validated here and
  runtime-enforced when ``REPRO_CHECK=1``.
- **state** (:mod:`.state_lint`): writes to module-level mutable state
  must hold a lock.

Suppress a finding with ``# unit: ignore[why]`` / ``# contract:
ignore[why]`` / ``# state: ignore[why]`` — the reason is mandatory.
"""
from .contracts import (ShapeContractError, checking_enabled,  # noqa: F401
                        set_checking, shape_contract)
from .report import Finding, SCHEMA  # noqa: F401
from .runner import check_paths, main  # noqa: F401
from .units import Unit, UnitError, parse_unit  # noqa: F401

__all__ = ["shape_contract", "ShapeContractError", "set_checking",
           "checking_enabled", "Finding", "SCHEMA", "check_paths", "main",
           "Unit", "UnitError", "parse_unit"]
