"""CLI entry: ``python -m repro.analysis [--json] [PATHS]``, exit 0 iff clean."""
import sys

from .runner import main

try:
    rc = main()
except BrokenPipeError:
    # downstream pager/head closed the pipe mid-report; exit quietly but
    # still nonzero — a truncated report must not read as "clean"
    sys.stderr.close()
    rc = 1
sys.exit(rc)
