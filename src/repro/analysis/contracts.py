"""Broadcast shape contracts for the vectorized cost-model kernels.

The grid planner's speed comes from struct-of-arrays broadcasting: every
kernel takes flat candidate-axis arrays and must stay shape-consistent or
NumPy silently broadcasts a wrong answer.  A contract writes the intended
shapes down once, at the def site::

    @shape_contract("(c,), (c,a) -> (c,a)")
    def price(wire, per_algo): ...

    @shape_contract("batch:(*g), dp:(*g), tp:(*g) -> ()")
    def working_set(cfg, *, batch, dp, tp): ...

Grammar per spec: ``name:`` (optional — binds by parameter name instead of
position) then a parenthesized axis list.  Axis tokens are names (``c``,
``a`` — equal names must have equal sizes, size-1/scalar operands broadcast)
or a starred group ``*g`` (arbitrary rank; all ``*g`` operands must be
mutually NumPy-broadcastable and outputs must be broadcastable to the
group's result shape).  ``()`` is scalar-or-size-1.

Enforcement is runtime but off by default: the wrapper is always installed,
and when checking is disabled (``REPRO_CHECK`` unset/0) it costs one global
load and a branch — the BENCH ≥1e5 cand/s pins hold with contracts compiled
in.  Tier-1 tests set ``REPRO_CHECK=1`` (tests/conftest.py) so every suite
run exercises the full checks.  The static half (:func:`lint_contracts`)
validates specs without importing: parseability, named params exist,
positional arity fits, output axes are bound by inputs.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import inspect
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .report import Finding

__all__ = ["shape_contract", "ShapeContractError", "set_checking",
           "checking_enabled", "parse_contract", "lint_contracts"]


class ShapeContractError(ValueError):
    """A runtime violation of a ``@shape_contract`` declaration."""


#: runtime enforcement flag; initialized once from the environment so the
#: disabled fast path is a single module-global truthiness test.
_CHECK = os.environ.get("REPRO_CHECK", "") not in ("", "0")


def set_checking(enabled: bool) -> bool:
    """Toggle runtime contract enforcement; returns the previous value."""
    global _CHECK
    prev = _CHECK
    _CHECK = bool(enabled)  # state: ignore[single GIL-atomic bool flip, test/CLI toggle — readers tolerate either value]
    return prev


def checking_enabled() -> bool:
    return _CHECK


# --- spec parsing -------------------------------------------------------------

_AXIS_RE = re.compile(r"^[A-Za-z_][A-Za-z_0-9]*$")


@dataclasses.dataclass(frozen=True)
class ArgSpec:
    """One operand's spec: named axes, or a broadcast group."""
    param: Optional[str]          # None = positional
    axes: Tuple[str, ...]         # named axes, outermost first
    group: Optional[str]          # broadcast-group name if starred

    @property
    def is_group(self) -> bool:
        return self.group is not None


@dataclasses.dataclass(frozen=True)
class Contract:
    spec: str
    inputs: Tuple[ArgSpec, ...]
    outputs: Tuple[ArgSpec, ...]


def _parse_one(tok: str, spec: str) -> ArgSpec:
    tok = tok.strip()
    param = None
    if ":" in tok:
        param, _, tok = tok.partition(":")
        param = param.strip()
        if not _AXIS_RE.match(param):
            raise ValueError(f"bad parameter name {param!r} in {spec!r}")
        tok = tok.strip()
    if not (tok.startswith("(") and tok.endswith(")")):
        raise ValueError(f"operand {tok!r} in {spec!r} must be parenthesized")
    inner = tok[1:-1].strip().rstrip(",").strip()
    if inner.startswith("*"):
        group = inner[1:].strip()
        if not _AXIS_RE.match(group):
            raise ValueError(f"bad group name {inner!r} in {spec!r}")
        return ArgSpec(param, (), group)
    axes: List[str] = []
    if inner:
        for ax in inner.split(","):
            ax = ax.strip()
            if not _AXIS_RE.match(ax):
                raise ValueError(f"bad axis name {ax!r} in {spec!r}")
            axes.append(ax)
    return ArgSpec(param, tuple(axes), None)


def _split_operands(side: str) -> List[str]:
    """Split on commas at paren depth 0 (axis commas live inside parens)."""
    out, depth, cur = [], 0, []
    for ch in side:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def parse_contract(spec: str) -> Contract:
    if "->" not in spec:
        raise ValueError(f"contract {spec!r} needs '->'")
    lhs, _, rhs = spec.partition("->")
    inputs = tuple(_parse_one(t, spec) for t in _split_operands(lhs))
    outputs = tuple(_parse_one(t, spec) for t in _split_operands(rhs))
    if not inputs:
        raise ValueError(f"contract {spec!r} has no inputs")
    for o in outputs:
        if o.param is not None:
            raise ValueError(f"output operands cannot be named: {spec!r}")
    in_axes = {ax for s in inputs for ax in s.axes}
    in_groups = {s.group for s in inputs if s.is_group}
    for o in outputs:
        for ax in o.axes:
            if ax not in in_axes:
                raise ValueError(
                    f"output axis {ax!r} in {spec!r} is not bound by any "
                    f"input operand")
        if o.is_group and o.group not in in_groups:
            raise ValueError(
                f"output group {o.group!r} in {spec!r} is not bound by any "
                f"input operand")
    return Contract(spec, inputs, outputs)


# --- runtime enforcement ------------------------------------------------------


def _shape_of(value) -> Optional[Tuple[int, ...]]:
    shape = getattr(value, "shape", None)
    if isinstance(shape, tuple):
        return shape
    if isinstance(value, (int, float, bool)):
        return ()
    if isinstance(value, (list, tuple)):
        import numpy as np
        try:
            return np.shape(value)
        except ValueError:  # ragged sequence: let the kernel complain
            return None
    return None  # not array-like: skipped (e.g. configs, dataclasses)


def _check_named(fname: str, where: str, spec: ArgSpec,
                 shape: Tuple[int, ...], sizes: Dict[str, int],
                 contract: str) -> None:
    rank = len(spec.axes)
    if len(shape) > rank:
        raise ShapeContractError(
            f"{fname}: {where} has shape {shape} but contract "
            f"{contract!r} allows rank <= {rank}")
    # right-align: missing leading axes broadcast like size 1
    aligned = (1,) * (rank - len(shape)) + shape
    for ax, size in zip(spec.axes, aligned):
        if size == 1:
            continue
        bound = sizes.get(ax)
        if bound is None or bound == 1:
            sizes[ax] = size
        elif bound != size:
            raise ShapeContractError(
                f"{fname}: {where} axis {ax!r} has size {size}, already "
                f"bound to {bound} (contract {contract!r})")


def _broadcast_shapes(shapes: Sequence[Tuple[int, ...]]) -> Tuple[int, ...]:
    import numpy as np
    try:
        return np.broadcast_shapes(*shapes)
    except ValueError as e:
        raise ShapeContractError(str(e)) from e


def shape_contract(spec: str):
    """Declare broadcast shapes for a vectorized kernel (see module doc).

    The spec parses at decoration time (import errors beat silent drift);
    the wrapped function checks it only when :func:`checking_enabled`.
    """
    contract = parse_contract(spec)

    def decorate(fn):
        sig = inspect.signature(fn)
        param_names = list(sig.parameters)
        positional = [s for s in contract.inputs if s.param is None]
        if len(positional) > len(param_names):
            raise ValueError(
                f"{fn.__name__}: contract {spec!r} has {len(positional)} "
                f"positional operands but the function takes "
                f"{len(param_names)} parameters")
        for s in contract.inputs:
            if s.param is not None and s.param not in sig.parameters:
                raise ValueError(
                    f"{fn.__name__}: contract names parameter {s.param!r} "
                    f"which the function does not take")
        # resolve every input spec to a parameter name once, eagerly
        resolved = []
        pos_iter = iter(param_names)
        for s in contract.inputs:
            pname = s.param if s.param is not None else next(pos_iter)
            resolved.append((pname, s))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _CHECK:
                return fn(*args, **kwargs)
            bound = sig.bind(*args, **kwargs)
            bound.apply_defaults()
            sizes: Dict[str, int] = {}
            groups: Dict[str, List[Tuple[int, ...]]] = {}
            for pname, s in resolved:
                if pname not in bound.arguments:
                    continue
                shape = _shape_of(bound.arguments[pname])
                if shape is None:
                    continue
                if s.is_group:
                    groups.setdefault(s.group, []).append(shape)
                else:
                    _check_named(fn.__name__, f"argument {pname!r}", s,
                                 shape, sizes, spec)
            group_shapes = {g: _broadcast_shapes(shapes)
                            for g, shapes in groups.items()}
            out = fn(*args, **kwargs)
            outs = out if isinstance(out, tuple) else (out,)
            if len(contract.outputs) == len(outs):
                for i, (ospec, val) in enumerate(
                        zip(contract.outputs, outs)):
                    shape = _shape_of(val)
                    if shape is None:
                        continue
                    where = f"output[{i}]"
                    if ospec.is_group:
                        want = group_shapes.get(ospec.group)
                        if want is not None and \
                                _broadcast_shapes([shape, want]) != want:
                            raise ShapeContractError(
                                f"{fn.__name__}: {where} shape {shape} is "
                                f"not broadcastable to group "
                                f"{ospec.group!r} shape {want} "
                                f"(contract {spec!r})")
                    else:
                        _check_named(fn.__name__, where, ospec, shape,
                                     sizes, spec)
            return out

        wrapper.__shape_contract__ = contract
        return wrapper

    return decorate


# --- static pass --------------------------------------------------------------


def _decorator_spec(dec: ast.expr) -> Optional[ast.Call]:
    if isinstance(dec, ast.Call):
        name = dec.func.attr if isinstance(dec.func, ast.Attribute) else \
            dec.func.id if isinstance(dec.func, ast.Name) else None
        if name == "shape_contract":
            return dec
    return None


def lint_contracts(path: str, tree: ast.Module) -> List[Finding]:
    """Validate every ``@shape_contract`` spec in a module without importing.

    Checks: the spec string parses (including output-axes-bound-by-inputs),
    named operands refer to real parameters, and positional operand count
    fits the signature.
    """
    findings: List[Finding] = []

    def flag(node: ast.AST, rule: str, msg: str) -> None:
        findings.append(Finding(path, node.lineno, node.col_offset + 1,
                                rule, "contract", msg))

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            call = _decorator_spec(dec)
            if call is None:
                continue
            if not (call.args and isinstance(call.args[0], ast.Constant)
                    and isinstance(call.args[0].value, str)):
                flag(dec, "contract-bad-spec",
                     f"@shape_contract on {node.name}() needs a literal "
                     f"string spec")
                continue
            spec = call.args[0].value
            try:
                contract = parse_contract(spec)
            except ValueError as e:
                flag(dec, "contract-bad-spec", str(e))
                continue
            params = ([a.arg for a in node.args.posonlyargs]
                      + [a.arg for a in node.args.args]
                      + [a.arg for a in node.args.kwonlyargs])
            params = [p for p in params if p not in ("self", "cls")]
            positional = [s for s in contract.inputs if s.param is None]
            if len(positional) > len(params) and node.args.vararg is None:
                flag(dec, "contract-arity",
                     f"{node.name}(): {len(positional)} positional operands "
                     f"in {spec!r} but only {len(params)} parameters")
            seen = set()
            for s in contract.inputs:
                if s.param is None:
                    continue
                if s.param not in params:
                    flag(dec, "contract-unknown-param",
                         f"{node.name}(): contract names {s.param!r}, not a "
                         f"parameter")
                if s.param in seen:
                    flag(dec, "contract-duplicate-param",
                         f"{node.name}(): parameter {s.param!r} appears "
                         f"twice in {spec!r}")
                seen.add(s.param)
    return findings
