"""The units lint: dimensional analysis over the `repro` AST.

Inference semantics (deliberately conservative — silence over noise):

- Every expression infers to a :class:`~repro.analysis.units.Unit`, the
  sentinel :data:`ANY` (numeric literals — unit-polymorphic, a ``2`` can
  scale bytes or seconds alike), or ``None`` (unknown — poisons silently,
  never flags).
- Declarations seed concrete units: attribute access via
  ``registry.ATTR_UNITS``, call returns via ``RETURN_UNITS``, local and
  parameter names via exact-name/suffix conventions (``name_unit``), and
  an optional module-level ``__repro_units__ = {"name": "spec"}`` dict.
- ``+``/``-``/comparisons/``np.where`` branches/ternaries flag only when
  *both* sides are concrete and incommensurable.  ``*``/``/`` combine
  dimension vectors, so ``bytes ÷ bytes/s → seconds`` and
  ``flops ÷ flops/s → seconds`` fall out of the algebra; ANY on either
  side of ``*``/``/`` makes the result ANY (a literal may carry hidden
  scale, e.g. bytes-per-param constants).
- Assigning a concrete unit to a name whose suffix declares a different
  dimension (``t_bytes = seconds_expr``) is a finding; scale suffixes
  (``_gb``, ``_ms``) exclude the name from inference entirely.
- Call sites of functions in ``PARAM_UNITS`` have their arguments checked
  positionally and by keyword.

Each finding carries file:line:col; suppress with ``# unit: ignore[why]``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Union

from . import registry
from .units import Unit
from .report import Finding

__all__ = ["ANY", "lint_units", "UnitLinter"]


class _Any:
    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<any-unit>"


#: numeric literals and zeros-like constructors: compatible with everything
ANY = _Any()

UnitLike = Union[Unit, _Any, None]

# calls that return their (first) argument's unit unchanged
_PASSTHROUGH_CALLS = {
    "asarray", "ascontiguousarray", "array", "abs", "float", "broadcast_to",
    "full_like", "squeeze", "ravel", "reshape", "copy", "ascontiguousarray",
    "nan_to_num", "atleast_1d",
}
# methods that preserve the receiver's unit
_PASSTHROUGH_METHODS = {
    "sum", "max", "min", "mean", "reshape", "ravel", "astype", "copy",
    "item", "take", "squeeze", "flatten", "clip", "cumsum",
}
# calls whose arguments must be mutually commensurable; result = common unit
_UNIFY_CALLS = {"maximum", "minimum", "fmax", "fmin", "hypot"}
# dimensionless-returning predicates/reductions
_DIMENSIONLESS_CALLS = {
    "len", "argmax", "argmin", "isfinite", "isnan", "isinf", "sign",
    "count_nonzero", "searchsorted", "nonzero",
}


def _callee_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class UnitLinter:
    """Per-file units lint; one instance per source file."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.findings: List[Finding] = []
        self.module_units: Dict[str, Unit] = {}
        self._load_module_decls(tree)

    # -- declarations ----------------------------------------------------------

    def _load_module_decls(self, tree: ast.Module) -> None:
        """Pick up ``__repro_units__ = {"name": "unit-spec"}`` if present."""
        for stmt in tree.body:
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and stmt.targets[0].id == "__repro_units__"
                    and isinstance(stmt.value, ast.Dict)):
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                            and isinstance(v, ast.Constant)
                            and isinstance(v.value, str)):
                        try:
                            from .units import parse_unit
                            self.module_units[k.value] = parse_unit(v.value)
                        except Exception:
                            self._flag(v, "bad-declaration",
                                       f"unparseable unit {v.value!r} in "
                                       f"__repro_units__")

    def _declared(self, name: str) -> object:
        if name in self.module_units:
            return self.module_units[name]
        return registry.name_unit(name)

    # -- findings --------------------------------------------------------------

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(
            self.path, getattr(node, "lineno", 0),
            getattr(node, "col_offset", -1) + 1, rule, "unit", message))

    # -- inference -------------------------------------------------------------

    def infer(self, node: ast.expr, env: Dict[str, UnitLike]) -> UnitLike:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool) or node.value is None:
                return ANY
            if isinstance(node.value, (int, float)):
                return ANY
            return None
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            decl = self._declared(node.id)
            if isinstance(decl, Unit):
                return decl
            if decl is registry.EXCLUDED:
                return None
            # np.inf / math spellings via bare names
            if node.id in ("inf", "nan"):
                return ANY
            return None
        if isinstance(node, ast.Attribute):
            if node.attr in ("inf", "nan", "newaxis", "e", "pi"):
                return ANY
            u = registry.ATTR_UNITS.get(node.attr)
            if u is not None:
                return u
            decl = registry.suffix_unit(node.attr)
            if isinstance(decl, Unit):
                return decl
            return None
        if isinstance(node, ast.UnaryOp):
            return self.infer(node.operand, env)
        if isinstance(node, ast.BinOp):
            return self._infer_binop(node, env)
        if isinstance(node, ast.Compare):
            self._check_compare(node, env)
            return ANY  # booleans scale anything (masks)
        if isinstance(node, ast.IfExp):
            a = self.infer(node.body, env)
            b = self.infer(node.orelse, env)
            return self._unify(node, a, b, "ternary branches")
        if isinstance(node, ast.Call):
            return self._infer_call(node, env)
        if isinstance(node, ast.Subscript):
            return self.infer(node.value, env)
        if isinstance(node, ast.Starred):
            return self.infer(node.value, env)
        if isinstance(node, (ast.Tuple, ast.List)):
            return None  # element units live in tuple-unpack handling
        if isinstance(node, ast.BoolOp):
            return ANY
        return None

    def _infer_binop(self, node: ast.BinOp, env: Dict[str, UnitLike]
                     ) -> UnitLike:
        left = self.infer(node.left, env)
        right = self.infer(node.right, env)
        op = node.op
        if isinstance(op, (ast.Add, ast.Sub)):
            if isinstance(left, Unit) and isinstance(right, Unit):
                if not left.commensurable(right):
                    self._flag(node, "unit-mismatch",
                               f"cannot {'add' if isinstance(op, ast.Add) else 'subtract'} "
                               f"{left} and {right}")
                    return None
                return left
            if isinstance(left, Unit) and right is ANY:
                return left
            if isinstance(right, Unit) and left is ANY:
                return right
            if left is ANY and right is ANY:
                return ANY
            return None
        if isinstance(op, (ast.Mult, ast.Div, ast.FloorDiv)):
            if left is ANY or right is ANY:
                return ANY
            if isinstance(left, Unit) and isinstance(right, Unit):
                return left * right if isinstance(op, ast.Mult) else left / right
            return None
        if isinstance(op, ast.Mod):
            return left if isinstance(left, Unit) else None
        if isinstance(op, ast.Pow):
            if isinstance(left, Unit) and left.is_dimensionless:
                return left
            if left is ANY:
                return ANY
            return None
        if isinstance(op, (ast.BitAnd, ast.BitOr, ast.BitXor)):
            return ANY  # boolean-mask algebra
        return None

    def _check_compare(self, node: ast.Compare,
                       env: Dict[str, UnitLike]) -> None:
        parts = [node.left] + list(node.comparators)
        units = [self.infer(p, env) for p in parts]
        concrete = [(p, u) for p, u in zip(parts, units) if isinstance(u, Unit)]
        for i in range(1, len(concrete)):
            a, b = concrete[i - 1][1], concrete[i][1]
            if not a.commensurable(b):
                self._flag(node, "unit-mismatch",
                           f"comparison between {a} and {b}")
                return

    def _unify(self, node: ast.AST, a: UnitLike, b: UnitLike,
               what: str) -> UnitLike:
        if isinstance(a, Unit) and isinstance(b, Unit):
            if not a.commensurable(b):
                self._flag(node, "unit-mismatch",
                           f"{what} have incommensurable units {a} and {b}")
                return None
            return a
        if isinstance(a, Unit) and b is ANY:
            return a
        if isinstance(b, Unit) and a is ANY:
            return b
        if a is ANY and b is ANY:
            return ANY
        return None

    def _infer_call(self, node: ast.Call, env: Dict[str, UnitLike]
                    ) -> UnitLike:
        name = _callee_name(node.func)
        # argument checks against per-function declarations
        if name in registry.PARAM_UNITS:
            self._check_call_args(node, name, env)
        if name is None:
            return None
        if name in ("where",):  # np.where(cond, a, b): unify branches
            if len(node.args) == 3:
                a = self.infer(node.args[1], env)
                b = self.infer(node.args[2], env)
                return self._unify(node, a, b, "np.where branches")
            return None
        if name in _UNIFY_CALLS:
            out: UnitLike = ANY
            for arg in node.args:
                out = self._unify(node, out, self.infer(arg, env),
                                  f"{name}() arguments")
            return out
        if name in ("zeros", "ones", "empty", "full", "arange", "linspace",
                    "zeros_like", "ones_like", "empty_like"):
            if name == "full" and len(node.args) >= 2:
                return self.infer(node.args[1], env)
            return ANY
        if name in _DIMENSIONLESS_CALLS:
            from .units import DIMENSIONLESS
            return DIMENSIONLESS
        if name in _PASSTHROUGH_CALLS:
            if node.args:
                return self.infer(node.args[0], env)
            return None
        ret = registry.RETURN_UNITS.get(name)
        if isinstance(ret, Unit):
            return ret
        if isinstance(ret, tuple):
            return None  # tuple returns handled at unpack sites
        if (name in _PASSTHROUGH_METHODS
                and isinstance(node.func, ast.Attribute)):
            return self.infer(node.func.value, env)
        return None

    def _check_call_args(self, node: ast.Call, name: str,
                         env: Dict[str, UnitLike]) -> None:
        decls = registry.PARAM_UNITS[name]
        by_name = dict(decls)
        for i, arg in enumerate(node.args):
            if i >= len(decls) or isinstance(arg, ast.Starred):
                break
            pname, want = decls[i]
            self._check_arg(node, name, pname, want, arg, env)
        for kw in node.keywords:
            if kw.arg is not None and kw.arg in by_name:
                self._check_arg(node, name, kw.arg, by_name[kw.arg],
                                kw.value, env)

    def _check_arg(self, node: ast.Call, fname: str, pname: str,
                   want: Optional[Unit], arg: ast.expr,
                   env: Dict[str, UnitLike]) -> None:
        if want is None:
            return
        got = self.infer(arg, env)
        if isinstance(got, Unit) and not got.commensurable(want):
            self._flag(arg, "unit-bad-arg",
                       f"{fname}({pname}=...) expects {want}, got {got}")

    # -- statement walk --------------------------------------------------------

    def check_function(self, fn: ast.FunctionDef) -> None:
        env: Dict[str, UnitLike] = {}
        args = fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            decl = self._declared(a.arg)
            if isinstance(decl, Unit):
                env[a.arg] = decl
        # the function's own declared parameter units, if registered
        for pname, unit in registry.PARAM_UNITS.get(fn.name, ()):
            if unit is not None:
                env.setdefault(pname, unit)
        self._walk_body(fn, fn.body, env)

    def _walk_body(self, fn: ast.FunctionDef, body: Sequence[ast.stmt],
                   env: Dict[str, UnitLike]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # visited independently by lint_units
            if isinstance(stmt, ast.Assign):
                self._handle_assign(stmt, env)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                if isinstance(stmt.target, ast.Name):
                    self._bind(stmt.target, stmt.target.id,
                               self.infer(stmt.value, env), env)
                else:
                    self.infer(stmt.value, env)
            elif isinstance(stmt, ast.AugAssign):
                self._handle_augassign(stmt, env)
            elif isinstance(stmt, ast.Return) and stmt.value is not None:
                self._handle_return(fn, stmt, env)
            elif isinstance(stmt, ast.Expr):
                self.infer(stmt.value, env)
            elif isinstance(stmt, ast.If):
                self.infer(stmt.test, env)
                self._walk_body(fn, stmt.body, env)
                self._walk_body(fn, stmt.orelse, env)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self.infer(stmt.iter, env)
                if isinstance(stmt.target, ast.Name):
                    env[stmt.target.id] = None
                self._walk_body(fn, stmt.body, env)
                self._walk_body(fn, stmt.orelse, env)
            elif isinstance(stmt, ast.While):
                self.infer(stmt.test, env)
                self._walk_body(fn, stmt.body, env)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                self._walk_body(fn, stmt.body, env)
            elif isinstance(stmt, ast.Try):
                self._walk_body(fn, stmt.body, env)
                for h in stmt.handlers:
                    self._walk_body(fn, h.body, env)
                self._walk_body(fn, stmt.orelse, env)
                self._walk_body(fn, stmt.finalbody, env)
            elif isinstance(stmt, (ast.Assert,)):
                self.infer(stmt.test, env)
            elif isinstance(stmt, ast.Raise):
                pass
            # everything else (pass, imports, global, ...) is unit-inert

    def _handle_assign(self, stmt: ast.Assign,
                       env: Dict[str, UnitLike]) -> None:
        value_unit = self.infer(stmt.value, env)
        for target in stmt.targets:
            if isinstance(target, ast.Name):
                self._bind(stmt, target.id, value_unit, env)
            elif isinstance(target, ast.Tuple):
                self._bind_tuple(stmt, target, env)
            # attribute/subscript targets: no tracked binding

    def _bind_tuple(self, stmt: ast.Assign, target: ast.Tuple,
                    env: Dict[str, UnitLike]) -> None:
        elem_units: Optional[tuple] = None
        if isinstance(stmt.value, ast.Call):
            name = _callee_name(stmt.value.func)
            ret = registry.RETURN_UNITS.get(name or "")
            if isinstance(ret, tuple) and len(ret) == len(target.elts):
                elem_units = ret
        elif isinstance(stmt.value, (ast.Tuple, ast.List)) \
                and len(stmt.value.elts) == len(target.elts):
            elem_units = tuple(self.infer(e, env) for e in stmt.value.elts)
        for i, elt in enumerate(target.elts):
            if isinstance(elt, ast.Name):
                u = elem_units[i] if elem_units is not None else None
                self._bind(stmt, elt.id, u, env)

    def _bind(self, node: ast.AST, name: str, value_unit: UnitLike,
              env: Dict[str, UnitLike]) -> None:
        decl = self._declared(name)
        if decl is registry.EXCLUDED:
            env[name] = None
            return
        if isinstance(decl, Unit):
            if isinstance(value_unit, Unit) \
                    and not value_unit.commensurable(decl):
                self._flag(node, "unit-bad-assign",
                           f"'{name}' is declared {decl} by naming "
                           f"convention but is assigned {value_unit}")
            env[name] = decl
            return
        env[name] = value_unit

    def _handle_augassign(self, stmt: ast.AugAssign,
                          env: Dict[str, UnitLike]) -> None:
        if not isinstance(stmt.target, ast.Name):
            self.infer(stmt.value, env)
            return
        cur = self.infer(stmt.target, env)
        val = self.infer(stmt.value, env)
        if isinstance(stmt.op, (ast.Add, ast.Sub)):
            if isinstance(cur, Unit) and isinstance(val, Unit) \
                    and not cur.commensurable(val):
                self._flag(stmt, "unit-mismatch",
                           f"augmented {'+=' if isinstance(stmt.op, ast.Add) else '-='} "
                           f"mixes {cur} and {val}")
        elif isinstance(stmt.op, (ast.Mult, ast.Div)):
            if isinstance(cur, Unit) and isinstance(val, Unit):
                new = cur * val if isinstance(stmt.op, ast.Mult) else cur / val
                self._bind(stmt, stmt.target.id, new, env)

    def _handle_return(self, fn: ast.FunctionDef, stmt: ast.Return,
                       env: Dict[str, UnitLike]) -> None:
        want = registry.RETURN_UNITS.get(fn.name)
        got = self.infer(stmt.value, env)
        if isinstance(want, Unit) and isinstance(got, Unit) \
                and not got.commensurable(want):
            self._flag(stmt, "unit-bad-return",
                       f"{fn.name}() is declared to return {want} "
                       f"but returns {got}")


def lint_units(path: str, tree: ast.Module) -> List[Finding]:
    """Run the units pass over every function in a parsed module."""
    linter = UnitLinter(path, tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            linter.check_function(node)
    return linter.findings
