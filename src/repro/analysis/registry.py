"""Unit declarations for the real `repro` API surface.

The units lint is only as good as its seed facts.  This registry declares,
once, what the cost model's quantities *are*:

- ``ATTR_UNITS``   — attribute accesses (``work.flops``, ``hw.peak_flops``,
  ``cost.wire_bytes``) whose unit is fixed by the owning dataclass.  Keyed
  by bare attribute name, so only attributes whose unit is unambiguous
  across the whole tree belong here (that invariant is itself part of the
  discipline: PR 8 renamed ``ExplainTerms``'s seconds-valued ``*_bytes``
  fields rather than whitelist the collision).
- ``RETURN_UNITS`` — functions/methods whose return unit is fixed
  (``bandwidth_for`` → bytes/s, ``resource_times`` → (s, s, s)).
- ``PARAM_UNITS``  — per-function parameter units, checked at call sites
  when the callee name matches.
- ``SUFFIX_UNITS`` — naming conventions (``*_bytes``, ``*_bw``, ``*_s``)
  that act as *declarations* on local names: a name carrying a suffix is
  assumed to hold that unit, and a concrete inferred unit that contradicts
  the suffix is a finding.  Scale suffixes (``_gb``, ``_ms``, ``_us``) map
  to :data:`EXCLUDED` — same dimension, different scale, so the linter
  stays silent rather than blessing e.g. GB as bytes.

New modules extend these dicts (or ship a module-level ``__repro_units__``
mapping, picked up by the linter) rather than sprinkling suppressions.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from .units import (BYTES, BYTES_PER_S, DIMENSIONLESS, FLOPS, FLOPS_PER_S,
                    SECONDS, Unit)

__all__ = ["ATTR_UNITS", "RETURN_UNITS", "PARAM_UNITS", "SUFFIX_UNITS",
           "NAME_UNITS", "EXCLUDED", "name_unit", "suffix_unit"]

#: sentinel: a name the linter must treat as unknown (scale-shifted units)
EXCLUDED = object()

# --- attribute declarations ---------------------------------------------------
# core/ridgeline.WorkUnit, measure/microbench.WorkUnit, core/hardware
# .HardwareSpec, distributed/collectives.CollectiveCost, core/sweep
# .SweepResult, launch/memory.WorkingSet, launch/plan_grid.GridResult /
# ExplainTerms.  Keep every entry tree-unambiguous (see module docstring).
ATTR_UNITS: Dict[str, Unit] = {
    # work (FLOPs)
    "flops": FLOPS,
    "comp_flops_s": SECONDS,          # ExplainTerms: seconds of flop time
    # bytes — traffic, footprints, capacities
    "mem_bytes": BYTES,
    "net_bytes": BYTES,
    "bytes_mem": BYTES,
    "bytes_net": BYTES,
    "wire_bytes": BYTES,
    "hbm_capacity_bytes": BYTES,
    "vmem_bytes": BYTES,
    "act_bytes": BYTES,
    "params": BYTES,                  # WorkingSet fields are bytes
    "grads": BYTES,
    "opt": BYTES,
    "activations": BYTES,
    "kv_cache": BYTES,
    "hbm_used_bytes": BYTES,
    "persisted": BYTES,               # WorkingSet: bytes a checkpoint writes
    # rates
    "peak_flops": FLOPS_PER_S,
    "hbm_bw": BYTES_PER_S,
    "net_bw": BYTES_PER_S,
    "ckpt_bw": BYTES_PER_S,
    # seconds
    "alpha_compute": SECONDS,
    "alpha_memory": SECONDS,
    "alpha_network": SECONDS,
    "t_compute": SECONDS,
    "t_memory": SECONDS,
    "t_network": SECONDS,
    "runtime": SECONDS,
    "best_seconds": SECONDS,
    "seconds": SECONDS,
    "comp_alpha_s": SECONDS,
    "mem_alpha_s": SECONDS,
    "mem_bytes_s": SECONDS,
    "net_dp_alpha_s": SECONDS,
    "net_dp_bytes_s": SECONDS,
    "net_tp_alpha_s": SECONDS,
    "net_tp_bytes_s": SECONDS,
    "net_pp_alpha_s": SECONDS,
    "net_pp_bytes_s": SECONDS,
    "net_ep_alpha_s": SECONDS,
    "net_ep_bytes_s": SECONDS,
    # resilience (FailureModel, MeshPlan goodput fields, VirtualCosts)
    "mtbf_chip_s": SECONDS,
    "restart_s": SECONDS,
    "reshard_s": SECONDS,
    "downtime_s": SECONDS,
    "ckpt_overhead_s": SECONDS,
    "rework_s": SECONDS,
    "ckpt_interval_s": SECONDS,
    "t_step_s": SECONDS,
    "t_ckpt_s": SECONDS,
    "wall_s": SECONDS,
    "useful_s": SECONDS,
    "backoff_base_s": SECONDS,
    "backoff_max_s": SECONDS,
    # dimensionless
    "net_steps": DIMENSIONLESS,
    "steps": DIMENSIONLESS,
    "compute_eff": DIMENSIONLESS,
    "model_rel_error": DIMENSIONLESS,
    "rel_spread": DIMENSIONLESS,
    "goodput": DIMENSIONLESS,
    "backoff_jitter": DIMENSIONLESS,
}

# --- return-unit declarations -------------------------------------------------
# Keyed by bare callee name (function or method).  A tuple value declares a
# tuple return, element-wise; None elements are unknown.
RETURN_UNITS: Dict[str, object] = {
    "bandwidth_for": BYTES_PER_S,
    "alpha_for": SECONDS,
    "effective_peak": FLOPS_PER_S,
    "resource_times": (SECONDS, SECONDS, SECONDS),
    "param_counts": (DIMENSIONLESS, DIMENSIONLESS),
    "expert_param_counts": (DIMENSIONLESS, DIMENSIONLESS),
    "best_all_reduce_grid": (BYTES, DIMENSIONLESS, None),
    "zero_dp_sync": None,             # returns CollectiveCost (object)
    "ep_dispatch_combine": None,      # returns CollectiveCost (object)
    "moe_routing_derate": DIMENSIONLESS,
    "pp_boundary_bytes": BYTES,
    "eff": DIMENSIONLESS,
    "eff_grid": DIMENSIONLESS,
    "time": SECONDS,                  # CollectiveCost.time / time.time
    "perf_counter": SECONDS,
    "training_working_set": None,     # WorkingSet object
    "decode_working_set": None,
    "total": BYTES,                   # WorkingSet.total property-as-call
    # resilience.failures kernels
    "mesh_mtbf_s": SECONDS,
    "ckpt_time_s": SECONDS,
    "young_daly_interval_s": SECONDS,
    "failure_overhead_terms": (SECONDS, SECONDS, SECONDS),
    "goodput_fraction": DIMENSIONLESS,
    "goodput_terms": (SECONDS, SECONDS, SECONDS, SECONDS, DIMENSIONLESS),
    "predicted_goodput": DIMENSIONLESS,
    "goodput_analytic": DIMENSIONLESS,
}

# --- parameter declarations ---------------------------------------------------
# Per-callee (name, unit) pairs in positional order; unit None = unchecked.
# Checked at call sites for both positional and keyword arguments.
_COLLECTIVE_ARGS: Tuple[Tuple[str, Optional[Unit]], ...] = (
    ("payload_bytes", BYTES), ("group_size", DIMENSIONLESS))
PARAM_UNITS: Dict[str, Tuple[Tuple[str, Optional[Unit]], ...]] = {
    "all_reduce": _COLLECTIVE_ARGS,
    "reduce_scatter": _COLLECTIVE_ARGS,
    "all_gather": _COLLECTIVE_ARGS,
    "all_to_all": _COLLECTIVE_ARGS,
    "best_all_reduce_grid": (
        ("payload_bytes", BYTES), ("group_size", DIMENSIONLESS),
        ("bw", BYTES_PER_S), ("alpha", SECONDS)),
    "zero_dp_sync": (("state_bytes_per_chip", BYTES), ("dp", DIMENSIONLESS),
                     ("stage", DIMENSIONLESS)),
    "pp_boundary_bytes": (("act_bytes", BYTES), ("pp", DIMENSIONLESS)),
    "ep_dispatch_combine": (("payload_bytes", BYTES),
                            ("ep", DIMENSIONLESS)),
    "moe_routing_derate": (("ep", DIMENSIONLESS),
                           ("tokens_mb", DIMENSIONLESS)),
    "time": (("link_bw", BYTES_PER_S), ("alpha", SECONDS)),
    "mesh_mtbf_s": (("chips", DIMENSIONLESS), ("mtbf_chip_s", SECONDS)),
    "ckpt_time_s": (("persisted_bytes", BYTES), ("ckpt_bw", BYTES_PER_S)),
    "young_daly_interval_s": (("t_ckpt_s", SECONDS), ("mtbf_s", SECONDS)),
    "failure_overhead_terms": (
        ("t_step_s", SECONDS), ("t_ckpt_s", SECONDS),
        ("interval_s", SECONDS), ("mtbf_s", SECONDS),
        ("downtime_s", SECONDS)),
    "goodput_fraction": (
        ("t_step_s", SECONDS), ("ckpt_overhead_s", SECONDS),
        ("rework_s", SECONDS), ("restart_s", SECONDS)),
}

# --- suffix conventions -------------------------------------------------------
# Longest match wins; matched against lowercased names.  A bare-name entry
# (no leading underscore) also matches the exact name.
SUFFIX_UNITS: Dict[str, object] = {
    "_flops": FLOPS,
    "flops": FLOPS,
    "_bytes": BYTES,
    "bytes": BYTES,
    "_bw": BYTES_PER_S,
    "_seconds": SECONDS,
    "_s": SECONDS,
    "_alpha": SECONDS,
    "alpha": SECONDS,
    "_steps": DIMENSIONLESS,
    "steps": DIMENSIONLESS,
    "_eff": DIMENSIONLESS,
    "_derate": DIMENSIONLESS,
    "derate": DIMENSIONLESS,
    # scale-shifted: same dimension, wrong scale — excluded, never inferred
    "_gb": EXCLUDED,
    "_gib": EXCLUDED,
    "_mb": EXCLUDED,
    "_ms": EXCLUDED,
    "_us": EXCLUDED,
    "_ns": EXCLUDED,
    "_hours": EXCLUDED,               # MTBF CLI surface: hours, not seconds
}


# --- exact-name declarations for local/parameter names ------------------------
# Wins over suffix conventions: ``peak_flops`` is a *rate* despite the
# ``_flops`` suffix (same for any future ``*_flops``-named ceiling).
NAME_UNITS: Dict[str, Unit] = {
    "peak_flops": FLOPS_PER_S,
    "peak": FLOPS_PER_S,
    "hbm_bw": BYTES_PER_S,
    "net_bw": BYTES_PER_S,
    "link_bw": BYTES_PER_S,
    "bw": BYTES_PER_S,
    "ckpt_bw": BYTES_PER_S,
    "goodput": DIMENSIONLESS,
}


def name_unit(name: str) -> object:
    """Declared unit for a local/param name: exact table, then suffix."""
    exact = NAME_UNITS.get(name)
    if exact is not None:
        return exact
    return suffix_unit(name)


def suffix_unit(name: str) -> object:
    """The declared unit for ``name`` by suffix convention, else None.

    Returns a :class:`Unit`, :data:`EXCLUDED`, or None (no convention).
    Longest suffix wins so ``step_ms`` hits ``_ms`` (excluded), not ``_s``.
    """
    low = name.lower()
    best: object = None
    best_len = -1
    for suf, unit in SUFFIX_UNITS.items():
        if (low.endswith(suf) or low == suf.lstrip("_")) and len(suf) > best_len:
            best, best_len = unit, len(suf)
    return best
