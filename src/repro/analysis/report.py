"""Findings, suppressions, and report rendering for `repro.analysis`.

A :class:`Finding` is one diagnostic: file, 1-based line/col, a rule id
(``unit-mismatch``, ``contract-bad-spec``, ``state-unlocked-write``, ...),
the family it belongs to (``unit`` / ``contract`` / ``state``), and a
human message.  Suppressions are source comments of the form

    x = flops + secs  # unit: ignore[explained why this is fine]

matched by family on the finding's line.  An *empty* reason is itself a
finding (``bad-suppression``): the whole point of the mechanism is that
every silenced diagnostic carries its justification in the diff.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, List, Tuple

__all__ = ["Finding", "collect_suppressions", "apply_suppressions",
           "render_text", "render_json", "SCHEMA"]

SCHEMA = "repro.analysis/v1"

_SUPPRESS_RE = re.compile(
    r"#\s*(?P<family>unit|contract|state)\s*:\s*ignore\[(?P<reason>[^\]]*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    family: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.family}/{self.rule}: {self.message}")

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def collect_suppressions(path: str, source: str) -> Tuple[
        Dict[Tuple[int, str], str], List[Finding]]:
    """Scan source for suppression comments.

    Returns ``({(line, family): reason}, bad)`` where ``bad`` holds a
    ``bad-suppression`` finding for each empty-reason comment.  Works on
    raw source lines, so suppressions inside strings are (rare, harmless)
    false matches — acceptable for a lint of our own tree.
    """
    table: Dict[Tuple[int, str], str] = {}
    bad: List[Finding] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        for m in _SUPPRESS_RE.finditer(text):
            family = m.group("family")
            reason = m.group("reason").strip()
            if not reason:
                bad.append(Finding(
                    path, lineno, m.start() + 1, "bad-suppression", family,
                    f"# {family}: ignore[] needs a reason — say why the "
                    f"finding is safe to silence"))
            else:
                table[(lineno, family)] = reason
    return table, bad


def apply_suppressions(
        findings: Iterable[Finding],
        table: Dict[Tuple[int, str], str],
) -> Tuple[List[Finding], List[Dict[str, object]]]:
    """Split findings into (kept, suppressed-with-reason)."""
    kept: List[Finding] = []
    suppressed: List[Dict[str, object]] = []
    for f in findings:
        reason = table.get((f.line, f.family))
        if reason is None:
            kept.append(f)
        else:
            d = f.to_dict()
            d["suppressed_reason"] = reason
            suppressed.append(d)
    return kept, suppressed


def render_text(findings: List[Finding], suppressed: List[Dict[str, object]],
                n_files: int) -> str:
    lines = [f.format() for f in findings]
    lines.append(
        f"repro.analysis: {len(findings)} finding(s), "
        f"{len(suppressed)} suppressed, {n_files} file(s) checked")
    return "\n".join(lines)


def render_json(findings: List[Finding], suppressed: List[Dict[str, object]],
                n_files: int) -> str:
    return json.dumps({
        "schema": SCHEMA,
        "n_files": n_files,
        "n_findings": len(findings),
        "findings": [f.to_dict() for f in findings],
        "suppressed": suppressed,
    }, indent=2, sort_keys=True)
