"""Driver for the three analysis passes: file discovery, suppressions, CLI.

Kept import-light (stdlib only until a pass needs more) so the gate runs
in any CI environment that has Python, independent of numpy/jax installs.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from . import contracts, lint, report, state_lint
from .report import Finding

__all__ = ["check_paths", "check_file", "main"]

#: file basenames never linted (vendored/generated would go here)
_SKIP_NAMES = {"__main__.py"}


def _iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py") and f not in _SKIP_NAMES:
                        out.append(os.path.join(root, f))
    return out


def check_file(path: str) -> Tuple[List[Finding], List[Dict[str, object]]]:
    """All three passes on one file; returns (findings, suppressed)."""
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, (e.offset or 0), "syntax",
                        "unit", f"file does not parse: {e.msg}")], []
    findings: List[Finding] = []
    findings.extend(lint.lint_units(path, tree))
    findings.extend(contracts.lint_contracts(path, tree))
    findings.extend(state_lint.lint_state(path, tree))
    table, bad = report.collect_suppressions(path, source)
    kept, suppressed = report.apply_suppressions(findings, table)
    return kept + bad, suppressed


def check_paths(paths: Sequence[str]) -> Tuple[
        List[Finding], List[Dict[str, object]], int]:
    """Run on files/directories; returns (findings, suppressed, n_files)."""
    files = _iter_py_files(paths)
    findings: List[Finding] = []
    suppressed: List[Dict[str, object]] = []
    for path in files:
        f, s = check_file(path)
        findings.extend(f)
        suppressed.extend(s)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed, len(files)


def _default_target() -> str:
    """src/repro relative to this package (the tree the gate protects)."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="units / shape-contract / global-state lint for repro")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: the repro package)")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable findings report")
    args = ap.parse_args(argv)
    paths = list(args.paths) or [_default_target()]
    findings, suppressed, n_files = check_paths(paths)
    if args.json:
        print(report.render_json(findings, suppressed, n_files))
    else:
        print(report.render_text(findings, suppressed, n_files))
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
