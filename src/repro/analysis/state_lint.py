"""Global-state / thread-safety lint.

PR 7 made the tracer and metrics registry thread-safe by hand; this pass
keeps that discipline mechanical.  Two rules, both scoped to *module-level*
mutable state (function locals and instance attributes are out of scope):

- ``state-unlocked-global``: a function declares ``global NAME`` and
  rebinds it outside a lock-held ``with`` block.  Process-wide singletons
  (``obs.trace._TRACER``) must only flip under their lock.
- ``state-unlocked-mutation``: a function mutates a module-level name that
  was bound to a dict/list/set literal (or comprehension) — subscript
  assignment/deletion or a mutator method call — outside a lock.

What does *not* flag: module top-level statements (import-time init is
single-threaded), ``__init__`` methods (objects under construction are
unshared), anything inside ``with <something whose dotted name contains
"lock">``, and module globals bound to *calls* (``REGISTRY =
MetricsRegistry()``, ``threading.local()`` — those objects own their
synchronization).  Suppress intentional cases with ``# state: ignore[why]``
(e.g. single-threaded CLI caches).
"""
from __future__ import annotations

import ast
from typing import List, Set

from .report import Finding

__all__ = ["lint_state"]

_MUTATORS = {"append", "add", "update", "clear", "pop", "popitem",
             "setdefault", "extend", "remove", "discard", "insert",
             "appendleft", "sort"}

_MUTABLE_LITERALS = (ast.Dict, ast.List, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)


def _module_mutable_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        else:
            continue
        if isinstance(value, _MUTABLE_LITERALS):
            for t in targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _is_lock_ctx(item: ast.withitem) -> bool:
    expr = item.context_expr
    if isinstance(expr, ast.Call):
        expr = expr.func
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
    return any("lock" in p.lower() for p in parts)


class _FnChecker(ast.NodeVisitor):
    def __init__(self, path: str, fn: ast.FunctionDef,
                 mutable_globals: Set[str], findings: List[Finding]):
        self.path = path
        self.fn = fn
        self.mutable_globals = mutable_globals
        self.findings = findings
        self.global_names: Set[str] = set()
        self.lock_depth = 0

    def _flag(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(Finding(
            self.path, node.lineno, node.col_offset + 1, rule, "state", msg))

    # -- scope/lock bookkeeping ------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is not self.fn:
            return  # nested defs are checked independently
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        return

    def visit_With(self, node: ast.With) -> None:
        locked = any(_is_lock_ctx(i) for i in node.items)
        if locked:
            self.lock_depth += 1
        self.generic_visit(node)
        if locked:
            self.lock_depth -= 1

    visit_AsyncWith = visit_With

    def visit_Global(self, node: ast.Global) -> None:
        self.global_names.update(node.names)

    # -- rule 1: unlocked rebinds of `global` names ----------------------------

    def _check_rebind(self, node: ast.AST, name: str) -> None:
        if name in self.global_names and self.lock_depth == 0:
            self._flag(node, "state-unlocked-global",
                       f"{self.fn.name}() rebinds module global '{name}' "
                       f"without holding a lock")

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_target(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_target(node.target, node)
        self.generic_visit(node)

    def _check_target(self, target: ast.expr, node: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self._check_rebind(node, target.id)
        elif isinstance(target, ast.Tuple):
            for e in target.elts:
                self._check_target(e, node)
        elif isinstance(target, ast.Subscript) and \
                isinstance(target.value, ast.Name):
            self._check_mutation(node, target.value.id, "item assignment")

    # -- rule 2: unlocked mutation of module mutable literals ------------------

    def _check_mutation(self, node: ast.AST, name: str, how: str) -> None:
        if name in self.mutable_globals and self.lock_depth == 0:
            self._flag(node, "state-unlocked-mutation",
                       f"{self.fn.name}() mutates module-level mutable "
                       f"'{name}' ({how}) without holding a lock")

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            if isinstance(t, ast.Subscript) and \
                    isinstance(t.value, ast.Name):
                self._check_mutation(node, t.value.id, "item deletion")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _MUTATORS and \
                isinstance(f.value, ast.Name):
            self._check_mutation(node, f.value.id, f".{f.attr}()")
        self.generic_visit(node)


def lint_state(path: str, tree: ast.Module) -> List[Finding]:
    findings: List[Finding] = []
    mutable_globals = _module_mutable_names(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name != "__init__":
            _FnChecker(path, node, mutable_globals, findings).visit(node)
    return findings
