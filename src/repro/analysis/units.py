"""A small unit algebra for the cost model's physical quantities.

The Ridgeline is dimensional analysis: ``t_C = α_C + F/(PEAK·eff(F))`` only
bounds anything if FLOPs, bytes, seconds, and their rates never get
conflated.  This module is the algebra the units lint (``repro.analysis
.lint``) propagates through the AST: a :class:`Unit` is a vector of integer
exponents over the three base dimensions

    flop    floating-point operations (work)
    byte    bytes (memory or wire traffic — same dimension)
    s       seconds (wall time)

so ``bytes/s`` is ``byte·s⁻¹``, dividing ``bytes`` by ``bytes/s`` yields
``seconds``, and adding ``flops`` to ``bytes`` is a dimension error.  The
six canonical units of the cost model (``flops``, ``bytes``, ``seconds``,
``bytes/s``, ``flops/s``, ``dimensionless``) have names; everything else
prints as an exponent product (e.g. the ridge point ``flops/byte``).

Pure stdlib, no numpy: the linter must run anywhere CI does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

__all__ = ["Unit", "UnitError", "parse_unit", "FLOPS", "BYTES", "SECONDS",
           "BYTES_PER_S", "FLOPS_PER_S", "DIMENSIONLESS", "NAMED_UNITS"]


class UnitError(ValueError):
    """A dimensional inconsistency (raised by the algebra, not the linter)."""


@dataclasses.dataclass(frozen=True)
class Unit:
    """A product of integer powers of the base dimensions.

    ``dims`` is a sorted tuple of (dimension, exponent) pairs with zero
    exponents dropped, so equal units compare (and hash) equal — the
    dimensionless unit is the empty tuple.
    """

    dims: Tuple[Tuple[str, int], ...] = ()

    @staticmethod
    def of(**exponents: int) -> "Unit":
        return Unit(tuple(sorted((d, e) for d, e in exponents.items()
                                 if e != 0)))

    @property
    def is_dimensionless(self) -> bool:
        return not self.dims

    def _as_dict(self) -> Dict[str, int]:
        return dict(self.dims)

    def __mul__(self, other: "Unit") -> "Unit":
        d = self._as_dict()
        for dim, e in other.dims:
            d[dim] = d.get(dim, 0) + e
        return Unit.of(**d)

    def __truediv__(self, other: "Unit") -> "Unit":
        d = self._as_dict()
        for dim, e in other.dims:
            d[dim] = d.get(dim, 0) - e
        return Unit.of(**d)

    def __pow__(self, k: int) -> "Unit":
        if not isinstance(k, int):
            raise UnitError(f"unit exponent must be an int, got {k!r}")
        return Unit.of(**{dim: e * k for dim, e in self.dims})

    def commensurable(self, other: "Unit") -> bool:
        """Can the two be added/subtracted/compared? (Same dimensions.)"""
        return self.dims == other.dims

    def __str__(self) -> str:
        name = _UNIT_NAMES.get(self.dims)
        if name is not None:
            return name
        num = [dim if e == 1 else f"{dim}^{e}"
               for dim, e in self.dims if e > 0]
        den = [dim if e == -1 else f"{dim}^{-e}"
               for dim, e in self.dims if e < 0]
        if not num:
            num = ["1"]
        return "*".join(num) + ("/" + "/".join(den) if den else "")


FLOPS = Unit.of(flop=1)
BYTES = Unit.of(byte=1)
SECONDS = Unit.of(s=1)
BYTES_PER_S = BYTES / SECONDS
FLOPS_PER_S = FLOPS / SECONDS
DIMENSIONLESS = Unit()

#: the canonical cost-model vocabulary, as spelled in declarations
NAMED_UNITS: Dict[str, Unit] = {
    "flops": FLOPS,
    "bytes": BYTES,
    "seconds": SECONDS,
    "s": SECONDS,
    "bytes/s": BYTES_PER_S,
    "flops/s": FLOPS_PER_S,
    "dimensionless": DIMENSIONLESS,
    "1": DIMENSIONLESS,
}

_UNIT_NAMES: Dict[Tuple[Tuple[str, int], ...], str] = {
    FLOPS.dims: "flops", BYTES.dims: "bytes", SECONDS.dims: "seconds",
    BYTES_PER_S.dims: "bytes/s", FLOPS_PER_S.dims: "flops/s",
    DIMENSIONLESS.dims: "dimensionless",
}


def parse_unit(spec: str) -> Unit:
    """A unit from its declaration spelling: named, or ``a/b`` quotients.

    Accepts any :data:`NAMED_UNITS` name and quotients/products of them
    (``"bytes/s"``, ``"flops/byte"``); unknown tokens raise ``UnitError``
    naming the vocabulary.
    """
    spec = spec.strip()
    if spec in NAMED_UNITS:
        return NAMED_UNITS[spec]
    # token/token[/token...] — each token a named unit or base dimension
    base = {"flop": FLOPS, "byte": BYTES}
    parts = spec.split("/")
    out: Optional[Unit] = None
    for i, raw in enumerate(parts):
        tok = raw.strip()
        u = NAMED_UNITS.get(tok, base.get(tok))
        if u is None:
            raise UnitError(
                f"unknown unit {tok!r} in {spec!r}; vocabulary: "
                f"{sorted(NAMED_UNITS)} plus base dims {sorted(base)}")
        out = u if out is None else (out / u if i else out * u)
    if out is None:
        raise UnitError(f"empty unit spec {spec!r}")
    return out


def unify(a: Optional[Unit], b: Optional[Unit]) -> Optional[Unit]:
    """Branch-join for the linter: None (unknown) absorbs, mismatch raises.

    Used for ``np.where``/ternary branches and min/max arguments — the two
    sides must be commensurable for the result to mean anything.
    """
    if a is None or b is None:
        return None
    if not a.commensurable(b):
        raise UnitError(f"incommensurable units {a} and {b}")
    return a


def check_commensurable(a: Mapping, b: Mapping) -> bool:  # pragma: no cover
    raise NotImplementedError  # placeholder guard: use Unit.commensurable
