"""Checkpointing (``checkpointer``) and elastic restore (``elastic``)."""
