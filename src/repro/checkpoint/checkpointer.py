"""Fault-tolerant checkpointing: atomic, sharded, async-capable.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # treedef, shapes, dtypes, shard layout, step
        shard_00000.npz        # flat-index -> array chunks for host 0
        ...
        COMMITTED              # written LAST via atomic rename

Guarantees:
  * atomicity — a step directory without COMMITTED is ignored (and GC'd),
    so a host dying mid-save can never corrupt restore;
  * multi-host — each host writes only its own shard file; host 0 writes
    the manifest and the commit marker after a barrier (here: thread join);
  * async — ``save`` can run in a background thread (training continues;
    the previous async save is joined first, bounding staleness to one);
  * keep-N GC of old committed steps;
  * integrity — each shard's crc32 is recorded in the manifest at save;
    ``latest_step`` cheaply skips committed steps whose files are missing
    or empty (a torn write that still managed to commit), and ``restore``
    verifies checksums before trusting any byte: a corrupt step is
    *quarantined* (renamed ``step_*.quarantined_*`` so no later scan
    picks it up) and restore falls back to the previous committed step —
    a bad checkpoint costs one interval of rework, never the job.

Restore reconstructs the pytree on the *current* topology: parameters are
saved in full logical shapes (device-gathered per shard), so restoring onto
a different mesh is just re-sharding at load — which is what
``checkpoint/elastic.py`` exercises.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMITTED"


class CheckpointCorruptionError(RuntimeError):
    """A committed checkpoint failed integrity verification."""


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def _step_of(name: str) -> Optional[int]:
    """Step number of a live ``step_NNN`` directory name; None for
    anything else (tmp dirs, quarantined steps, strays)."""
    if not name.startswith("step_"):
        return None
    tail = name[len("step_"):]
    return int(tail) if tail.isdigit() else None


def _crc32_of(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                return crc
            crc = zlib.crc32(buf, crc)


class Checkpointer:
    def __init__(self, root: str, keep: int = 3, n_hosts: int = 1,
                 host_id: int = 0):
        self.root = root
        self.keep = keep
        self.n_hosts = n_hosts
        self.host_id = host_id
        self._async_thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ---- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, async_: bool = False) -> str:
        """Snapshot ``tree`` at ``step``.  Arrays are host-fetched NOW (so
        training may mutate state immediately); writing happens inline or in
        a background thread."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        meta = {
            "step": step,
            # restore() rebuilds structure from the caller's `like` pytree;
            # the manifest records leaf metadata only (proto-serializing
            # treedefs rejects user-defined nodes like TrainState).
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "n_hosts": self.n_hosts,
        }
        if async_:
            self.wait()
            self._async_thread = threading.Thread(
                target=self._write, args=(step, host_leaves, meta), daemon=True)
            self._async_thread.start()
        else:
            self._write(step, host_leaves, meta)
        return _step_dir(self.root, step)

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_leaves: List[np.ndarray],
               meta: Dict) -> None:
        d = _step_dir(self.root, step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        # each host owns a contiguous slice of the leaf list
        per = (len(host_leaves) + self.n_hosts - 1) // max(self.n_hosts, 1)
        lo, hi = self.host_id * per, min((self.host_id + 1) * per,
                                         len(host_leaves))
        shard_name = f"shard_{self.host_id:05d}.npz"
        np.savez(os.path.join(tmp, shard_name),
                 **{str(i): host_leaves[i] for i in range(lo, hi)})
        # crc32 over the written file: restore refuses to trust any byte
        # that does not hash back (bitrot, torn writes, tampering).  In a
        # multi-host job each host would publish its own checksum before
        # the barrier; single-process, host 0 owns every shard.
        meta["checksums"] = {
            shard_name: _crc32_of(os.path.join(tmp, shard_name))}
        if self.host_id == 0:
            # In a real multi-host job a barrier precedes the commit (every
            # host has written its shard file by barrier entry); in this
            # single-process container host 0 owns all leaves, so the commit
            # is immediate.
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.isdir(d):
                shutil.rmtree(d)
            os.replace(tmp, d)
            with open(os.path.join(d, COMMIT_MARKER), "w") as f:
                f.write(str(time.time()))
            self._gc()

    # ---- restore --------------------------------------------------------------
    def _quick_ok(self, d: str) -> bool:
        """Cheap structural check: a committed step must still have its
        manifest and at least one non-empty shard file (catches zero-length
        truncation without hashing anything)."""
        if not os.path.exists(os.path.join(d, "manifest.json")):
            return False
        shards = [n for n in os.listdir(d)
                  if n.startswith("shard_") and n.endswith(".npz")]
        return bool(shards) and all(
            os.path.getsize(os.path.join(d, n)) > 0 for n in shards)

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            d = os.path.join(self.root, name)
            if (_step_of(name) is not None
                    and os.path.exists(os.path.join(d, COMMIT_MARKER))
                    and self._quick_ok(d)):
                steps.append(_step_of(name))
        return max(steps) if steps else None

    def _quarantine(self, step: int) -> str:
        """Move a corrupt step aside so no scan trusts it again (kept on
        disk, not deleted — the bytes are evidence)."""
        d = _step_dir(self.root, step)
        q = f"{d}.quarantined_{int(time.time() * 1e3)}"
        os.replace(d, q)
        return q

    def _verify(self, d: str) -> None:
        """Checksum every shard against the manifest; raises
        CheckpointCorruptionError on any mismatch.  Manifests predating
        checksums (older checkpoints) skip hash verification."""
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                checksums = json.load(f).get("checksums") or {}
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(f"{d}: unreadable manifest: {e}")
        for name, want in checksums.items():
            path = os.path.join(d, name)
            if not os.path.exists(path):
                raise CheckpointCorruptionError(f"{d}: missing shard {name}")
            got = _crc32_of(path)
            if got != want:
                raise CheckpointCorruptionError(
                    f"{d}: shard {name} crc32 {got:#010x} != "
                    f"manifest {want:#010x}")

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Rebuild the pytree of ``like``'s structure.  ``shardings``
        (optional pytree of NamedSharding) re-shards onto the current mesh —
        the elastic-restart path.

        With ``step=None`` (the auto-resume path), a step that fails
        checksum verification is quarantined and restore falls back to the
        previous committed step until one verifies.  An explicitly
        requested ``step`` is also verified, but corruption raises (the
        caller asked for those exact bytes — silently substituting older
        ones would be worse than failing)."""
        if step is not None:
            return self._restore_step(like, step, shardings), step
        while True:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no committed checkpoint in {self.root}")
            try:
                return self._restore_step(like, step, shardings), step
            except CheckpointCorruptionError:
                self._quarantine(step)

    def _restore_step(self, like: Any, step: int, shardings: Any) -> Any:
        d = _step_dir(self.root, step)
        if not os.path.exists(os.path.join(d, COMMIT_MARKER)):
            raise FileNotFoundError(f"checkpoint {d} not committed")
        self._verify(d)
        arrays: Dict[int, np.ndarray] = {}
        try:
            for name in sorted(os.listdir(d)):
                if name.startswith("shard_") and name.endswith(".npz"):
                    with np.load(os.path.join(d, name)) as z:
                        for k in z.files:
                            arrays[int(k)] = z[k]
        except (OSError, ValueError, KeyError) as e:
            # unreadable zip/npz (e.g. truncated mid-write): same corruption
            # class as a checksum mismatch, same quarantine-and-fall-back
            raise CheckpointCorruptionError(f"{d}: unreadable shard: {e}")
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(arrays) == len(leaves_like), (
            f"checkpoint has {len(arrays)} leaves, expected {len(leaves_like)}")
        restored = []
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(leaves_like))
        for i, proto in enumerate(leaves_like):
            arr = arrays[i]
            if hasattr(proto, "dtype"):
                arr = arr.astype(proto.dtype)
            if flat_sh[i] is not None:
                restored.append(jax.device_put(arr, flat_sh[i]))
            else:
                restored.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, restored)

    # ---- GC --------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            _step_of(n) for n in os.listdir(self.root)
            if _step_of(n) is not None and os.path.exists(
                os.path.join(self.root, n, COMMIT_MARKER)))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)
        # drop orphaned tmp dirs from crashed saves
        for n in os.listdir(self.root):
            if n.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, n), ignore_errors=True)
