"""Fault-tolerant checkpointing: atomic, sharded, async-capable.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # treedef, shapes, dtypes, shard layout, step
        shard_00000.npz        # flat-index -> array chunks for host 0
        ...
        COMMITTED              # written LAST via atomic rename

Guarantees:
  * atomicity — a step directory without COMMITTED is ignored (and GC'd),
    so a host dying mid-save can never corrupt restore;
  * multi-host — each host writes only its own shard file; host 0 writes
    the manifest and the commit marker after a barrier (here: thread join);
  * async — ``save`` can run in a background thread (training continues;
    the previous async save is joined first, bounding staleness to one);
  * keep-N GC of old committed steps.

Restore reconstructs the pytree on the *current* topology: parameters are
saved in full logical shapes (device-gathered per shard), so restoring onto
a different mesh is just re-sharding at load — which is what
``checkpoint/elastic.py`` exercises.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMITTED"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


class Checkpointer:
    def __init__(self, root: str, keep: int = 3, n_hosts: int = 1,
                 host_id: int = 0):
        self.root = root
        self.keep = keep
        self.n_hosts = n_hosts
        self.host_id = host_id
        self._async_thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ---- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, async_: bool = False) -> str:
        """Snapshot ``tree`` at ``step``.  Arrays are host-fetched NOW (so
        training may mutate state immediately); writing happens inline or in
        a background thread."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        meta = {
            "step": step,
            # restore() rebuilds structure from the caller's `like` pytree;
            # the manifest records leaf metadata only (proto-serializing
            # treedefs rejects user-defined nodes like TrainState).
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "n_hosts": self.n_hosts,
        }
        if async_:
            self.wait()
            self._async_thread = threading.Thread(
                target=self._write, args=(step, host_leaves, meta), daemon=True)
            self._async_thread.start()
        else:
            self._write(step, host_leaves, meta)
        return _step_dir(self.root, step)

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_leaves: List[np.ndarray],
               meta: Dict) -> None:
        d = _step_dir(self.root, step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        # each host owns a contiguous slice of the leaf list
        per = (len(host_leaves) + self.n_hosts - 1) // max(self.n_hosts, 1)
        lo, hi = self.host_id * per, min((self.host_id + 1) * per,
                                         len(host_leaves))
        np.savez(os.path.join(tmp, f"shard_{self.host_id:05d}.npz"),
                 **{str(i): host_leaves[i] for i in range(lo, hi)})
        if self.host_id == 0:
            # In a real multi-host job a barrier precedes the commit (every
            # host has written its shard file by barrier entry); in this
            # single-process container host 0 owns all leaves, so the commit
            # is immediate.
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.isdir(d):
                shutil.rmtree(d)
            os.replace(tmp, d)
            with open(os.path.join(d, COMMIT_MARKER), "w") as f:
                f.write(str(time.time()))
            self._gc()

    # ---- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            d = os.path.join(self.root, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(d, COMMIT_MARKER))):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Rebuild the pytree of ``like``'s structure.  ``shardings``
        (optional pytree of NamedSharding) re-shards onto the current mesh —
        the elastic-restart path."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        d = _step_dir(self.root, step)
        if not os.path.exists(os.path.join(d, COMMIT_MARKER)):
            raise FileNotFoundError(f"checkpoint {d} not committed")
        arrays: Dict[int, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.startswith("shard_") and name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        arrays[int(k)] = z[k]
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(arrays) == len(leaves_like), (
            f"checkpoint has {len(arrays)} leaves, expected {len(leaves_like)}")
        restored = []
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(leaves_like))
        for i, proto in enumerate(leaves_like):
            arr = arrays[i]
            if hasattr(proto, "dtype"):
                arr = arr.astype(proto.dtype)
            if flat_sh[i] is not None:
                restored.append(jax.device_put(arr, flat_sh[i]))
            else:
                restored.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, restored), step

    # ---- GC --------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.root, n, COMMIT_MARKER)))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)
        # drop orphaned tmp dirs from crashed saves
        for n in os.listdir(self.root):
            if n.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, n), ignore_errors=True)
