"""Elastic scaling: reshard a checkpoint onto a different mesh.

Scenario: a 16×16 pod loses a row (hardware failure) and the job must
restart on 12×16, or scale from 1 to 2 pods.  Because checkpoints store
*full logical* arrays (see checkpointer.py), resharding is pure metadata:
build the new mesh, derive NamedShardings from the same logical-axis specs
under the new axis sizes (divisibility fallbacks recomputed), and
device_put at restore.

Also provides batch-schedule remapping: with the same global batch and a
different host count, each surviving host's shard of the batch changes —
``repro.data.pipeline`` batches are pure functions of (seed, step, host_id),
so the remap is just constructing new DataConfigs.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.data.pipeline import DataConfig
from repro.distributed.sharding import (_drop_nondividing, logical_spec,
                                        use_sharding)


def reshard_specs(specs: Any, like: Any, mesh: Mesh, rules=None) -> Any:
    """Logical specs + target mesh -> NamedSharding pytree (divisibility-safe)."""

    def one(proto, axes):
        spec = _drop_nondividing(logical_spec(axes, rules), proto.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, like, specs,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


def restore_on_mesh(checkpointer, like: Any, specs: Any, mesh: Mesh,
                    rules=None, step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore a checkpoint saved on any topology onto ``mesh``."""
    with use_sharding(mesh, rules):
        shardings = reshard_specs(specs, like, mesh, rules=None)
        return checkpointer.restore(like, step=step, shardings=shardings)


def remap_data_configs(old: DataConfig, new_n_hosts: int) -> list[DataConfig]:
    """Recompute per-host data configs after an elastic resize."""
    if old.global_batch % new_n_hosts:
        raise ValueError(
            f"global batch {old.global_batch} must divide new host count "
            f"{new_n_hosts}")
    import dataclasses
    return [dataclasses.replace(old, n_hosts=new_n_hosts, host_id=h)
            for h in range(new_n_hosts)]
