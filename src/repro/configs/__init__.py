"""Architecture registry: the 10 assigned archs + the paper's case study."""
from typing import Callable, Dict, List, Tuple

from repro.configs import (dlrm_mlp, hymba_1_5b, internvl2_26b, minitron_8b,
                           qwen2_5_3b, qwen2_7b, qwen2_moe_a2_7b,
                           qwen3_moe_30b_a3b, smollm_135m, whisper_tiny,
                           xlstm_125m)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, cells
from repro.models.config import ModelConfig

_MODULES = [whisper_tiny, qwen2_5_3b, minitron_8b, smollm_135m, qwen2_7b,
            qwen2_moe_a2_7b, qwen3_moe_30b_a3b, xlstm_125m, internvl2_26b,
            hymba_1_5b, dlrm_mlp]

REGISTRY: Dict[str, "module"] = {m.ARCH: m for m in _MODULES}

#: the 10 assigned architectures (dlrm-mlp is the paper's own, extra)
ASSIGNED: Tuple[str, ...] = tuple(m.ARCH for m in _MODULES[:-1])


def get_config(arch: str) -> ModelConfig:
    return REGISTRY[arch].config()


def get_reduced(arch: str) -> ModelConfig:
    return REGISTRY[arch].reduced()


def list_archs() -> List[str]:
    return list(REGISTRY)
