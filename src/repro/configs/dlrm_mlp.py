"""dlrm-mlp — the paper's own case study (§III) [arXiv:2104.05158].

DLRM-style MLP tower: 8 fully-connected layers of width 4096 (the paper's
"input output feature map size of 4096"), trained data-parallel with
all-reduce gradient sync.  Batch is swept by the Fig. 4/6 benchmarks.
"""
from repro.models.config import ModelConfig

ARCH = "dlrm-mlp"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="mlp", n_layers=8, d_model=4096, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab_size=0, mlp_widths=(4096,) * 8)


def reduced() -> ModelConfig:
    return config().replace(n_layers=3, mlp_widths=(64,) * 3, d_model=64)
