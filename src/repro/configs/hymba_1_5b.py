"""hymba-1.5b [arXiv:2411.13676; hf]: parallel attention + mamba heads.

32L, d_model=1600, 25H GQA kv=5 (head_dim 64), d_ff=5504, vocab=32001,
ssm_state=16.  Sliding-window attention (1024) everywhere except global
layers (first / middle / last), per the paper's global+local pattern.
"""
from repro.models.config import ModelConfig

ARCH = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid", n_layers=32, d_model=1600, n_heads=25,
        n_kv_heads=5, d_ff=5504, vocab_size=32001, ssm_state=16,
        sliding_window=1024, global_attn_layers=(0, 15, 31), ssm_chunk=256)


def reduced() -> ModelConfig:
    return config().replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=512, ssm_state=4,
                            sliding_window=8, global_attn_layers=(0, 2),
                            ssm_chunk=8)
