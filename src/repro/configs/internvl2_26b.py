"""internvl2-26b [arXiv:2404.16821; hf]: InternViT (STUB) + InternLM2-20B LM.

LM backbone: 48L, d_model=6144, 48H GQA kv=8, d_ff=16384, vocab=92553.
Vision frontend stubbed: input_specs provides (B, 256, 3200) InternViT-6B
patch embeddings; the 2-layer MLP connector projects them into the LM.
"""
from repro.models.config import ModelConfig

ARCH = "internvl2-26b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab_size=92553,
        visual_tokens=256, visual_width=3200)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=160, vocab_size=512, visual_tokens=4,
                            visual_width=32)
