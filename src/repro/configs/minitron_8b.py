"""minitron-8b [arXiv:2407.14679; hf]: width-pruned Nemotron-4.

32L, d_model=4096, 32H GQA kv=8, d_ff=16384, vocab=256000.
Nemotron family: squared-ReLU MLP (non-gated), no QKV bias.
"""
from repro.models.config import ModelConfig

ARCH = "minitron-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=16384, vocab_size=256000,
        ffn_activation="relu2", norm="layernorm", norm_eps=1e-5)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=512)
