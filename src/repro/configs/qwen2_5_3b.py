"""qwen2.5-3b [hf:Qwen/Qwen2.5 family; hf]: dense GQA decoder, QKV bias.

36L, d_model=2048, 16H GQA kv=2, d_ff=11008, vocab=151936.
"""
from repro.models.config import ModelConfig

ARCH = "qwen2.5-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=36, d_model=2048, n_heads=16,
        n_kv_heads=2, d_ff=11008, vocab_size=151936, qkv_bias=True,
        rope_theta=1_000_000.0)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=160, vocab_size=512)
