"""qwen2-7b [arXiv:2407.10671; hf]: dense GQA decoder, QKV bias.

28L, d_model=3584, 28H GQA kv=4, d_ff=18944, vocab=152064.
"""
from repro.models.config import ModelConfig

ARCH = "qwen2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=28, d_model=3584, n_heads=28,
        n_kv_heads=4, d_ff=18944, vocab_size=152064, qkv_bias=True,
        rope_theta=1_000_000.0)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
                            d_ff=160, vocab_size=512)
