"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L, d_model=2048, 16H MHA (kv=16), vocab=151936; MoE: 60 routed experts
top-4 with per-expert d_ff=1408 + 4 shared experts (shared hidden 5632 =
4x1408), QKV bias.
"""
from repro.models.config import ModelConfig

ARCH = "qwen2-moe-a2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=5632, vocab_size=151936, qkv_bias=True,
        n_experts=60, n_shared_experts=4, moe_top_k=4, moe_d_ff=1408)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                            d_ff=96, vocab_size=512, n_experts=8,
                            n_shared_experts=1, moe_top_k=2, moe_d_ff=24)
