"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf].

48L, d_model=2048, 32H GQA kv=4 with explicit head_dim=128, QK-norm,
vocab=151936; MoE: 128 routed experts top-8, per-expert d_ff=768, no shared.
"""
from repro.models.config import ModelConfig

ARCH = "qwen3-moe-30b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, head_dim=128, d_ff=0, vocab_size=151936,
        qk_norm=True, rope_theta=1_000_000.0,
        n_experts=128, n_shared_experts=0, moe_top_k=8, moe_d_ff=768)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            head_dim=16, vocab_size=512, n_experts=8,
                            moe_top_k=2, moe_d_ff=32)
