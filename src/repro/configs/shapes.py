"""Assigned input-shape presets and the (arch × shape) applicability matrix.

LM transformer shapes are seq_len × global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache / recurrent state of
seq_len), not ``train_step``.  ``long_500k`` requires sub-quadratic attention
and only runs for the SSM / hybrid families (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: families whose decode state is constant-size (or window-bounded) — the
#: only ones assigned long_500k
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(arch_family: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_family in SUBQUADRATIC_FAMILIES
    return True


def cells(arch_family: str) -> Tuple[str, ...]:
    return tuple(s for s in SHAPES if applicable(arch_family, s))
