"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]: llama-arch small LM.

30L, d_model=576, 9H GQA kv=3, d_ff=1536, vocab=49152, tied embeddings.
"""
from repro.models.config import ModelConfig

ARCH = "smollm-135m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=30, d_model=576, n_heads=9,
        n_kv_heads=3, d_ff=1536, vocab_size=49152, tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
                            d_ff=128, vocab_size=512)
