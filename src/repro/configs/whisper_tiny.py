"""whisper-tiny [arXiv:2212.04356; unverified]: enc-dec, conv frontend STUB.

4L enc + 4L dec, d_model=384, 6H MHA (kv=6), d_ff=1536, vocab=51865.
GELU MLP, LayerNorm, biased projections, learned decoder positions,
sinusoidal encoder positions, tied embeddings.  Encoder context fixed at
1500 frames (3000-frame mel -> stride-2 conv stub).  The learned position
table is resized to the requested shape for the 32k cells (DESIGN.md note).
"""
from repro.models.config import ModelConfig

ARCH = "whisper-tiny"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="encdec", n_layers=4, d_model=384, n_heads=6,
        n_kv_heads=6, d_ff=1536, vocab_size=51865, encoder_layers=4,
        encoder_seq=1500, qkv_bias=True, ffn_bias=True,
        ffn_activation="gelu", norm="layernorm", norm_eps=1e-5,
        pos_emb="learned", tie_embeddings=True, max_seq_len=448)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, encoder_seq=24, max_seq_len=64)
