"""xlstm-125m [arXiv:2405.04517; unverified]: sLSTM + mLSTM blocks.

12L, d_model=768, 4H, vocab=50304.  xLSTM[7:1]-style mix: sLSTM at blocks
(3, 11), mLSTM elsewhere (exact positions unpublished for this size; choice
recorded here).  No separate FFN — the blocks carry their own projections.
Unrolled layers (shallow + heterogeneous; see transformer.py docstring).
"""
from repro.models.config import ModelConfig

ARCH = "xlstm-125m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm", n_layers=12, d_model=768, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=50304, slstm_layers=(3, 11),
        ssm_chunk=256, scan_layers=False, tie_embeddings=True,
        pos_emb="none")


def reduced() -> ModelConfig:
    return config().replace(n_layers=3, d_model=48, n_heads=2,
                            n_kv_heads=2, vocab_size=512, slstm_layers=(1,),
                            ssm_chunk=8)
