"""Ridgeline core: the paper's 2D distributed roofline model.

Public API:
  HardwareSpec / TPU_V5E / CLX — machine resource books
  WorkUnit / analyze / RidgelineAnalysis — the model itself
  classify_by_quadrant / classify_by_times — the two (equivalent) classifiers
  parse_collectives / analyze_compiled — HLO-derived work units
  CellReport / roofline_table — dry-run artifact schema + report emission
  sweep / SweepResult — vectorized Ridgeline over whole scenario grids
"""
from repro.core.hardware import CLX, TPU_V5E, HardwareSpec, get_hardware
from repro.core.hlo_analysis import (CollectiveSummary, StepCosts,
                                     analyze_compiled, parse_collectives)
from repro.core.report import (CellReport, dryrun_table, load_reports,
                               make_cell_report, roofline_table)
from repro.core.ridgeline import (Resource, RidgelineAnalysis, WorkUnit,
                                  analyze, analyze_multilink, ascii_plot,
                                  classify_by_quadrant, classify_by_times,
                                  region_at, svg_plot)
from repro.core import roofline, sweep
from repro.core.sweep import SweepResult

__all__ = [
    "CLX", "TPU_V5E", "HardwareSpec", "get_hardware",
    "CollectiveSummary", "StepCosts", "analyze_compiled", "parse_collectives",
    "CellReport", "dryrun_table", "load_reports", "make_cell_report",
    "roofline_table",
    "Resource", "RidgelineAnalysis", "WorkUnit", "analyze",
    "analyze_multilink", "ascii_plot", "classify_by_quadrant",
    "classify_by_times", "region_at", "svg_plot", "roofline",
    "sweep", "SweepResult",
]
