"""Hardware resource book for Ridgeline analysis.

A ``HardwareSpec`` carries the three bandwidth-like quantities the Ridgeline
model (paper §II) needs — peak compute throughput, memory bandwidth, and
network bandwidth, all *per compute entity* (chip / socket) — plus the α
(latency) terms of the α–β extension: a fixed per-execution overhead for
compute and memory, and a per-hop latency for the network, so collective
time is ``α·steps + bytes/bandwidth`` (Chan et al.) instead of
bandwidth-only.  Multi-level networks (ICI within a pod, DCI between pods)
are expressed as a dict of named network links so the multi-pod analysis can
take per-axis terms; each named link can carry its own α.

Specs come from two sources:

  * **datasheet** presets (``PRESETS`` below) — vendor peaks, the classic
    roofline inputs;
  * **calibrated** specs — achievable ceilings fitted from real timings by
    ``repro.measure.calibrate`` and persisted as JSON under
    ``artifacts/calibration/``.  ``get_hardware(name, calibrated=True)``
    resolves the calibrated twin of a datasheet preset;
    ``list_hardware()`` enumerates both.

This module stays jax- and numpy-free so the planner CLI and the sweep
engine can resolve any spec without pulling in an accelerator runtime.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Mapping, Optional


@dataclasses.dataclass(frozen=True)
class EfficiencyModel:
    """Size-dependent achievable fraction of a peak: ``eff(q)`` in (0, 1].

    The datasheet roofline prices every work unit at full PEAK; real machines
    only reach it asymptotically — a small GEMM pays its dispatch/fill
    overhead as a *reduced achievable rate*, not as a constant everyone-pays
    latency (Wang et al., time-based roofline).  This is the parametric
    saturating form the calibration fits from the sized-GEMM microbenches:

        eff(q) = eff_min + (1 - eff_min) / (1 + (f_half / q) ** p)

    a Hill curve in the work-unit quantity ``q`` (FLOPs for the compute
    ceiling): ``f_half`` is the size recovering half the headroom, ``p`` the
    sharpness, ``eff_min`` the floor as q → 0.  ``p == 1`` is exactly the
    α–β intercept model in disguise (t = q/(peak·eff) = q/peak + f_half/peak);
    ``p < 1`` gives the heavier small-size tail real kernel suites show.
    With ``p ≤ 1`` (or ``eff_min > 0``) the priced time ``q/(peak·eff(q))``
    stays monotone non-decreasing in q; ``p > 1`` with a zero floor would
    make tinier work *slower* without bound, so the calibration fit never
    selects it (``calibrate._EFF_P_RANGE``).

    The default (``f_half == 0``) is the **identity** model ``eff ≡ 1``,
    which reproduces the paper's constant-ceiling times bit-for-bit — every
    datasheet preset uses it.  ``eff`` is monotone non-decreasing in q and
    bounded in (0, 1] for q > 0 (property-tested).
    """

    f_half: float = 0.0      # quantity at half headroom; 0 => identity
    p: float = 1.0           # Hill sharpness exponent
    eff_min: float = 0.0     # efficiency floor as q -> 0

    def __post_init__(self):
        if self.f_half < 0 or self.p <= 0 or not 0.0 <= self.eff_min <= 1.0:
            raise ValueError(
                f"EfficiencyModel needs f_half >= 0, p > 0, eff_min in "
                f"[0, 1]; got {self}")

    @property
    def is_identity(self) -> bool:
        return self.f_half == 0.0

    def eff(self, quantity: float) -> float:
        """Achievable fraction of peak for a work unit of size ``quantity``.

        Scalar and pure-math (this module stays numpy-free); the vectorized
        twin lives in ``core/sweep`` and is property-tested against this.
        """
        if self.f_half <= 0.0:
            return 1.0
        q = float(quantity)
        if q <= 0.0:
            return self.eff_min
        if math.isinf(q):
            return 1.0
        try:
            ratio = (self.f_half / q) ** self.p   # -> inf for tiny q
        except OverflowError:                     # float ** raises past 1e308
            return self.eff_min
        return self.eff_min + (1.0 - self.eff_min) / (1.0 + ratio)

    def to_dict(self) -> Dict[str, float]:
        return {"f_half": self.f_half, "p": self.p, "eff_min": self.eff_min}

    @staticmethod
    def from_dict(d: Optional[Mapping]) -> "EfficiencyModel":
        """Registry JSON -> model; None/empty (pre-v3 entries) -> identity."""
        if not d:
            return EfficiencyModel()
        return EfficiencyModel(f_half=float(d.get("f_half", 0.0)),
                               p=float(d.get("p", 1.0)),
                               eff_min=float(d.get("eff_min", 0.0)))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip resource peaks used as Ridgeline balance points.

    Attributes:
      name: human-readable identifier.
      peak_flops: peak compute throughput, FLOP/s (in the dtype of interest).
      hbm_bw: main-memory bandwidth, bytes/s.
      net_bw: primary network bandwidth, bytes/s per chip (for TPU this is the
        per-link ICI bandwidth; collectives ride multiple links but the
        per-device wire-byte accounting in ``hlo_analysis`` is normalized to a
        single link so the division is consistent).
      extra_links: optional named slower links (e.g. ``{"dci": 25e9}``) for
        multi-level network analysis; keys are mesh-axis tags.
      alpha_compute: fixed launch/dispatch overhead per work-unit execution,
        seconds (the α in ``t_C = α + F/PEAK``); 0 for pure-bandwidth specs.
      alpha_memory: fixed per-execution memory-system overhead, seconds.
      alpha_network: per-hop network latency, seconds per serialized
        collective step (the α in ``t_N = α·steps + B_N/bw``).
      link_alphas: optional per-link α overrides keyed like ``extra_links``;
        a link without an entry inherits ``alpha_network``.
      model_rel_error: median |relative error| of this spec's calibration on
        whole-step validation points (0 for datasheet presets); consumers
        like the planner widen point estimates into uncertainty bands by it.
      compute_eff: size-dependent achievable-PEAK curve ``eff(F)`` — the
        effective compute ceiling of an F-FLOP work unit is
        ``peak_flops · compute_eff.eff(F)``.  Datasheet presets use the
        identity model (``eff ≡ 1``, paper-exact); calibration can fit the
        saturating form from sized-GEMM measurements.
      vmem_bytes: fast scratchpad capacity per core (VMEM for TPU), used by
        kernel block-shape planning, not by the Ridgeline itself.
      hbm_capacity_bytes: device main-memory *capacity* per chip, bytes.
        The Ridgeline bounds time; capacity bounds which candidates can run
        at all — the planner's working-set model (``launch/memory``) prunes
        meshes whose per-chip footprint exceeds it.  ``0`` means unknown
        (no constraint), which every pre-existing custom spec gets for free.
      ckpt_bw: sustained per-chip bandwidth to checkpoint storage, bytes/s.
        Each chip persists its own shard of the training state (params +
        optimizer states under the candidate's ZeRO/tp/pp/ep sharding), so
        checkpoint time is ``persisted bytes per chip / ckpt_bw`` — the
        input to the failure-aware goodput model (``repro.resilience``).
        ``0`` means unknown: goodput planning refuses rather than divides.
    """

    name: str
    peak_flops: float
    hbm_bw: float
    net_bw: float
    extra_links: Mapping[str, float] = dataclasses.field(default_factory=dict)
    alpha_compute: float = 0.0
    alpha_memory: float = 0.0
    alpha_network: float = 0.0
    link_alphas: Mapping[str, float] = dataclasses.field(default_factory=dict)
    model_rel_error: float = 0.0
    compute_eff: EfficiencyModel = EfficiencyModel()
    vmem_bytes: int = 128 * 1024 * 1024 // 8  # 16 MiB (v5e VMEM per core)
    hbm_capacity_bytes: float = 0.0           # 0 = unknown, no feasibility cut
    ckpt_bw: float = 0.0                      # 0 = unknown, no goodput model

    def effective_peak(self, flops: float) -> float:
        """The achievable compute ceiling for an ``flops``-sized unit."""
        return self.peak_flops * self.compute_eff.eff(flops)

    # ---- machine balance points (paper §II, Fig. 2) -------------------------
    @property
    def ridge_arithmetic(self) -> float:
        """y* = Peak / HBM_bw: the classic roofline ridge (FLOP/mem-byte)."""
        return self.peak_flops / self.hbm_bw

    @property
    def ridge_memory(self) -> float:
        """x* = HBM_bw / Net_bw: memory-network balance (mem-byte/net-byte)."""
        return self.hbm_bw / self.net_bw

    @property
    def ridge_network(self) -> float:
        """k* = Peak / Net_bw: compute-network balance (FLOP/net-byte).

        The hyperbola x*y = k* is the straight separation line (in log-log)
        of the upper-left quadrant (paper Fig. 2d).
        """
        return self.peak_flops / self.net_bw

    #: names that always resolve to the primary link
    PRIMARY_LINKS = (None, "ici", "net")

    def bandwidth_for(self, link: str | None = None) -> float:
        """Bandwidth of a named link; unknown names raise with the options."""
        if link in self.PRIMARY_LINKS:
            return self.net_bw
        try:
            return float(self.extra_links[link])
        except KeyError:
            raise KeyError(
                f"hardware spec {self.name!r} has no network link {link!r}; "
                f"available links: primary ('net'/'ici'/None at "
                f"{self.net_bw:.3g} B/s) plus extra_links "
                f"{sorted(self.extra_links) or '{}'}") from None

    def alpha_for(self, link: str | None = None) -> float:
        """Per-hop α of a named link (falls back to ``alpha_network``)."""
        if link not in self.PRIMARY_LINKS and link not in self.extra_links:
            self.bandwidth_for(link)           # raise the actionable KeyError
        return float(self.link_alphas.get(link, self.alpha_network))


# --- Presets -----------------------------------------------------------------

#: TPU v5e — the target deployment chip for this framework.  Constants per the
#: brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.  The multi-pod
#: ``pod`` axis rides data-center interconnect, modelled at 25 GB/s/chip.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    net_bw=50e9,
    extra_links={"pod": 25e9},
    hbm_capacity_bytes=16e9,      # 16 GB HBM per v5e chip (datasheet)
    ckpt_bw=1e9,                  # ~1 GB/s/chip sustained to blob storage
)

#: Intel Xeon Cascade Lake socket exactly as in the paper's case study (§III):
#: 4.2 TF/s FP32, 105 GB/s DRAM, 12 GB/s network per socket.
CLX = HardwareSpec(
    name="clx",
    peak_flops=4.2e12,
    hbm_bw=105e9,
    net_bw=12e9,
    vmem_bytes=36 * 1024 * 1024,  # LLC, unused in analysis
    hbm_capacity_bytes=192e9,     # 6-channel DDR4 socket, 32 GB DIMMs
    ckpt_bw=2e9,                  # local NVMe per socket
)

PRESETS: Dict[str, HardwareSpec] = {"tpu_v5e": TPU_V5E, "clx": CLX}


# --- calibration registry -----------------------------------------------------

#: JSON schema tag the calibration registry *writes* (v3: v2's α–β fit plus
#: the size-dependent ``compute_eff`` achievable-PEAK curve)
CALIBRATION_SCHEMA = "repro.calibration/v3"

#: schema tags the registry *reads*; v1 entries (bandwidth-only fit, extra
#: links scaled by the primary-NET ratio) load with all α = 0, and both v1
#: and v2 entries (which predate the efficiency model) load with ``eff ≡ 1``
CALIBRATION_SCHEMAS = ("repro.calibration/v1", "repro.calibration/v2",
                       CALIBRATION_SCHEMA)

#: suffix convention: the calibrated twin of preset ``clx`` is ``clx_cal``
CALIBRATED_SUFFIX = "_cal"


def calibration_dir(registry_dir: Optional[str] = None) -> str:
    """Where calibrated specs live: explicit arg > env > repo default.

    The default resolves relative to this source tree
    (``<repo>/artifacts/calibration``) so CLIs work from any cwd.
    """
    if registry_dir is not None:
        return registry_dir
    env = os.environ.get("REPRO_CALIBRATION_DIR")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))   # src/repro/core -> repo
    return os.path.join(root, "artifacts", "calibration")


def spec_from_calibration(d: Mapping) -> HardwareSpec:
    """Build a HardwareSpec from one calibration-registry JSON dict.

    Accepts any schema in :data:`CALIBRATION_SCHEMAS`; v1 entries predate
    the α–β fit, so their α terms default to 0 (bandwidth-only behaviour is
    preserved bit-for-bit), and v1/v2 entries predate the efficiency model,
    so ``compute_eff`` defaults to the identity curve.
    """
    schema = d.get("schema")
    if schema not in CALIBRATION_SCHEMAS:
        raise ValueError(
            f"calibration entry {d.get('name')!r} has schema {schema!r}, "
            f"expected one of {CALIBRATION_SCHEMAS}")
    validation = d.get("validation", {}) or {}
    # capacity passthrough: entries written before the field existed fall
    # back to their base preset's datasheet capacity (calibration measures
    # rates, not capacity — the committed registry never needs a rewrite)
    base = PRESETS.get(str(d.get("base", "")))
    capacity = d.get("hbm_capacity_bytes",
                     base.hbm_capacity_bytes if base is not None else 0.0)
    ckpt_bw = d.get("ckpt_bw", base.ckpt_bw if base is not None else 0.0)
    return HardwareSpec(
        name=str(d["name"]),
        peak_flops=float(d["peak_flops"]),
        hbm_bw=float(d["hbm_bw"]),
        net_bw=float(d["net_bw"]),
        extra_links={k: float(v)
                     for k, v in dict(d.get("extra_links", {})).items()},
        alpha_compute=float(d.get("alpha_compute", 0.0)),
        alpha_memory=float(d.get("alpha_memory", 0.0)),
        alpha_network=float(d.get("alpha_network", 0.0)),
        link_alphas={k: float(v)
                     for k, v in dict(d.get("link_alphas", {})).items()},
        model_rel_error=float(validation.get("median_abs_rel_error", 0.0)),
        compute_eff=EfficiencyModel.from_dict(d.get("compute_eff")),
        vmem_bytes=int(d.get("vmem_bytes", HardwareSpec.vmem_bytes)),
        hbm_capacity_bytes=float(capacity),
        ckpt_bw=float(ckpt_bw),
    )


def _read_calibration_entry(path: str) -> Optional[Dict]:
    """One registry file as a dict, or None if unreadable/off-schema."""
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(d, dict) or d.get("schema") not in CALIBRATION_SCHEMAS:
        return None
    return d


def load_calibrated(name: str,
                    registry_dir: Optional[str] = None) -> HardwareSpec:
    """Load a calibrated spec by its own name or by its base preset's name.

    Only ever raises KeyError on failure (corrupt or off-schema registry
    entries are skipped), so callers can treat the registry like a dict.
    """
    cdir = calibration_dir(registry_dir)
    candidates = [os.path.join(cdir, name + ".json"),
                  os.path.join(cdir, name + CALIBRATED_SUFFIX + ".json")]
    if os.path.isdir(cdir):
        candidates += [os.path.join(cdir, fn)
                       for fn in sorted(os.listdir(cdir))
                       if fn.endswith(".json")]
    for path in candidates:
        d = _read_calibration_entry(path) if os.path.isfile(path) else None
        if d is None:
            continue
        base = os.path.basename(path)[:-len(".json")]
        if base == name or d.get("name") == name or d.get("base") == name:
            return spec_from_calibration(d)
    calibrated = sorted(n for n, src in list_hardware(registry_dir).items()
                        if src == "calibrated")
    raise KeyError(
        f"no calibration for {name!r} under {cdir}; run "
        f"`python -m repro.measure.calibrate` first "
        f"(calibrated specs available: {calibrated or 'none'})")


def list_hardware(registry_dir: Optional[str] = None) -> Dict[str, str]:
    """All resolvable spec names -> source ('datasheet' | 'calibrated').

    A registry entry whose name shadows a datasheet preset is skipped:
    ``get_hardware`` would resolve that name to the preset, and listing it
    as calibrated would misattribute the numbers.
    """
    out = {name: "datasheet" for name in PRESETS}
    cdir = calibration_dir(registry_dir)
    if os.path.isdir(cdir):
        for fn in sorted(os.listdir(cdir)):
            if not fn.endswith(".json"):
                continue
            d = _read_calibration_entry(os.path.join(cdir, fn))
            if d is not None and "name" in d and d["name"] not in PRESETS:
                out[str(d["name"])] = "calibrated"
    return out


def get_hardware(name: str, *, calibrated: bool = False,
                 registry_dir: Optional[str] = None) -> HardwareSpec:
    """Resolve a spec by name.

    ``calibrated=True`` demands the measured twin (KeyError if never
    calibrated).  With the default ``calibrated=False``, datasheet presets
    win, but names only present in the calibration registry (e.g.
    ``clx_cal``) still resolve — so every name in :func:`list_hardware` is
    directly usable.
    """
    if calibrated:
        return load_calibrated(name, registry_dir)
    if name in PRESETS:
        return PRESETS[name]
    try:
        return load_calibrated(name, registry_dir)
    except KeyError:
        pass
    raise KeyError(f"unknown hardware spec {name!r}; "
                   f"have {sorted(list_hardware(registry_dir))}")
