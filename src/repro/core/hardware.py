"""Hardware resource book for Ridgeline analysis.

A ``HardwareSpec`` carries exactly the three bandwidth-like quantities the
Ridgeline model (paper §II) needs: peak compute throughput, memory bandwidth,
and network bandwidth — all *per compute entity* (chip / socket).  Multi-level
networks (ICI within a pod, DCI between pods) are expressed as a dict of named
network links so the multi-pod analysis can take per-axis terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip resource peaks used as Ridgeline balance points.

    Attributes:
      name: human-readable identifier.
      peak_flops: peak compute throughput, FLOP/s (in the dtype of interest).
      hbm_bw: main-memory bandwidth, bytes/s.
      net_bw: primary network bandwidth, bytes/s per chip (for TPU this is the
        per-link ICI bandwidth; collectives ride multiple links but the
        per-device wire-byte accounting in ``hlo_analysis`` is normalized to a
        single link so the division is consistent).
      extra_links: optional named slower links (e.g. ``{"dci": 25e9}``) for
        multi-level network analysis; keys are mesh-axis tags.
      vmem_bytes: fast scratchpad capacity per core (VMEM for TPU), used by
        kernel block-shape planning, not by the Ridgeline itself.
    """

    name: str
    peak_flops: float
    hbm_bw: float
    net_bw: float
    extra_links: Mapping[str, float] = dataclasses.field(default_factory=dict)
    vmem_bytes: int = 128 * 1024 * 1024 // 8  # 16 MiB (v5e VMEM per core)

    # ---- machine balance points (paper §II, Fig. 2) -------------------------
    @property
    def ridge_arithmetic(self) -> float:
        """y* = Peak / HBM_bw: the classic roofline ridge (FLOP/mem-byte)."""
        return self.peak_flops / self.hbm_bw

    @property
    def ridge_memory(self) -> float:
        """x* = HBM_bw / Net_bw: memory-network balance (mem-byte/net-byte)."""
        return self.hbm_bw / self.net_bw

    @property
    def ridge_network(self) -> float:
        """k* = Peak / Net_bw: compute-network balance (FLOP/net-byte).

        The hyperbola x*y = k* is the straight separation line (in log-log)
        of the upper-left quadrant (paper Fig. 2d).
        """
        return self.peak_flops / self.net_bw

    def bandwidth_for(self, link: str | None = None) -> float:
        if link is None or link == "ici" or link == "net":
            return self.net_bw
        return float(self.extra_links[link])


# --- Presets -----------------------------------------------------------------

#: TPU v5e — the target deployment chip for this framework.  Constants per the
#: brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.  The multi-pod
#: ``pod`` axis rides data-center interconnect, modelled at 25 GB/s/chip.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    net_bw=50e9,
    extra_links={"pod": 25e9},
)

#: Intel Xeon Cascade Lake socket exactly as in the paper's case study (§III):
#: 4.2 TF/s FP32, 105 GB/s DRAM, 12 GB/s network per socket.
CLX = HardwareSpec(
    name="clx",
    peak_flops=4.2e12,
    hbm_bw=105e9,
    net_bw=12e9,
    vmem_bytes=36 * 1024 * 1024,  # LLC, unused in analysis
)

PRESETS: Dict[str, HardwareSpec] = {"tpu_v5e": TPU_V5E, "clx": CLX}


def get_hardware(name: str) -> HardwareSpec:
    try:
        return PRESETS[name]
    except KeyError as e:
        raise KeyError(f"unknown hardware preset {name!r}; have {sorted(PRESETS)}") from e
