"""Extract Ridgeline work-unit terms (F, B_M, B_N) from compiled XLA artifacts.

``F`` and ``B_M`` come from ``compiled.cost_analysis()`` — XLA reports
``flops`` and ``bytes accessed`` for the *partitioned per-device module*
(calibrated by ``tests/test_hlo_analysis.py::test_cost_analysis_is_per_device``).

``B_N`` (network wire bytes) is NOT in cost_analysis.  We parse the optimized
HLO text of the compiled module and sum, over every collective op, the
per-device *wire bytes* — operand bytes scaled by the collective's ring
algorithm factor:

    all-reduce          2 (n-1)/n   (reduce-scatter + all-gather phases)
    all-gather            (n-1)/n   (operand is the per-device shard)
    reduce-scatter        (n-1)/n   (operand is the full per-device buffer)
    all-to-all            (n-1)/n   (each device keeps 1/n locally)
    collective-permute    1         (point-to-point)

where n is the replica-group size parsed from the op attributes.  This is the
standard alpha-beta wire-byte accounting used by collective cost models.

Shapes like ``bf16[2048,512]{1,0}`` are parsed structurally; tuple-shaped
all-reduces sum their element buffers.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Mapping, Optional, Tuple

_DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s2": 0.25, "s4": 0.5, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u2": 0.25, "u4": 0.5, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f4e2m1fn": 0.5,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

#: collective op kinds we account for, mapped to their per-device wire-byte
#: factor fn(n) *applied to the result-buffer bytes*:
#:   all-reduce: result = full buffer S, ring wire = 2 S (n-1)/n
#:   all-gather: result = gathered S, each device ships its shard to n-1 peers
#:               around the ring = S (n-1)/n
#:   reduce-scatter: result = the SHARD S/n; full buffer = n*result, wire =
#:               (n*result)(n-1)/n = result (n-1)
#:   all-to-all: result size = input size S, (n-1)/n of it crosses the wire
#:   collective-permute / broadcast: point-to-point, factor 1
_COLLECTIVE_KINDS = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n if n > 1 else 0.0,
    "all-gather": lambda n: (n - 1) / n if n > 1 else 0.0,
    "reduce-scatter": lambda n: float(n - 1) if n > 1 else 0.0,
    "all-to-all": lambda n: (n - 1) / n if n > 1 else 0.0,
    "collective-permute": lambda n: 1.0,
    "ragged-all-to-all": lambda n: (n - 1) / n if n > 1 else 0.0,
    "collective-broadcast": lambda n: 1.0,
}

_SHAPE_RE = re.compile(
    r"\b([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?"
)
# matches e.g. `bf16[4,2048,512]{2,1,0}` or `f32[]`


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_shapes(line: str, kind_start: int) -> List[Tuple[str, str]]:
    """Result shapes of an HLO instruction: ``%name = <shape> op(...)``.

    The shape(s) sit between the first ``=`` and the op name; tuple results
    list several shapes there.  ``kind_start`` is the index where the op-name
    match begins, so attribute strings (``channel_id=1``…) are never scanned.
    """
    eq = line.find("=")
    if eq < 0 or eq >= kind_start:
        return []
    return _SHAPE_RE.findall(line[eq + 1:kind_start])


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_result: float       # per-device result-buffer bytes
    group_size: int           # replica group size n
    wire_bytes: float         # bytes on the wire per device (ring factor applied)
    cross_pod_fraction: float = 0.0   # share of ring hops crossing pods
    channel: Optional[int] = None

    @property
    def cross_pod_wire_bytes(self) -> float:
        return self.wire_bytes * self.cross_pod_fraction


@dataclasses.dataclass
class CollectiveSummary:
    ops: List[CollectiveOp]

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    @property
    def cross_pod_wire_bytes(self) -> float:
        return sum(o.cross_pod_wire_bytes for o in self.ops)

    @property
    def total_buffer_bytes(self) -> float:
        return sum(o.bytes_result for o in self.ops)

    def by_kind(self) -> Dict[str, Tuple[int, float]]:
        out: Dict[str, Tuple[int, float]] = {}
        for o in self.ops:
            cnt, byt = out.get(o.kind, (0, 0.0))
            out[o.kind] = (cnt + 1, byt + o.wire_bytes)
        return out

    def pretty(self) -> str:
        rows = [f"  {k:<22} n={c:<4d} wire={b / 1e9:.4f} GB"
                for k, (c, b) in sorted(self.by_kind().items())]
        rows.append(f"  {'TOTAL':<22}        wire={self.total_wire_bytes / 1e9:.4f} GB")
        return "\n".join(rows)


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(\[[0-9,]+\])(?:T\(([0-9,]+)\))?")
_START_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=")


def _parse_groups(line: str, default_n: int):
    """Parse replica groups: returns (group_size, groups ndarray or None).

    Handles both the iota format ``replica_groups=[G,n]<=[d0,d1,..]T(perm)``
    (materialized exactly — the permuted-iota encodes which mesh axes the
    collective spans) and the explicit ``{{0,1},{2,3}}`` format.
    """
    import numpy as _np

    m = _GROUPS_IOTA_RE.search(line)
    if m:
        G, n = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).strip("[]").split(",") if d]
        total = 1
        for d in dims:
            total *= d
        ids = _np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(G, n)
        return max(1, n), groups
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        rows = re.findall(r"\{([0-9,\s]*)\}", body)
        if rows:
            parsed = [[int(t) for t in r.split(",") if t.strip()]
                      for r in rows]
            n = max((len(r) for r in parsed), default=default_n)
            width = max(len(r) for r in parsed)
            if all(len(r) == width for r in parsed):
                return max(1, n), _np.asarray(parsed)
            return max(1, n), None
    return default_n, None


def _cross_pod_fraction(groups, pod_size: int) -> float:
    """Fraction of each group's ring traffic that crosses a pod boundary.

    With groups materialized, count the ring edges (i -> i+1 within the
    group, wrap included) whose endpoints sit in different pods.
    """
    if groups is None or pod_size <= 0:
        return 0.0
    import numpy as _np

    g = _np.asarray(groups)
    if g.shape[1] < 2:
        return 0.0
    pods = g // pod_size
    nxt = _np.roll(pods, -1, axis=1)
    crossings = (pods != nxt).mean()
    return float(crossings)


def parse_collectives(hlo_text: str, num_devices: int,
                      pod_size: int = 0) -> CollectiveSummary:
    """Sum per-device collective wire bytes over an HLO module text.

    ``pod_size`` > 0 additionally attributes each op's ring traffic to
    intra-pod (ICI) vs cross-pod (DCI) hops from its materialized replica
    groups (multi-pod meshes).
    """
    ops: List[CollectiveOp] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if "=" not in line or not _START_RE.match(line):
            continue
        # op kind appears right after '= <shape>' as the instruction name
        kind, kind_start = None, -1
        for k in _COLLECTIVE_KINDS:
            # match `all-reduce(`, `all-reduce-start(`, `all-gather(` etc.
            m = re.search(rf"[\]\)\s]({re.escape(k)})(?:-start)?\(", line)
            if m:
                kind, kind_start = k, m.start(1)
                break
        if kind is None:
            continue
        if re.search(rf"{re.escape(kind)}-done\(", line):
            continue  # -done carries no new traffic; -start already counted
        shapes = _result_shapes(line, kind_start)
        if not shapes:
            continue
        if "-start(" in line:
            # async form returns a tuple aliasing operand+result (+contexts):
            # take the largest element to avoid double-counting the buffer.
            nbytes = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        else:
            # sync tuple collectives (gradient buckets) genuinely carry the
            # sum of their element buffers.
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        n, groups = _parse_groups(line, num_devices)
        factor = _COLLECTIVE_KINDS[kind](n)
        ops.append(
            CollectiveOp(kind=kind, bytes_result=nbytes, group_size=n,
                         wire_bytes=nbytes * factor,
                         cross_pod_fraction=_cross_pod_fraction(groups,
                                                                pod_size))
        )
    return CollectiveSummary(ops=ops)


#: direct param convert:      %x = f32[...] convert(%param.N)
#: loop-hoisted wrapped form: %x = f32[...] fusion(%param.N), ...,
#:                                 calls=%wrapped_convert_computation.K
_PARAM_CONVERT_RE = re.compile(
    r"%(\S+) = f32\[([0-9,]+)\]\S*\s+"
    r"(?:convert\(%param[.\d]*\)"
    r"|fusion\(%param[.\d]*\),[^\n]*calls=%wrapped_convert)")


def float_normalization_overhead(hlo_text: str,
                                 min_bytes: int = 32 * 1024 * 1024) -> float:
    """Bytes of bf16->f32 PARAMETER upcasts XLA:CPU materializes.

    The CPU backend's float-normalization pass rewrites bf16 compute to f32.
    For module *parameters* (weights, KV caches) this materializes a
    whole-buffer f32 copy at entry that is then carried through the layer
    loop — purely a CPU-backend artifact: on the TPU target these buffers
    stay bf16 end-to-end.  In-graph f32 converts of computed values (the
    fp32 softmax scores etc.) are legitimate on TPU too and are NOT counted.

    The TPU-corrected peak-memory estimate subtracts half of the sum (the
    f32-vs-bf16 delta).
    """
    seen = {}
    for m in _PARAM_CONVERT_RE.finditer(hlo_text):
        name, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        nbytes = n * 4
        if nbytes >= min_bytes:
            seen[name] = nbytes
    return float(sum(seen.values()))


@dataclasses.dataclass
class StepCosts:
    """Per-device costs of one compiled step, ready for Ridgeline analysis."""

    flops: float                     # per-device HLO flops
    mem_bytes: float                 # per-device HLO bytes accessed
    wire_bytes: float                # per-device collective wire bytes
    collectives: CollectiveSummary
    peak_memory_per_device: float    # from memory_analysis, bytes
    num_devices: int
    # raw blobs for the record
    cost_raw: Mapping[str, float] = dataclasses.field(default_factory=dict)
    float_norm_overhead: float = 0.0  # CPU-backend bf16->f32 inflation, bytes

    @property
    def total_flops(self) -> float:
        return self.flops * self.num_devices


def _extract_cost(cost: Mapping[str, float]) -> Tuple[float, float]:
    flops = float(cost.get("flops", 0.0))
    # XLA reports "bytes accessed" under this key
    mem = float(cost.get("bytes accessed", 0.0))
    if mem == 0.0:
        # fall back: sum operand/result byte keys if aggregate missing
        mem = sum(v for k, v in cost.items()
                  if k.startswith("bytes accessed"))
    return flops, mem


def _memory_stats(mem_analysis) -> float:
    """Peak per-device bytes: args + temps + outputs − donated aliases.

    ``alias_size_in_bytes`` is the portion of outputs that share a buffer
    with donated arguments (the decode cache) — counting it in both args
    and outputs would double it.
    """
    if not hasattr(mem_analysis, "temp_size_in_bytes"):
        return 0.0
    try:
        total = (
            getattr(mem_analysis, "temp_size_in_bytes", 0)
            + getattr(mem_analysis, "argument_size_in_bytes", 0)
            + getattr(mem_analysis, "output_size_in_bytes", 0)
            + getattr(mem_analysis, "generated_code_size_in_bytes", 0)
            - getattr(mem_analysis, "alias_size_in_bytes", 0)
        )
        return float(total)
    except Exception:  # pragma: no cover
        return 0.0


def cost_analysis_dict(compiled) -> Dict:
    """``compiled.cost_analysis()`` across jax versions (0.4.x: [dict])."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_compiled(compiled, num_devices: int,
                     pod_size: int = 0) -> StepCosts:
    """Build StepCosts from a ``jax.stages.Compiled`` object."""
    cost = cost_analysis_dict(compiled)
    flops, mem = _extract_cost(cost)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, num_devices, pod_size=pod_size)
    try:
        peak = _memory_stats(compiled.memory_analysis())
    except Exception:
        peak = 0.0
    return StepCosts(
        flops=flops,
        mem_bytes=mem,
        wire_bytes=coll.total_wire_bytes,
        collectives=coll,
        peak_memory_per_device=peak,
        float_norm_overhead=float_normalization_overhead(hlo),
        num_devices=num_devices,
        cost_raw={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
    )
