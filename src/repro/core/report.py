"""Ridgeline reports: the per-cell artifact schema and markdown emitters.

A *cell* = (architecture, input shape, mesh).  ``launch/dryrun.py`` produces
one ``CellReport`` JSON per cell; everything in EXPERIMENTS.md §Dry-run,
§Roofline and §Perf is generated from these artifacts via
``benchmarks/arch_table.py`` so the numbers in the doc are reproducible.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import HardwareSpec, get_hardware
from repro.core.hlo_analysis import StepCosts
from repro.core.ridgeline import RidgelineAnalysis, WorkUnit, analyze


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str                      # train_4k / prefill_32k / decode_32k / long_500k
    mesh: str                       # "16x16" | "2x16x16"
    step_kind: str                  # train_step | serve_step
    num_devices: int
    hardware: str
    # per-device terms
    flops: float
    mem_bytes: float
    wire_bytes: float
    wire_bytes_by_kind: Dict[str, float]
    peak_memory_per_device: float
    # model-level accounting
    model_flops: float              # 6*N*D (dense) or 6*N_active*D (MoE), total
    params_total: float
    params_active: float
    tokens_per_step: float
    # derived (filled by finalize)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_network: float = 0.0
    bottleneck: str = ""
    runtime: float = 0.0
    peak_fraction: float = 0.0
    useful_flops_ratio: float = 0.0   # MODEL_FLOPS / (per-dev flops * devices)
    i_arithmetic: float = 0.0
    i_memory: float = 0.0
    i_network: float = 0.0
    notes: str = ""
    variant: str = "baseline"       # baseline | <optimization tag>
    wall_compile_s: float = 0.0
    #: TPU-corrected peak memory: raw minus half the CPU backend's bf16->f32
    #: upcast buffers (float-normalization artifact; see hlo_analysis)
    peak_memory_corrected: float = 0.0
    # ---- empirical overlay (repro.measure): 0/"" until a clock has run ------
    measured_runtime: float = 0.0     # wall seconds of the real step; the
                                      # statistic (best/median) is named in
                                      # measured_source
    measured_rel_error: float = 0.0   # (model runtime − measured) / measured
    measured_source: str = ""         # e.g. "calibrate:clx_cal@cpu/best"

    def finalize(self, hw: HardwareSpec) -> "CellReport":
        wu = WorkUnit(f"{self.arch}/{self.shape}", self.flops, self.mem_bytes,
                      self.wire_bytes)
        a = analyze(wu, hw)
        self.t_compute, self.t_memory, self.t_network = (
            a.t_compute, a.t_memory, a.t_network)
        self.bottleneck = a.bottleneck.value
        self.runtime = a.runtime
        self.peak_fraction = a.peak_fraction
        self.i_arithmetic = a.y
        self.i_memory = a.x
        self.i_network = wu.network_intensity
        total_hlo = self.flops * self.num_devices
        self.useful_flops_ratio = (
            self.model_flops / total_hlo if total_hlo else 0.0)
        return self

    def analysis(self, hw: Optional[HardwareSpec] = None) -> RidgelineAnalysis:
        hw = hw or get_hardware(self.hardware)
        return analyze(
            WorkUnit(f"{self.arch}/{self.shape}@{self.mesh}",
                     self.flops, self.mem_bytes, self.wire_bytes), hw)

    # ---- persistence ---------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "CellReport":
        d = json.loads(text)
        known = {f.name for f in dataclasses.fields(CellReport)}
        return CellReport(**{k: v for k, v in d.items() if k in known})

    def save(self, directory: str) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(
            directory, f"{self.arch}__{self.shape}__{self.mesh}__{self.variant}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_json())
        os.replace(tmp, path)
        return path


def load_reports(directory: str) -> List[CellReport]:
    out: List[CellReport] = []
    if not os.path.isdir(directory):
        return out
    for fn in sorted(os.listdir(directory)):
        if fn.endswith(".json"):
            with open(os.path.join(directory, fn)) as f:
                out.append(CellReport.from_json(f.read()))
    return out


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.3f}s"


def make_cell_report(
    *, arch: str, shape: str, mesh: str, step_kind: str,
    costs: StepCosts, hw: HardwareSpec, model_flops: float,
    params_total: float, params_active: float, tokens_per_step: float,
    variant: str = "baseline", notes: str = "", wall_compile_s: float = 0.0,
) -> CellReport:
    rep = CellReport(
        arch=arch, shape=shape, mesh=mesh, step_kind=step_kind,
        num_devices=costs.num_devices, hardware=hw.name,
        flops=costs.flops, mem_bytes=costs.mem_bytes,
        wire_bytes=costs.wire_bytes,
        wire_bytes_by_kind={k: b for k, (c, b) in costs.collectives.by_kind().items()},
        peak_memory_per_device=costs.peak_memory_per_device,
        peak_memory_corrected=max(
            0.0, costs.peak_memory_per_device - costs.float_norm_overhead / 2),
        model_flops=model_flops, params_total=params_total,
        params_active=params_active, tokens_per_step=tokens_per_step,
        variant=variant, notes=notes, wall_compile_s=wall_compile_s,
    )
    return rep.finalize(hw)


ROOFLINE_HEADER = (
    "| arch | shape | mesh | step | t_compute | t_memory | t_network | "
    "bottleneck | bound runtime | peak frac | useful/HLO | bytes/dev | notes |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|---|---|"
)


def roofline_row(r: CellReport) -> str:
    return (
        f"| {r.arch} | {r.shape} | {r.mesh} | {r.step_kind} | "
        f"{_fmt_s(r.t_compute)} | {_fmt_s(r.t_memory)} | {_fmt_s(r.t_network)} | "
        f"**{r.bottleneck}** | {_fmt_s(r.runtime)} | {100 * r.peak_fraction:.1f}% | "
        f"{r.useful_flops_ratio:.2f} | "
        f"{(r.peak_memory_corrected or r.peak_memory_per_device) / 2**30:.2f} GiB | "
        f"{r.notes} |"
    )


def roofline_table(reports: Sequence[CellReport]) -> str:
    rows = [ROOFLINE_HEADER]
    rows += [roofline_row(r) for r in reports]
    return "\n".join(rows)


def dryrun_table(reports: Sequence[CellReport]) -> str:
    head = (
        "| arch | shape | mesh | devices | HLO GFLOPs/dev | HBM GB/dev | "
        "wire GB/dev | peak mem GiB/dev | collectives |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    rows = [head]
    for r in reports:
        kinds = ", ".join(
            f"{k}:{v / 2**30:.2f}GiB" for k, v in sorted(r.wire_bytes_by_kind.items()))
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.num_devices} | "
            f"{r.flops / 1e9:.1f} | {r.mem_bytes / 1e9:.2f} | "
            f"{r.wire_bytes / 1e9:.3f} | {r.peak_memory_per_device / 2**30:.2f} | "
            f"{kinds or '-'} |")
    return "\n".join(rows)
