"""The Ridgeline model (paper §II) — the core contribution, reimplemented.

Given a *work unit* characterized by

    F    FLOPs
    B_M  memory bytes accessed
    B_N  bytes moved over the network

and a machine (``HardwareSpec``), the Ridgeline places the work unit on the
plane (x = I_M = B_M/B_N, y = I_A = F/B_M) and classifies its bottleneck by
the quadrant/hyperbola construction of Fig. 2:

  * x < x*, y < y*            -> NETWORK   (lower-left)
  * x > x*, y < y*            -> MEMORY    (lower-right)
  * x > x*, y > y*            -> COMPUTE   (upper-right)
  * x < x*, y > y*            -> x·y ≶ k*: NETWORK if below, COMPUTE if above

with x* = HBM/NET, y* = PEAK/HBM, k* = PEAK/NET.  The classification is
*provably equivalent* to the argmax of the three resource times

    t_C = F / PEAK,  t_M = B_M / HBM,  t_N = B_N / NET

(see ``tests/test_ridgeline.py`` for the hypothesis property test), and the
projected runtime at the bound is ``max(t_C, t_M, t_N)`` (paper §III: divide
the dominant traffic by its bandwidth).

**α–β extension.**  Real collectives pay a per-hop latency on top of the
bandwidth term (Chan et al.), and real kernels pay a dispatch overhead, so
the resource times here are

    t_C = α_C + F / (PEAK · eff(F))   (α_C only when F > 0)
    t_M = α_M + B_M / HBM             (α_M only when B_M > 0)
    t_N = α_N · steps + B_N / NET

with the α's coming from :class:`~repro.core.hardware.HardwareSpec` and
``steps`` (serialized network hops) from :class:`WorkUnit.net_steps`.

**Size-dependent ceiling.**  ``eff(F)`` is the spec's
:class:`~repro.core.hardware.EfficiencyModel` achievable-PEAK curve: small
work units never reach datasheet PEAK (a 256³ GEMM runs at a third of what
a 2048³ GEMM sustains), so the effective compute ceiling saturates with
size instead of being a constant (Wang et al., time-based roofline).

Every datasheet preset has α = 0 and the identity ``eff ≡ 1``, which
recovers the paper's bandwidth-only model exactly — including the
quadrant/argmax equivalence theorem, which holds in that regime.  With
nonzero α (or a non-identity efficiency curve) the *classification* is the
argmax of the α-aware times (the physical definition); the plane placement
is unchanged.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core.hardware import HardwareSpec


class Resource(enum.Enum):
    COMPUTE = "compute"
    MEMORY = "memory"
    NETWORK = "network"


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """The three Ridgeline characteristics of a kernel / step / program.

    Quantities are totals over one execution of the unit *per compute entity*
    (per chip), matching the per-chip bandwidths in ``HardwareSpec``.  Using
    aggregate cluster totals with aggregate bandwidths gives identical
    intensities (the model is scale-free) — we standardize on per-chip.
    """

    name: str
    flops: float          # F
    mem_bytes: float      # B_M
    net_bytes: float      # B_N  (wire bytes per chip; 0 for single-chip work)
    net_steps: float = 0.0  # serialized network hops (the α multiplier);
    #                         0 keeps the bandwidth-only network time

    def __post_init__(self):
        if self.flops < 0 or self.mem_bytes < 0 or self.net_bytes < 0 \
                or self.net_steps < 0:
            raise ValueError(f"negative resource count in {self}")

    # ---- intensities (paper Table I) ----------------------------------------
    @property
    def arithmetic_intensity(self) -> float:
        """I_A = F / B_M (FLOP per memory byte) — the y axis."""
        return _safe_div(self.flops, self.mem_bytes)

    @property
    def memory_intensity(self) -> float:
        """I_M = B_M / B_N (memory byte per network byte) — the x axis."""
        return _safe_div(self.mem_bytes, self.net_bytes)

    @property
    def network_intensity(self) -> float:
        """I_N = F / B_N = I_A · I_M (FLOP per network byte)."""
        return _safe_div(self.flops, self.net_bytes)


@dataclasses.dataclass(frozen=True)
class RidgelineAnalysis:
    """Full placement of one WorkUnit on one machine."""

    work: WorkUnit
    hw: HardwareSpec
    # resource times, seconds
    t_compute: float
    t_memory: float
    t_network: float
    bottleneck: Resource
    # roofline-style attainable performance
    runtime: float                   # max of the three times (projected bound)
    attained_flops: float            # F / runtime
    peak_fraction: float             # attained / peak == t_compute / runtime
    # plane coordinates
    x: float                         # I_M
    y: float                         # I_A

    def resource_times(self) -> Dict[Resource, float]:
        return {
            Resource.COMPUTE: self.t_compute,
            Resource.MEMORY: self.t_memory,
            Resource.NETWORK: self.t_network,
        }

    def summary(self) -> str:
        return (
            f"{self.work.name}: I_A={self.y:.3g} I_M={self.x:.3g} "
            f"I_N={self.work.network_intensity:.3g} | "
            f"t_C={self.t_compute:.3e}s t_M={self.t_memory:.3e}s "
            f"t_N={self.t_network:.3e}s -> {self.bottleneck.value.upper()} "
            f"bound, {100 * self.peak_fraction:.1f}% of peak"
        )


def _safe_div(a: float, b: float) -> float:
    if b == 0:
        return math.inf if a > 0 else 0.0
    return a / b


def classify_by_quadrant(work: WorkUnit, hw: HardwareSpec) -> Resource:
    """Bottleneck via the paper's 2D plane construction (Fig. 2c/2e).

    Kept literally quadrant-based (not argmax-based) so that the equivalence
    with :func:`classify_by_times` is a *checked theorem*, not a tautology.
    Boundary convention: ties go COMPUTE > MEMORY > NETWORK (a point exactly
    on a ridge attains peak for both resources; we report the "better" one).
    """
    if work.flops == 0 and work.mem_bytes == 0 and work.net_bytes == 0:
        return Resource.COMPUTE  # degenerate empty unit; matches argmax tie-break
    x, y = work.memory_intensity, work.arithmetic_intensity
    x_star, y_star = hw.ridge_memory, hw.ridge_arithmetic
    if x >= x_star and y >= y_star:
        return Resource.COMPUTE
    if x >= x_star and y < y_star:
        return Resource.MEMORY
    if x < x_star and y < y_star:
        return Resource.NETWORK
    # upper-left: compare the hyperbola x*y against k* (paper Fig. 2d)
    xy = work.network_intensity  # == x * y, but exact when B_M cancels
    return Resource.COMPUTE if xy >= hw.ridge_network else Resource.NETWORK


def resource_times(work: WorkUnit, hw: HardwareSpec,
                   link: Optional[str] = None
                   ) -> Tuple[float, float, float]:
    """The α-aware (t_C, t_M, t_N); α's of 0 give the paper's pure-β times.

    This is the single scalar definition of the time model — the
    calibration fit prices its measurements through it, and the vectorized
    twin in ``core/sweep`` is property-tested against it.  ``link`` names
    the network link the wire bytes rode (None = primary): its bandwidth
    and per-hop α come from ``hw.bandwidth_for``/``hw.alpha_for``.  The
    compute ceiling is size-dependent, ``PEAK · eff(F)``
    (``hw.compute_eff``); the identity curve multiplies by exactly 1.0, so
    specs without a fitted efficiency model reproduce the constant-ceiling
    times bit-for-bit.
    """
    t_c = (hw.alpha_compute if work.flops > 0 else 0.0) + \
        _safe_div(work.flops,
                  hw.peak_flops * hw.compute_eff.eff(work.flops))
    t_m = (hw.alpha_memory if work.mem_bytes > 0 else 0.0) + \
        _safe_div(work.mem_bytes, hw.hbm_bw)
    t_n = hw.alpha_for(link) * work.net_steps + \
        _safe_div(work.net_bytes, hw.bandwidth_for(link))
    return t_c, t_m, t_n


def _classify_times(t_c: float, t_m: float, t_n: float) -> Resource:
    """Argmax of three precomputed times, COMPUTE > MEMORY > NETWORK ties.

    Branch-only (no dict/list/Enum construction per call): ``analyze`` sits
    on the planner/calibration hot path, and building the times mapping and
    priority list per classification dominated its profile.
    """
    if t_c >= t_m:
        return Resource.COMPUTE if t_c >= t_n else Resource.NETWORK
    return Resource.MEMORY if t_m >= t_n else Resource.NETWORK


def classify_by_times(work: WorkUnit, hw: HardwareSpec) -> Resource:
    """Bottleneck as argmax of the α-aware times (the physical definition).

    Equals :func:`classify_by_quadrant` whenever the spec's α terms are zero
    (the checked theorem); with α > 0 this is the ground truth and the
    quadrant construction remains the bandwidth-only plane picture.
    """
    return _classify_times(*resource_times(work, hw))


def analyze(work: WorkUnit, hw: HardwareSpec) -> RidgelineAnalysis:
    # one resource_times computation feeds times, bound, and classification
    t_c, t_m, t_n = resource_times(work, hw)
    runtime = max(t_c, t_m, t_n)
    attained = _safe_div(work.flops, runtime) if runtime > 0 else 0.0
    return RidgelineAnalysis(
        work=work,
        hw=hw,
        t_compute=t_c,
        t_memory=t_m,
        t_network=t_n,
        bottleneck=_classify_times(t_c, t_m, t_n),
        runtime=runtime,
        attained_flops=attained,
        peak_fraction=_safe_div(attained, hw.peak_flops),
        x=work.memory_intensity,
        y=work.arithmetic_intensity,
    )


def analyze_multilink(
    work_per_link: Mapping[str, WorkUnit], hw: HardwareSpec
) -> RidgelineAnalysis:
    """Beyond-paper: Ridgeline with a multi-level network.

    ``work_per_link`` maps link tag -> WorkUnit whose ``net_bytes`` (and
    ``net_steps``) are the wire traffic on that link (flops/mem_bytes
    identical across entries).  Each link's time is α–β priced with *its
    own* bandwidth and per-hop α; the effective network time is the max over
    links, folded back into a single equivalent WorkUnit by scaling B_N to
    primary-link units so the 2D plane still applies (the plane is defined
    up to the choice of network).
    """
    if not work_per_link:
        raise ValueError("need at least one link")
    items = list(work_per_link.items())
    base = items[0][1]
    t_net = 0.0
    for tag, w in items:
        bw = hw.bandwidth_for(tag)
        t_link = hw.alpha_for(tag) * w.net_steps + _safe_div(w.net_bytes, bw)
        t_net = max(t_net, t_link)
    eff_net_bytes = t_net * hw.net_bw  # primary-link-equivalent bytes
    # steps fold into the equivalent bytes, so the folded unit carries none
    eff = WorkUnit(base.name, base.flops, base.mem_bytes, eff_net_bytes)
    return analyze(eff, hw)


# --- Region geometry for plotting -------------------------------------------

def region_at(x: float, y: float, hw: HardwareSpec) -> Resource:
    """Region of an arbitrary plane point (used by plotting/tests)."""
    return classify_by_quadrant(WorkUnit("pt", x * y, x, 1.0), hw)
    # note: B_N=1, B_M=x, F=x*y reproduces coordinates (x, y) exactly.


def ascii_plot(
    analyses: Sequence[RidgelineAnalysis],
    hw: HardwareSpec,
    width: int = 72,
    height: int = 24,
    x_range: Optional[Tuple[float, float]] = None,
    y_range: Optional[Tuple[float, float]] = None,
    point_notes: Optional[Mapping[str, str]] = None,
) -> str:
    """Log-log ASCII Ridgeline plot: region letters + labelled points.

    Regions: ``.`` network, ``-`` memory, ``+`` compute. Points: digits
    indexing into ``analyses`` (shown in the legend).  ``point_notes`` maps
    a work-unit name to an annotation appended to its legend line — the
    measured-overlay path uses it for wall times and model error.
    """
    point_notes = point_notes or {}
    finite = [a for a in analyses if math.isfinite(a.x) and math.isfinite(a.y)
              and a.x > 0 and a.y > 0]
    xs = [a.x for a in finite] + [hw.ridge_memory]
    ys = [a.y for a in finite] + [hw.ridge_arithmetic]
    if x_range is None:
        x_range = (min(xs) / 8, max(xs) * 8)
    if y_range is None:
        y_range = (min(ys) / 8, max(ys) * 8)
    lx0, lx1 = math.log10(x_range[0]), math.log10(x_range[1])
    ly0, ly1 = math.log10(y_range[0]), math.log10(y_range[1])

    def to_col(x: float) -> int:
        return int(round((math.log10(x) - lx0) / (lx1 - lx0) * (width - 1)))

    def to_row(y: float) -> int:
        return (height - 1) - int(
            round((math.log10(y) - ly0) / (ly1 - ly0) * (height - 1))
        )

    glyph = {Resource.NETWORK: ".", Resource.MEMORY: "-", Resource.COMPUTE: "+"}
    grid = []
    for r in range(height):
        ly = ly1 - (ly1 - ly0) * r / (height - 1)
        row = []
        for c in range(width):
            lx = lx0 + (lx1 - lx0) * c / (width - 1)
            row.append(glyph[region_at(10 ** lx, 10 ** ly, hw)])
        grid.append(row)

    # ridge crosshair
    xc, yr = to_col(hw.ridge_memory), to_row(hw.ridge_arithmetic)
    for r in range(height):
        if 0 <= xc < width:
            grid[r][xc] = "|"
    for c in range(width):
        if 0 <= yr < height:
            grid[yr][c] = "="
    if 0 <= yr < height and 0 <= xc < width:
        grid[yr][xc] = "*"

    legend = []
    for i, a in enumerate(finite):
        ch = str(i % 10) if i < 10 else chr(ord("a") + (i - 10) % 26)
        r, c = to_row(a.y), to_col(a.x)
        if 0 <= r < height and 0 <= c < width:
            grid[r][c] = ch
        note = point_notes.get(a.work.name)
        legend.append(
            f"  [{ch}] {a.work.name}: ({a.x:.3g}, {a.y:.3g}) -> "
            f"{a.bottleneck.value}" + (f" | {note}" if note else "")
        )

    header = (
        f"Ridgeline plane for {hw.name} "
        f"(x*={hw.ridge_memory:.3g} mem-B/net-B, "
        f"y*={hw.ridge_arithmetic:.3g} FLOP/mem-B, "
        f"k*={hw.ridge_network:.3g} FLOP/net-B)\n"
        f"regions: '.'=network  '-'=memory  '+'=compute; "
        f"x: I_M=B_M/B_N (log), y: I_A=F/B_M (log)\n"
    )
    body = "\n".join("".join(row) for row in grid)
    return header + body + "\n" + "\n".join(legend)


def svg_plot(
    analyses: Sequence[RidgelineAnalysis],
    hw: HardwareSpec,
    width: int = 640,
    height: int = 480,
    point_notes: Optional[Mapping[str, str]] = None,
) -> str:
    """Self-contained SVG Ridgeline plot (no plotting deps available).

    Points named in ``point_notes`` render as hollow "measured" markers with
    the note under the label (used for model-vs-measured overlays).
    """
    point_notes = point_notes or {}
    finite = [a for a in analyses if a.x > 0 and a.y > 0
              and math.isfinite(a.x) and math.isfinite(a.y)]
    xs = [a.x for a in finite] + [hw.ridge_memory]
    ys = [a.y for a in finite] + [hw.ridge_arithmetic]
    lx0, lx1 = math.log10(min(xs) / 10), math.log10(max(xs) * 10)
    ly0, ly1 = math.log10(min(ys) / 10), math.log10(max(ys) * 10)
    m = 50  # margin

    def px(x):
        return m + (math.log10(x) - lx0) / (lx1 - lx0) * (width - 2 * m)

    def py(y):
        return height - m - (math.log10(y) - ly0) / (ly1 - ly0) * (height - 2 * m)

    parts = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" '
        f'height="{height}" viewBox="0 0 {width} {height}">',
        f'<rect width="{width}" height="{height}" fill="white"/>',
    ]
    # region shading via coarse raster
    cols, rows = 64, 48
    fill = {Resource.NETWORK: "#fde0dd", Resource.MEMORY: "#e0ecf4",
            Resource.COMPUTE: "#e5f5e0"}
    cw, ch = (width - 2 * m) / cols, (height - 2 * m) / rows
    for i in range(cols):
        for j in range(rows):
            lx = lx0 + (lx1 - lx0) * (i + 0.5) / cols
            ly = ly0 + (ly1 - ly0) * (j + 0.5) / rows
            reg = region_at(10 ** lx, 10 ** ly, hw)
            x0 = m + i * cw
            y0 = height - m - (j + 1) * ch
            parts.append(
                f'<rect x="{x0:.1f}" y="{y0:.1f}" width="{cw + 0.5:.1f}" '
                f'height="{ch + 0.5:.1f}" fill="{fill[reg]}"/>'
            )
    # ridges
    parts.append(
        f'<line x1="{px(hw.ridge_memory):.1f}" y1="{m}" '
        f'x2="{px(hw.ridge_memory):.1f}" y2="{height - m}" '
        'stroke="#d62728" stroke-dasharray="4"/>'
    )
    parts.append(
        f'<line x1="{m}" y1="{py(hw.ridge_arithmetic):.1f}" '
        f'x2="{width - m}" y2="{py(hw.ridge_arithmetic):.1f}" '
        'stroke="#1f77b4" stroke-dasharray="4"/>'
    )
    # hyperbola x*y = k* (straight in log space)
    hx0, hx1 = 10 ** lx0, 10 ** lx1
    pts = []
    for i in range(65):
        x = 10 ** (lx0 + (lx1 - lx0) * i / 64)
        y = hw.ridge_network / x
        if 10 ** ly0 <= y <= 10 ** ly1:
            pts.append(f"{px(x):.1f},{py(y):.1f}")
    if pts:
        parts.append(
            f'<polyline points="{" ".join(pts)}" fill="none" '
            'stroke="#2ca02c" stroke-dasharray="2"/>'
        )
    for a in finite:
        note = point_notes.get(a.work.name)
        if note is None:
            parts.append(
                f'<circle cx="{px(a.x):.1f}" cy="{py(a.y):.1f}" r="4" '
                'fill="#333"/>')
        else:
            parts.append(
                f'<circle cx="{px(a.x):.1f}" cy="{py(a.y):.1f}" r="5" '
                'fill="none" stroke="#d62728" stroke-width="2" '
                'class="measured"/>')
        parts.append(
            f'<text x="{px(a.x) + 6:.1f}" y="{py(a.y) - 6:.1f}" '
            f'font-size="10" font-family="monospace">{a.work.name}</text>'
        )
        if note:
            parts.append(
                f'<text x="{px(a.x) + 6:.1f}" y="{py(a.y) + 6:.1f}" '
                f'font-size="9" font-family="monospace" '
                f'fill="#d62728">{note}</text>')
    parts.append(
        f'<text x="{width / 2:.0f}" y="{height - 12}" font-size="12" '
        'text-anchor="middle" font-family="monospace">'
        "I_M = B_M / B_N (log)</text>"
        f'<text x="14" y="{height / 2:.0f}" font-size="12" '
        'text-anchor="middle" font-family="monospace" '
        f'transform="rotate(-90 14 {height / 2:.0f})">I_A = F / B_M (log)</text>'
    )
    parts.append("</svg>")
    return "\n".join(parts)
