"""Classic (single-node) Roofline model [Williams et al., CACM'09].

Kept as a separate module both because the paper builds on it (§I) and
because the Ridgeline reduces to it when B_N -> 0.  Includes the
"memory-network roofline" variant the paper introduces in Fig. 2b.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.hardware import HardwareSpec


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    name: str
    intensity: float          # FLOP / byte
    attainable_flops: float   # min(peak, intensity * bw)
    bound: str                # "compute" | "memory"


def attainable(intensity: float, hw: HardwareSpec) -> float:
    """Attainable FLOP/s at the given arithmetic intensity."""
    return min(hw.peak_flops, intensity * hw.hbm_bw)


def classify(intensity: float, hw: HardwareSpec) -> str:
    return "compute" if intensity >= hw.ridge_arithmetic else "memory"


def point(name: str, flops: float, mem_bytes: float, hw: HardwareSpec) -> RooflinePoint:
    i = flops / mem_bytes if mem_bytes else float("inf")
    return RooflinePoint(name, i, attainable(i, hw), classify(i, hw))


def memory_network_attainable(mem_intensity: float, hw: HardwareSpec) -> float:
    """Paper Fig. 2b: attainable *memory bandwidth* vs I_M = B_M/B_N.

    For low memory intensity the achievable memory throughput is limited by
    the network feeding it (I_M * net_bw); it saturates at hbm_bw.
    """
    return min(hw.hbm_bw, mem_intensity * hw.net_bw)


def memory_network_classify(mem_intensity: float, hw: HardwareSpec) -> str:
    return "memory" if mem_intensity >= hw.ridge_memory else "network"


def sweep(intensities: Sequence[float], hw: HardwareSpec) -> List[Tuple[float, float]]:
    return [(i, attainable(i, hw)) for i in intensities]
