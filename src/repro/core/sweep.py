"""Vectorized Ridgeline sweeps: whole scenario grids in one NumPy pass.

The scalar model (``core/ridgeline``) places one WorkUnit at a time; the
paper's case study and the parallelism planner both need *surfaces* —
bottleneck maps and projected-runtime grids over
(batch × mesh × strategy × hardware × collective algorithm).  This module
evaluates those grids with broadcast arithmetic instead of Python loops:
every input of :func:`sweep` broadcasts against every other, so a
``(n_batch, 1)`` flops column against a ``(1, n_mesh)`` net-bytes row yields
the full 2-D map directly.

Classification is the argmax of the three resource times with the same
COMPUTE > MEMORY > NETWORK tie-break as the scalar path —
``tests/test_sweep.py`` property-checks elementwise agreement with
``repro.core.ridgeline.analyze``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.contracts import shape_contract
from repro.core.hardware import EfficiencyModel, HardwareSpec, get_hardware
from repro.core.ridgeline import Resource
from repro.obs import trace

ArrayLike = Union[float, np.ndarray]
HardwareLike = Union[HardwareSpec, str]

#: code order == argmax priority order (ties resolve to the earlier entry),
#: matching the scalar classifier's COMPUTE > MEMORY > NETWORK convention
RESOURCE_ORDER: Tuple[Resource, ...] = (
    Resource.COMPUTE, Resource.MEMORY, Resource.NETWORK)
RESOURCE_CODES: Dict[Resource, int] = {r: i for i, r in
                                       enumerate(RESOURCE_ORDER)}
_LABELS = np.array([r.value for r in RESOURCE_ORDER])


def _safe_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Vectorized twin of ridgeline._safe_div: x/0 -> inf (x>0) else 0."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    a, b = np.broadcast_arrays(a, b)
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(b != 0, a / np.where(b != 0, b, 1.0),
                       np.where(a > 0, np.inf, 0.0))
    return out


@shape_contract("q:(*g) -> (*g)")
def eff_grid(model: Optional[EfficiencyModel], q: ArrayLike):
    """Vectorized twin of ``EfficiencyModel.eff`` (property-tested against
    the scalar): achievable-fraction-of-peak on a grid of work sizes.

    Returns the scalar 1.0 for the identity model so the caller's
    ``peak * eff`` stays bit-exact with the constant-ceiling model.
    """
    if model is None or model.is_identity:
        return 1.0
    q = np.asarray(q, dtype=np.float64)
    with np.errstate(divide="ignore", over="ignore", invalid="ignore"):
        ratio = np.where(q > 0,
                         (model.f_half / np.where(q > 0, q, 1.0)) ** model.p,
                         np.inf)            # q <= 0 -> the eff_min floor
    return model.eff_min + (1.0 - model.eff_min) / (1.0 + ratio)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Every Ridgeline quantity, on the full broadcast grid."""

    flops: np.ndarray
    mem_bytes: np.ndarray
    net_bytes: np.ndarray
    t_compute: np.ndarray
    t_memory: np.ndarray
    t_network: np.ndarray
    runtime: np.ndarray              # max of the three times (projected bound)
    bottleneck: np.ndarray           # int8 codes into RESOURCE_ORDER
    attained_flops: np.ndarray
    peak_fraction: np.ndarray
    x: np.ndarray                    # I_M = B_M / B_N
    y: np.ndarray                    # I_A = F / B_M

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.runtime.shape

    def labels(self) -> np.ndarray:
        """Bottleneck names ('compute'|'memory'|'network') on the grid."""
        return _LABELS[self.bottleneck]

    def resources(self) -> np.ndarray:
        """Bottlenecks as Resource enums (object array on the grid)."""
        return np.array(RESOURCE_ORDER, dtype=object)[self.bottleneck]

    def region_counts(self) -> Dict[str, int]:
        lab, cnt = np.unique(self.bottleneck, return_counts=True)
        return {RESOURCE_ORDER[int(l)].value: int(c)
                for l, c in zip(lab, cnt)}


@shape_contract(
    "flops:(*g), mem_bytes:(*g), net_bytes:(*g), net_steps:(*g), "
    "peak_flops:(*g), hbm_bw:(*g), net_bw:(*g), alpha_compute:(*g), "
    "alpha_memory:(*g), alpha_network:(*g) -> (*g)")
def sweep(flops: ArrayLike, mem_bytes: ArrayLike, net_bytes: ArrayLike,
          hw: Optional[HardwareLike] = None, *,
          peak_flops: Optional[ArrayLike] = None,
          hbm_bw: Optional[ArrayLike] = None,
          net_bw: Optional[ArrayLike] = None,
          net_steps: ArrayLike = 0.0,
          alpha_compute: Optional[ArrayLike] = None,
          alpha_memory: Optional[ArrayLike] = None,
          alpha_network: Optional[ArrayLike] = None,
          compute_eff: Optional[EfficiencyModel] = None) -> SweepResult:
    """Evaluate the (α-aware) Ridgeline on a broadcast grid of work units.

    Machine peaks come either from ``hw`` (one spec for the whole grid; a
    string resolves through ``core.hardware.get_hardware``, so calibrated
    registry names work anywhere a spec does) or from explicit
    ``peak_flops``/``hbm_bw``/``net_bw`` arrays, which also broadcast —
    sweeping *hardware* is just another grid axis.  α terms and ``net_steps``
    (serialized network hops) broadcast the same way and default from ``hw``
    (0 without one), reproducing the bandwidth-only model when all zero:

        t_C = α_C·[F>0] + F/(peak·eff(F))   t_M = α_M·[B_M>0] + B_M/hbm
        t_N = α_N·steps + B_N/net

    ``compute_eff`` (defaulting from ``hw``, identity without one) is the
    size-dependent achievable-PEAK curve: the effective compute ceiling of
    each grid cell is ``peak · eff(F)``.  The identity curve keeps the
    constant-ceiling times bit-for-bit.

    Runs under a ``core.sweep`` trace span carrying the evaluated cell
    count (``repro.obs.trace``; a no-op unless tracing is enabled).
    """
    with trace.span("core.sweep") as sp:
        res = _sweep_impl(
            flops, mem_bytes, net_bytes, hw, peak_flops=peak_flops,
            hbm_bw=hbm_bw, net_bw=net_bw, net_steps=net_steps,
            alpha_compute=alpha_compute, alpha_memory=alpha_memory,
            alpha_network=alpha_network, compute_eff=compute_eff)
        sp.set(cells=int(res.runtime.size))
        return res


def _sweep_impl(flops: ArrayLike, mem_bytes: ArrayLike, net_bytes: ArrayLike,
                hw: Optional[HardwareLike] = None, *,
                peak_flops: Optional[ArrayLike] = None,
                hbm_bw: Optional[ArrayLike] = None,
                net_bw: Optional[ArrayLike] = None,
                net_steps: ArrayLike = 0.0,
                alpha_compute: Optional[ArrayLike] = None,
                alpha_memory: Optional[ArrayLike] = None,
                alpha_network: Optional[ArrayLike] = None,
                compute_eff: Optional[EfficiencyModel] = None) -> SweepResult:
    if isinstance(hw, str):
        hw = get_hardware(hw)
    if hw is not None:
        peak_flops = hw.peak_flops if peak_flops is None else peak_flops
        hbm_bw = hw.hbm_bw if hbm_bw is None else hbm_bw
        net_bw = hw.net_bw if net_bw is None else net_bw
        alpha_compute = hw.alpha_compute if alpha_compute is None \
            else alpha_compute
        alpha_memory = hw.alpha_memory if alpha_memory is None \
            else alpha_memory
        alpha_network = hw.alpha_network if alpha_network is None \
            else alpha_network
        compute_eff = hw.compute_eff if compute_eff is None else compute_eff
    if peak_flops is None or hbm_bw is None or net_bw is None:
        raise ValueError("pass hw= or all three of peak_flops/hbm_bw/net_bw")
    alpha_compute = 0.0 if alpha_compute is None else alpha_compute
    alpha_memory = 0.0 if alpha_memory is None else alpha_memory
    alpha_network = 0.0 if alpha_network is None else alpha_network

    f, bm, bn, pk, mb, nb, ns, a_c, a_m, a_n = np.broadcast_arrays(
        *(np.asarray(v, dtype=np.float64)
          for v in (flops, mem_bytes, net_bytes, peak_flops, hbm_bw, net_bw,
                    net_steps, alpha_compute, alpha_memory, alpha_network)))
    t_c = np.where(f > 0, a_c, 0.0) + _safe_div(f, pk * eff_grid(
        compute_eff, f))
    t_m = np.where(bm > 0, a_m, 0.0) + _safe_div(bm, mb)
    t_n = a_n * ns + _safe_div(bn, nb)
    times = np.stack([t_c, t_m, t_n])       # axis 0 == RESOURCE_ORDER
    runtime = times.max(axis=0)
    # np.argmax returns the first maximal index -> the priority tie-break
    bottleneck = times.argmax(axis=0).astype(np.int8)
    attained = np.where(runtime > 0, _safe_div(f, runtime), 0.0)
    return SweepResult(
        flops=f, mem_bytes=bm, net_bytes=bn,
        t_compute=t_c, t_memory=t_m, t_network=t_n,
        runtime=runtime, bottleneck=bottleneck,
        attained_flops=attained, peak_fraction=_safe_div(attained, pk),
        x=_safe_div(bm, bn), y=_safe_div(f, bm))


def grid(**axes: Sequence) -> Dict[str, np.ndarray]:
    """Named meshgrid: 1-D axes -> broadcastable N-D coordinate arrays.

    ``grid(batch=[...], dp=[...])`` returns arrays of shape
    ``(len(batch), len(dp))`` in the keyword order given.
    """
    names = list(axes)
    arrays = np.meshgrid(*(np.asarray(axes[n]) for n in names),
                         indexing="ij")
    return dict(zip(names, arrays))


# --- ridge crossings ----------------------------------------------------------


def crossover(xs: ArrayLike, t_a: ArrayLike, t_b: ArrayLike,
              log_x: bool = False) -> Optional[float]:
    """The x where the curves ``t_a`` and ``t_b`` cross (first sign change).

    Linearly interpolates ``t_a − t_b`` between the bracketing samples
    (in log-x when ``log_x``); exact when the difference is linear in x —
    e.g. constant network time vs batch-linear compute time (Fig. 4c).
    Returns None when the curves never cross on the sampled range.

    With ``log_x`` a bracket touching a nonpositive sample (where log is
    undefined) falls back to linear interpolation for that bracket instead
    of raising — sampled grids that start at 0 are common in sweeps.
    """
    xs = np.asarray(xs, dtype=np.float64)
    d = np.asarray(t_a, dtype=np.float64) - np.asarray(t_b, dtype=np.float64)
    sign = np.sign(d)
    idx = np.nonzero(sign[:-1] * sign[1:] < 0)[0]
    if idx.size == 0:
        exact = np.nonzero(sign == 0)[0]
        return float(xs[exact[0]]) if exact.size else None
    i = int(idx[0])
    use_log = log_x and xs[i] > 0 and xs[i + 1] > 0
    x0, x1 = (math.log(xs[i]), math.log(xs[i + 1])) if use_log else \
        (xs[i], xs[i + 1])
    frac = d[i] / (d[i] - d[i + 1])
    xc = x0 + frac * (x1 - x0)
    return float(math.exp(xc)) if use_log else float(xc)


def transitions(result: SweepResult, xs: Optional[ArrayLike] = None
                ) -> List[Tuple[int, str, str]]:
    """Bottleneck changes along a 1-D sweep: (index-after, from, to).

    ``xs`` is unused for the indices but validates the sweep is 1-D and
    aligned when provided.
    """
    labels = result.labels()
    if labels.ndim != 1:
        raise ValueError(f"transitions needs a 1-D sweep, got {labels.shape}")
    if xs is not None and len(np.asarray(xs)) != labels.shape[0]:
        raise ValueError("xs length does not match sweep length")
    return [(i + 1, str(labels[i]), str(labels[i + 1]))
            for i in range(labels.shape[0] - 1)
            if labels[i] != labels[i + 1]]


def ridge_crossing(result: SweepResult, xs: ArrayLike,
                   a: Resource = Resource.NETWORK,
                   b: Resource = Resource.COMPUTE,
                   log_x: bool = True) -> Optional[float]:
    """Interpolated x where resource ``a``'s time hands over to ``b``'s."""
    times = {Resource.COMPUTE: result.t_compute,
             Resource.MEMORY: result.t_memory,
             Resource.NETWORK: result.t_network}
    return crossover(xs, times[a], times[b], log_x=log_x)
