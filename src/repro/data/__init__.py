"""Synthetic data pipelines feeding the train loop (``pipeline``)."""
