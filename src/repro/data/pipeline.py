"""Deterministic synthetic data pipeline with per-host sharding.

Production contract: every host derives its shard of each global batch from
(seed, step, host_id) alone — no coordination, no state to checkpoint beyond
the step counter.  That is what makes elastic restarts trivial (a rejoined
or replacement host regenerates exactly its shard) and is the standard
strategy for deterministic multi-host input pipelines.

Synthetic tasks (this container has no datasets) that still give a
decreasing loss so the end-to-end examples demonstrate learning:

  * LM families: order-k Markov token streams — a fixed random transition
    table the model can learn (CE drops well below log V).
  * MLP/DLRM: clicks from a random ground-truth logistic model over the
    feature vector.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    global_batch: int = 32
    seq_len: int = 128
    n_hosts: int = 1
    host_id: int = 0
    markov_order: int = 1
    vocab_cap: int = 512        # synthetic stream uses min(vocab, cap)

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLMStream:
    """Markov-chain token stream: fixed transition matrix per seed."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg, self.data = cfg, data
        self.v = min(cfg.vocab_size, data.vocab_cap)
        rng = np.random.default_rng(data.seed)
        # peaked transition table: each token has ~4 likely successors that
        # together carry ~96% of the mass (optimal CE ~ 1.7 nats vs uniform
        # log V ~ 6.2 — a strong, learnable signal for the smoke examples)
        logits = rng.standard_normal((self.v, self.v)).astype(np.float32)
        top = np.argsort(logits, axis=1)[:, -4:]
        boost = np.zeros_like(logits)
        np.put_along_axis(boost, top, 8.0, axis=1)
        p = np.exp(logits * 0.1 + boost)
        self.trans = (p / p.sum(1, keepdims=True)).astype(np.float32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        d = self.data
        rng = np.random.default_rng(
            (d.seed * 1_000_003 + step) * 4096 + d.host_id)
        B, S = d.host_batch, d.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.v, B)
        u = rng.random((B, S)).astype(np.float32)
        cdf = np.cumsum(self.trans, axis=1)
        for t in range(S):
            toks[:, t + 1] = (
                cdf[toks[:, t]] < u[:, t:t + 1]).sum(1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (B, self.cfg.visual_tokens, self.cfg.visual_width)
            ).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class SyntheticCTRStream:
    """DLRM click stream: y ~ Bernoulli(sigmoid(w·x)) for a fixed hidden w."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg, self.data = cfg, data
        rng = np.random.default_rng(data.seed)
        self.d_in = cfg.mlp_widths[0]
        self.w = (rng.standard_normal(self.d_in) / np.sqrt(self.d_in)
                  ).astype(np.float32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        d = self.data
        rng = np.random.default_rng(
            (d.seed * 1_000_003 + step) * 4096 + d.host_id)
        x = rng.standard_normal((d.host_batch, self.d_in)).astype(np.float32)
        p = 1.0 / (1.0 + np.exp(-4.0 * x @ self.w))
        y = (rng.random(d.host_batch) < p).astype(np.float32)
        return {"features": x, "click": y}


def make_stream(cfg: ModelConfig, data: DataConfig):
    if cfg.family == "mlp":
        return SyntheticCTRStream(cfg, data)
    return SyntheticLMStream(cfg, data)


def skip_to(stream, step: int) -> None:
    """Restart support: nothing to do — batches are pure functions of step."""
    return None
