"""Distributed substrate: logical-axis sharding + collective cost models.

``collectives`` is pure NumPy; the sharding re-exports are lazy (PEP 562)
so that analytic consumers (the sweep engine, the parallelism planner CLI)
never pay the jax import just to price an all-reduce.
"""
from repro.distributed import collectives  # noqa: F401  (jax-free)

_SHARDING_NAMES = ("DEFAULT_RULES", "gqa_safe_rules", "logical_spec",
                   "shard_hint", "specs_to_shardings", "use_sharding")

__all__ = list(_SHARDING_NAMES) + ["collectives"]


def __getattr__(name):
    if name in _SHARDING_NAMES:
        from repro.distributed import sharding
        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
