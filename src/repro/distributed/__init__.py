from repro.distributed.sharding import (DEFAULT_RULES, gqa_safe_rules,
                                        logical_spec, shard_hint,
                                        specs_to_shardings, use_sharding)
