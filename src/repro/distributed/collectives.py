"""Analytic per-chip collective cost models (α–β accounting, vectorized).

The Ridgeline's ``B_N`` term is *wire bytes sent per chip*; this module is
the single source of those bytes for every collective the parallelism
strategies use.  All functions are NumPy-vectorized: ``payload_bytes`` and
``group_size`` broadcast against each other, so a whole sweep grid
(batch × mesh × algorithm) evaluates in one call.

Conventions (matching ``core/hlo_analysis`` and the literature, e.g.
Chan et al. "Collective communication: theory, practice, and experience"
and the NCCL ring/tree models):

  * ``payload_bytes`` is the *logical result/input size* of the collective:
    the full reduced tensor for all-reduce and reduce-scatter, the full
    gathered tensor for all-gather, and the per-chip resident buffer for
    all-to-all.
  * ``group_size`` ``n`` may be a float; ``math.inf`` gives the paper's
    large-n asymptote (the §III case study counts the ring all-reduce at
    exactly 2·payload, i.e. n→∞).  ``n == 1`` degenerates to zero bytes
    for every op/algorithm.
  * Per-chip bytes count what each chip *sends* on its busiest link; the
    bandwidth-optimal algorithms are link-balanced so this equals
    received bytes.

Per-chip wire bytes:

  all-reduce     ring    2·(n−1)/n · payload     (reduce-scatter + all-gather)
                 bidir   (n−1)/n · payload       (two half-payload rings)
                 tree    2·payload (n>1)         (send up + forward down)
  reduce-scatter ring    (n−1)/n · payload
  all-gather     ring    (n−1)/n · payload
  all-to-all     ring    (n−1)/n · payload

Latency ``steps`` are the serialized hop counts of each algorithm; together
with a per-hop latency α they give the α–β collective time

    t = α · steps + wire_bytes / link_bw

(:meth:`CollectiveCost.time`), which is what the α-aware Ridgeline
(``core/ridgeline``, ``core/sweep``) and the planner charge for network
work.  With α = 0 this degenerates to the paper's bandwidth-only model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.contracts import shape_contract

ArrayLike = Union[float, np.ndarray]

#: supported all-reduce algorithm tags
ALGORITHMS = ("ring", "bidir_ring", "tree")

#: CLI-friendly short names accepted anywhere an algorithm tag is
ALGORITHM_ALIASES = {"bidir": "bidir_ring"}


def canonical_algorithm(name: str) -> str:
    """Resolve an algorithm tag or alias; unknown names raise with options."""
    name = ALGORITHM_ALIASES.get(name, name)
    if name not in ALGORITHMS:
        raise ValueError(f"unknown all-reduce algorithm {name!r}; "
                         f"have {ALGORITHMS} (aliases "
                         f"{sorted(ALGORITHM_ALIASES)})")
    return name


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """Per-chip cost of one collective: bytes on the busiest link + hops."""

    wire_bytes: ArrayLike
    steps: ArrayLike

    def time(self, link_bw: float, alpha: float = 0.0) -> ArrayLike:
        """α–β time: ``alpha·steps + wire_bytes/link_bw`` (α defaults to 0,
        the bandwidth-only model)."""
        return (np.asarray(alpha, dtype=np.float64) * np.asarray(self.steps)
                + np.asarray(self.wire_bytes) / link_bw)

    def __add__(self, other: "CollectiveCost") -> "CollectiveCost":
        """Serial composition: bytes and hops both accumulate."""
        return CollectiveCost(
            np.asarray(self.wire_bytes) + np.asarray(other.wire_bytes),
            np.asarray(self.steps) + np.asarray(other.steps))

    def scaled(self, k: ArrayLike) -> "CollectiveCost":
        """``k`` back-to-back executions of this collective."""
        k = np.asarray(k, dtype=np.float64)
        return CollectiveCost(k * np.asarray(self.wire_bytes),
                              k * np.asarray(self.steps))


def _ring_factor(n: ArrayLike) -> np.ndarray:
    """(n−1)/n with n=1 → 0 and n=inf → 1, elementwise."""
    n = np.asarray(n, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        f = 1.0 - 1.0 / n
    return np.where(n <= 1.0, 0.0, f)


def _active(n: ArrayLike) -> np.ndarray:
    """1.0 where the group actually communicates (n > 1), else 0.0."""
    return np.where(np.asarray(n, dtype=np.float64) > 1.0, 1.0, 0.0)


def _log2_steps(n: ArrayLike) -> np.ndarray:
    n = np.asarray(n, dtype=np.float64)
    with np.errstate(divide="ignore", over="ignore"):
        return np.where(n > 1.0, np.ceil(np.log2(np.where(n > 1.0, n, 2.0))),
                        0.0)


@shape_contract("(*g), (*g) -> (*g)")
def all_reduce(payload_bytes: ArrayLike, group_size: ArrayLike,
               algorithm: str = "ring") -> CollectiveCost:
    p = np.asarray(payload_bytes, dtype=np.float64)
    n = np.asarray(group_size, dtype=np.float64)
    if algorithm == "ring":
        return CollectiveCost(2.0 * _ring_factor(n) * p,
                              2.0 * np.maximum(n - 1.0, 0.0))
    if algorithm == "bidir_ring":
        # the payload is split across the two ring directions
        return CollectiveCost(_ring_factor(n) * p, np.maximum(n - 1.0, 0.0))
    if algorithm == "tree":
        # pipelined binomial reduce + broadcast: each chip forwards the
        # whole payload up and down once — n-independent bytes, log-n hops
        return CollectiveCost(2.0 * _active(n) * p, 2.0 * _log2_steps(n))
    raise ValueError(f"unknown all-reduce algorithm {algorithm!r}; "
                     f"have {ALGORITHMS}")


def reduce_scatter(payload_bytes: ArrayLike,
                   group_size: ArrayLike) -> CollectiveCost:
    p = np.asarray(payload_bytes, dtype=np.float64)
    n = np.asarray(group_size, dtype=np.float64)
    return CollectiveCost(_ring_factor(n) * p, np.maximum(n - 1.0, 0.0))


def all_gather(payload_bytes: ArrayLike,
               group_size: ArrayLike) -> CollectiveCost:
    # identical wire profile to reduce-scatter (its mirror image)
    return reduce_scatter(payload_bytes, group_size)


@shape_contract("(*g), (*g) -> (*g)")
def all_to_all(payload_bytes: ArrayLike,
               group_size: ArrayLike) -> CollectiveCost:
    """payload = per-chip resident bytes; each chip keeps 1/n of it local."""
    return reduce_scatter(payload_bytes, group_size)


def all_reduce_bytes(payload_bytes: ArrayLike, group_size: ArrayLike,
                     algorithm: str = "ring") -> ArrayLike:
    return all_reduce(payload_bytes, group_size, algorithm).wire_bytes


# --- algorithm selection (α–β argmin over the algorithm menu) -----------------


def best_all_reduce(payload_bytes: float, group_size: float, bw: float,
                    alpha: float = 0.0,
                    algorithms: Sequence[str] = ALGORITHMS
                    ) -> Tuple[str, CollectiveCost]:
    """The α–β-fastest all-reduce algorithm for one payload on one link.

    Scalar argmin of ``CollectiveCost.time(bw, alpha)`` over ``algorithms``
    (Hashemi et al.: communication cost models are per-algorithm, so the
    *choice* is part of the cost model).  With α > 0 the log-step tree wins
    small payloads and a bandwidth-optimal ring wins large ones; with α = 0
    the fewest-wire-bytes algorithm always wins.  Ties resolve to the
    earlier entry of ``algorithms`` (deterministic).  ``group_size <= 1``
    degenerates to a zero cost — a size-1 group has no collective to run,
    so no α is paid either.
    """
    if not algorithms:
        raise ValueError("need at least one algorithm to choose from")
    best: Optional[Tuple[str, CollectiveCost, float]] = None
    for name in algorithms:
        algo = canonical_algorithm(name)
        cost = all_reduce(payload_bytes, group_size, algo)
        t = float(cost.time(bw, alpha))
        if best is None or t < best[2]:
            best = (algo, cost, t)
    return best[0], best[1]


@shape_contract("(*g), (*g), (*g), (*g) -> (*g), (*g), (*g)")
def best_all_reduce_grid(payload_bytes: ArrayLike, group_size: ArrayLike,
                         bw: ArrayLike, alpha: ArrayLike = 0.0,
                         algorithms: Sequence[str] = ALGORITHMS,
                         allowed: Optional[np.ndarray] = None,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized α–β argmin over the algorithm menu, elementwise.

    The grid twin of :func:`best_all_reduce`: every argument broadcasts
    against every other, so a whole planner candidate set — each element
    its own payload, group size, *and link* (per-element ``bw``/``alpha``)
    — selects in one pass.  Returns ``(wire_bytes, steps, algo_idx)``
    arrays of the broadcast shape, with ``algo_idx`` indexing into
    ``algorithms`` (canonicalized).  Ties resolve to the earliest menu
    entry, matching the scalar's strict-less-than scan bit-for-bit
    (property-tested in ``tests/test_plan_grid.py``).

    ``allowed`` optionally masks the menu per element — shape
    ``(len(algorithms), *broadcast_shape)`` of booleans — so a candidate
    set can mix "auto" rows (all True) with fixed-algorithm rows (one
    True) in the same pass; a disallowed entry prices at +inf and is
    never selected, and a column with no allowed entry at all raises
    (there is nothing valid to return for it).
    """
    if not algorithms:
        raise ValueError("need at least one algorithm to choose from")
    p = np.asarray(payload_bytes, dtype=np.float64)
    n = np.asarray(group_size, dtype=np.float64)
    bw = np.asarray(bw, dtype=np.float64)
    alpha = np.asarray(alpha, dtype=np.float64)
    shape = np.broadcast_shapes(p.shape, n.shape, bw.shape, alpha.shape)
    wire = np.empty((len(algorithms),) + shape, dtype=np.float64)
    steps = np.empty_like(wire)
    for a, name in enumerate(algorithms):
        cost = all_reduce(p, n, canonical_algorithm(name))
        wire[a] = np.broadcast_to(cost.wire_bytes, shape)
        steps[a] = np.broadcast_to(cost.steps, shape)
    times = alpha * steps + wire / bw          # same expression as .time()
    if allowed is not None:
        if not np.all(np.any(allowed, axis=0)):
            raise ValueError(
                "allowed mask excludes every algorithm for at least one "
                "element; each column needs one True entry")
        times = np.where(allowed, times, np.inf)
    idx = times.argmin(axis=0)                 # first minimum == menu order
    sel = np.expand_dims(idx, 0)
    return (np.take_along_axis(wire, sel, 0)[0],
            np.take_along_axis(steps, sel, 0)[0], idx)


def all_reduce_flip_payload(group_size: float, bw: float, alpha: float,
                            algorithms: Sequence[str] = ALGORITHMS
                            ) -> Optional[Tuple[float, str, str]]:
    """Payload where the best all-reduce algorithm flips, if it does.

    Each algorithm's time is affine in the payload,
    ``t(p) = α·steps(n) + slope(n)·p/bw``, so the argmin along payload is a
    lower envelope of lines: the minimum-intercept algorithm wins small
    payloads, the minimum-slope one wins large payloads, and the flip sits
    where their lines cross.  Returns ``(flip_payload_bytes, small_algo,
    large_algo)``, or None when one algorithm dominates (e.g. α = 0, a
    size-1 group, or n too small for the tree's log-step advantage).
    """
    n = float(group_size)
    if n <= 1.0 or not algorithms:
        return None
    lines = []
    for name in algorithms:
        algo = canonical_algorithm(name)
        unit = all_reduce(1.0, n, algo)              # per-payload-byte cost
        lines.append((algo, alpha * float(unit.steps),
                      float(unit.wire_bytes) / bw))
    small = min(lines, key=lambda l: (l[1], l[2]))   # min intercept
    large = min(lines, key=lambda l: (l[2], l[1]))   # min slope
    if small[0] == large[0] or small[2] <= large[2]:
        return None                                  # one line dominates
    flip = (large[1] - small[1]) / (small[2] - large[2])
    return flip, small[0], large[0]


# --- strategy-level accounting (what feeds WorkUnit.net_bytes/net_steps) ------


def dp_grad_sync(grad_bytes_per_chip: ArrayLike, dp: ArrayLike,
                 algorithm: str = "ring") -> CollectiveCost:
    """Data parallel: one all-reduce of the local gradient shard per step."""
    return all_reduce(grad_bytes_per_chip, dp, algorithm)


def dp_grad_sync_bytes(grad_bytes_per_chip: ArrayLike, dp: ArrayLike,
                       algorithm: str = "ring") -> ArrayLike:
    return dp_grad_sync(grad_bytes_per_chip, dp, algorithm).wire_bytes


@shape_contract("(*g), (*g), (*g) -> (*g)")
def zero_dp_sync(state_bytes_per_chip: ArrayLike, dp: ArrayLike,
                 stage: ArrayLike) -> CollectiveCost:
    """ZeRO-sharded dp-axis traffic per step (Rajbhandari et al.).

    ``state_bytes_per_chip`` is this chip's full parameter-block size (the
    gradient block is the same size in this repo's fp32 accounting).  With
    states sharded over dp, the ring all-reduce decomposes into its two
    halves plus — at stage 3 — one more gather:

      stage 1/2   reduce-scatter(grads) + all-gather(params)
                  = 2 · (dp−1)/dp · bytes,  2·(dp−1) hops
      stage 3     + a second params all-gather (forward re-gathers the
                  shard it no longer holds)
                  = 3 · (dp−1)/dp · bytes,  3·(dp−1) hops

    Stage 1/2 wire bytes equal the plain ring all-reduce (RS+AG *is* the
    ring), so pricing stays continuous with the zero-0 model; what changes
    is that the algorithm is structural — sharded state cannot ride a tree
    or bidirectional ring — so the planner pins these rows to this cost
    instead of the α–β argmin.  ``stage`` broadcasts; stage 0 prices as
    stage 1/2 (callers route stage-0 rows to the argmin path instead).
    """
    p = np.asarray(state_bytes_per_chip, dtype=np.float64)
    n = np.asarray(dp, dtype=np.float64)
    k = np.where(np.asarray(stage, dtype=np.float64) >= 3.0, 3.0, 2.0)
    return CollectiveCost(k * _ring_factor(n) * p,
                          k * np.maximum(n - 1.0, 0.0))


def tp_act_sync(act_bytes: ArrayLike, tp: ArrayLike,
                syncs_per_layer: ArrayLike, n_layers: ArrayLike,
                algorithm: str = "ring") -> CollectiveCost:
    """Tensor parallel: activation all-reduces at block boundaries.

    Megatron-style transformers sync 4×/layer (f+g, fwd+bwd over attn and
    mlp blocks); a plain MLP tower syncs 2×/layer (fwd + bwd).  The syncs
    are serialized by data dependence, so hops accumulate too.
    """
    per = all_reduce(act_bytes, tp, algorithm)
    return per.scaled(np.asarray(syncs_per_layer, np.float64)
                      * np.asarray(n_layers, np.float64))


def tp_act_sync_bytes(act_bytes: ArrayLike, tp: ArrayLike,
                      syncs_per_layer: ArrayLike, n_layers: ArrayLike,
                      algorithm: str = "ring") -> ArrayLike:
    return tp_act_sync(act_bytes, tp, syncs_per_layer, n_layers,
                       algorithm).wire_bytes


@shape_contract("(*g), (*g) -> (*g)")
def ep_dispatch_combine(payload_bytes: ArrayLike,
                        ep: ArrayLike) -> CollectiveCost:
    """Expert parallel: dispatch + combine all-to-alls, per MoE layer.

    ``payload_bytes`` is the per-chip routed-token buffer (tokens · k ·
    capacity_factor · width · act bytes, after any routing-imbalance
    derate); each MoE layer pays one all-to-all to scatter tokens to
    their experts' chips and a second to bring the expert outputs home —
    2·(ep−1)/ep · payload wire bytes, 2·(ep−1) serialized hops.  A size-1
    ep group runs no collective and costs exactly zero (wire and steps).
    """
    return all_to_all(payload_bytes, ep).scaled(2.0)


@shape_contract("(*g), (*g) -> (*g)")
def pp_boundary_bytes(act_bytes: ArrayLike, pp: ArrayLike) -> ArrayLike:
    """Pipeline parallel: point-to-point activations at stage boundaries.

    A middle stage sends the boundary activation forward and its gradient
    backward each step: 2·act_bytes of sends per chip, zero when pp == 1.
    """
    return 2.0 * _active(pp) * np.asarray(act_bytes, dtype=np.float64)
