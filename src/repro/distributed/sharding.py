"""Logical-axis sharding: rules mapping model axes -> mesh axes.

Models annotate params (``*_specs`` pytrees of logical-axis tuples) and
activations (``shard_hint``) with *logical* names; this module binds them to
mesh axes at launch time.  Outside an active binding, ``shard_hint`` is the
identity, so all model code runs unmodified on a single CPU device (smoke
tests) and under any mesh (dry-run / production).

Default rules (the baseline sharding scheme recorded in EXPERIMENTS.md):

  batch   -> ("pod", "data")   DP over pods and the data axis
  q_proj / kv_proj / heads / ffn / experts / vocab -> "model"   TP / EP
  embed   -> None (replicated activations dim)
  seq     -> None (SP variants map it to "model" for long-context shapes)
  layers / kv_seq -> None

GQA note: ``kv_proj`` maps to "model" only when n_kv_heads divides the mesh
axis; otherwise the launcher drops it to None (kv heads replicated), the
standard GQA TP fallback.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]
Rules = Mapping[str, AxisName]

DEFAULT_RULES: Dict[str, AxisName] = {
    "batch": ("pod", "data"),
    "seq": None,
    "attn_seq": None,   # SP fallback for attention internals
    "embed": None,
    "q_proj": "model",
    "kv_proj": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_ffn": None,     # swapped with "experts" when E % model_size != 0
    "vocab": "model",
    "layers": None,
    "kv_seq": None,
    "head_dim": None,     # decode-cache dh sharding (serve rules map it to model)
    "dp_shard": ("pod", "data"),   # ZeRO/FSDP param & moment sharding
}

_state = threading.local()


def _active() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_state, "binding", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[Rules] = None):
    """Bind a mesh + logical rules; nests with the jax mesh context."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # drop rule entries naming axes the mesh doesn't have (single-pod mesh
    # has no "pod" axis)
    def _filter(axis: AxisName) -> AxisName:
        names = set(mesh.axis_names)
        if isinstance(axis, tuple):
            kept = tuple(a for a in axis if a in names)
            return kept if kept else None
        return axis if (axis is None or axis in names) else None

    rules = {k: _filter(v) for k, v in rules.items()}
    prev = _active()
    _state.binding = (mesh, rules)
    try:
        with mesh:
            yield rules
    finally:
        _state.binding = prev


def logical_spec(axes: Sequence[Optional[str]],
                 rules: Optional[Rules] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    A mesh axis may appear at most once in a spec; when two logical axes
    map to the same mesh axis (e.g. seq and vocab both -> "model" under
    sequence parallelism), the first keeps it and later ones drop to None.
    """
    binding = _active()
    if rules is None:
        if binding is None:
            return P()
        rules = binding[1]
    used: set = set()
    out = []
    for a in axes:
        m = rules.get(a) if a is not None else None
        names = m if isinstance(m, tuple) else (m,) if m else ()
        if any(n in used for n in names):
            out.append(None)
            continue
        used.update(names)
        out.append(m)
    return P(*out)


def _drop_nondividing(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Replace spec entries whose mesh extent doesn't divide the dim size."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        ext = 1
        for n in names:
            ext *= mesh.shape[n]
        out.append(entry if dim % ext == 0 else None)
    return P(*out)


def shard_hint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint against the active binding (identity if none).

    Axes whose mesh extent doesn't divide the dimension are dropped
    (replicated) rather than erroring — odd vocab sizes (51865, 32001, …)
    and head counts are the norm in the assigned configs.
    """
    binding = _active()
    if binding is None:
        return x
    mesh, rules = binding
    spec = _drop_nondividing(logical_spec(axes, rules), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def specs_to_shardings(specs: Any, mesh: Mesh,
                       rules: Optional[Rules] = None) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    Leaves are tuples of logical names; ``is_leaf`` keys on tuples so nested
    dicts/lists of specs work.
    """
    binding = _active()
    rules = rules or (binding[1] if binding else DEFAULT_RULES)

    def to_sharding(axes):
        return NamedSharding(mesh, logical_spec(axes, rules))

    return jax.tree.map(to_sharding, specs,
                        is_leaf=lambda x: isinstance(x, tuple))


def validate_divisibility(shapes: Any, shardings: Any) -> None:
    """Raise early (with a useful message) when a dim doesn't divide."""
    flat_sh, _ = jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    flat_shape, _ = jax.tree_util.tree_flatten(shapes)
    for arr, sh in zip(flat_shape, flat_sh):
        shape = getattr(arr, "shape", None)
        if shape is None or not isinstance(sh, NamedSharding):
            continue
        mesh = sh.mesh
        for dim, spec in zip(shape, sh.spec):
            if spec is None:
                continue
            names = spec if isinstance(spec, tuple) else (spec,)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            if dim % size:
                raise ValueError(
                    f"dim {dim} not divisible by mesh extent {size} "
                    f"({names}) for shape {shape}")


def gqa_safe_rules(n_kv_heads: int, mesh: Mesh,
                   base: Optional[Rules] = None) -> Dict[str, AxisName]:
    """Drop kv_proj/kv_heads TP when kv heads don't divide the model axis."""
    rules = dict(DEFAULT_RULES, **(base or {}))
    model_size = mesh.shape.get("model", 1) if hasattr(mesh, "shape") else 1
    if n_kv_heads % max(model_size, 1):
        rules["kv_proj"] = None
        rules["kv_heads"] = None
    return rules
