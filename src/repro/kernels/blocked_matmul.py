"""Pallas TPU blocked matmul with fused bias + activation epilogue.

The paper's case-study hotspot: the MLP layer GEMM ``O = f(W·I + b)``.
Fusing the bias-add and activation into the GEMM epilogue removes the
elementwise HBM round-trip the paper's B_M accounting would otherwise pay
(2 extra R/W of the (batch, features) activation per layer).

TPU mapping: grid (M/bm, N/bn, K/bk) with the K dimension innermost so the
fp32 VMEM accumulator carries across K steps; blocks default to 512×512×512
(MXU-aligned multiples of 128; ~1.5 MiB of VMEM for bf16 operands + fp32
accumulator, well inside the 16 MiB/core budget).  Validated on CPU with
``interpret=True`` against ``ref.ref_matmul``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS = ("relu", "relu2", "silu", "gelu")


def _epilogue(y: jnp.ndarray, act: Optional[str]) -> jnp.ndarray:
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "relu2":
        r = jnp.maximum(y, 0.0)
        return r * r
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    return y


def _kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, k_steps: int,
            act: Optional[str]):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        y = acc_ref[...]
        if bias_ref is not None:
            y = y + bias_ref[...].astype(jnp.float32)
        o_ref[...] = _epilogue(y, act).astype(o_ref.dtype)


def blocked_matmul(a: jnp.ndarray, b: jnp.ndarray,
                   bias: Optional[jnp.ndarray] = None,
                   act: Optional[str] = None,
                   block_m: int = 512, block_n: int = 512, block_k: int = 512,
                   interpret: bool = True) -> jnp.ndarray:
    """a (M, K) @ b (K, N) [+ bias (N,)] with fused activation.

    Requires M % block_m == K % block_k == N % block_n == 0 (the ops.py
    wrapper pads).  ``interpret=True`` runs the kernel body on CPU; on real
    TPU pass interpret=False.
    """
    if act is not None and act not in _ACTS:
        raise ValueError(f"unsupported activation {act}")
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shape ({M},{K})x({K},{N}) not divisible by blocks ({bm},{bn},{bk})"
    grid = (M // bm, N // bn, K // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(bias.reshape(1, N))
        kernel = functools.partial(_kernel, k_steps=grid[2], act=act)
    else:
        kernel = functools.partial(
            lambda a_ref, b_ref, o_ref, acc_ref, **kw:
            _kernel(a_ref, b_ref, None, o_ref, acc_ref, **kw),
            k_steps=grid[2], act=act)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
