"""Pallas TPU causal GQA flash attention (streaming softmax, O(S) memory).

The transformer hotspot: the baseline jnp attention materializes the
(S × S) score tensor in HBM (fp32) — at prefill_32k that is the dominant
B_M term and busts the 16 GiB budget.  This kernel streams K/V blocks
through VMEM with the online max/sum rescaling of FlashAttention
[arXiv:2205.14135], adapted to the TPU memory hierarchy: block shapes are
MXU-aligned (q 256 × kv 512 × dh), the running (m, l, acc) state lives in
VMEM scratch across the innermost kv-grid dimension, and masking (causal /
sliding-window / length padding) is applied with block-position iota instead
of a materialized mask.

Layout contract (ops.py handles transposes): q (B, H, S, dh),
k/v (B, K, S, dh) with H = G·K query groups per kv head.
Validated on CPU with ``interpret=True`` against ``ref.ref_flash_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            kv_steps: int, block_q: int, block_k: int, sm_scale: float,
            causal: bool, window: int, seq_len: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                               # (bq, dh)
    k = k_ref[0, 0]                               # (bk, dh)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_len                          # padded keys
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    p = jnp.exp(jnp.where(m_new <= NEG_INF, NEG_INF, s - m_new))
    alpha = jnp.exp(jnp.where(m_new <= NEG_INF, 0.0, m_prev - m_new))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == kv_steps - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         causal: bool = True, window: int = 0,
                         seq_len: Optional[int] = None,
                         block_q: int = 256, block_k: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """q (B,H,Sp,dh), k/v (B,K,Sp,dh), Sp padded to block multiples.

    ``seq_len`` = true (unpadded) length for key masking.
    """
    B, H, Sp, dh = q.shape
    K = k.shape[1]
    G = H // K
    seq_len = Sp if seq_len is None else seq_len
    bq, bk = min(block_q, Sp), min(block_k, Sp)
    assert Sp % bq == 0 and Sp % bk == 0
    grid = (B * H, Sp // bq, Sp // bk)
    sm_scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _kernel, kv_steps=grid[2], block_q=bq, block_k=bk,
        sm_scale=sm_scale, causal=causal, window=window, seq_len=seq_len)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda bh, iq, ik: (bh // H, bh % H, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda bh, iq, ik: (bh // H, bh % H, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
