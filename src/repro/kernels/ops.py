"""Jit'd dispatch wrappers around the Pallas kernels.

Handles layout/padding glue so callers keep model-native shapes:
  * ``matmul``: collapses leading dims, pads (M, K, N) to block multiples,
    slices back.  ``interpret=True`` on CPU (this container); compiled on TPU.
  * ``flash_attention``: (B, S, H, dh) model layout -> (B, H, S, dh) kernel
    layout, pads S, restores.

The wrappers fall back to the jnp reference for shapes where a kernel launch
is not worth it (tiny matrices in smoke tests).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.blocked_matmul import blocked_matmul
from repro.kernels.flash_attention import flash_attention_bhsd

#: flip on real TPU deployments (pallas compiles natively); interpret on CPU
INTERPRET = jax.default_backend() != "tpu"

_MIN_DIM = 256  # below this, kernel launch overhead > any win: use jnp


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("act", "block"))
def matmul(a: jnp.ndarray, b: jnp.ndarray,
           bias: Optional[jnp.ndarray] = None,
           act: Optional[str] = None, block: int = 512) -> jnp.ndarray:
    """(…, K) @ (K, N) with fused bias+activation via the Pallas kernel."""
    *lead, K = a.shape
    N = b.shape[1]
    M = 1
    for d in lead:
        M *= d
    if min(M, N, K) < _MIN_DIM:
        y = ref.ref_matmul(a.reshape(M, K), b, bias=bias, act=act)
        return y.reshape(*lead, N)
    a2 = _pad_to(_pad_to(a.reshape(M, K), block, 0), block, 1)
    b2 = _pad_to(_pad_to(b, block, 0), block, 1)
    bias2 = _pad_to(bias, block, 0) if bias is not None else None
    y = blocked_matmul(a2, b2, bias=bias2, act=act,
                       block_m=block, block_n=block, block_k=block,
                       interpret=INTERPRET)
    return y[:M, :N].reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Model layout q (B,S,H,dh), k/v (B,S,K,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    if S < _MIN_DIM:
        return ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    # pad S to a multiple of 512 = lcm(block_q, block_k)
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 512, 2)         # (B,H,Sp,dh)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 512, 2)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 512, 2)
    bq, bk = 256, 512
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             seq_len=S, block_q=bq, block_k=bk,
                             interpret=INTERPRET)
    return jnp.swapaxes(o[:, :, :S], 1, 2)
