"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` mirrors the kernel's exact math (including fp32 accumulation
semantics) so ``tests/test_kernels.py`` can assert_allclose across shape /
dtype sweeps with interpret-mode kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ref_matmul(a: jnp.ndarray, b: jnp.ndarray,
               bias: Optional[jnp.ndarray] = None,
               act: Optional[str] = None) -> jnp.ndarray:
    """(M, K) @ (K, N) with fp32 accumulation + fused bias/activation."""
    y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act == "relu":
        y = jax.nn.relu(y)
    elif act == "relu2":
        r = jax.nn.relu(y)
        y = r * r
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act is not None:
        raise ValueError(act)
    return y.astype(a.dtype)


def ref_flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B,S,H,dh), k/v (B,S,K,dh) -> (B,S,H,dh); softmax in fp32.

    GQA via kv-head repetition, same as models/attention._sdpa.
    """
    B, S, H, dh = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window > 0:
        ok = ok & (kpos > qpos - window)
    scores = jnp.where(ok[None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
