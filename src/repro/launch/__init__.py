"""Launchers: dry-run lowering, train/serve entry points, mesh planner.

``plan`` is the analytic parallelism planner CLI
(``python -m repro.launch.plan``); it stays importable without jax.
"""
