import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the production meshes out of 512
# placeholder host devices; no tensor is ever materialized (AOT lower+compile
# over ShapeDtypeStructs only).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we:
  1. build the production mesh (16×16 single pod / 2×16×16 multi-pod),
  2. bind GQA-safe logical sharding rules,
  3. AOT-lower ``train_step`` (train shapes) or ``serve_step``/``prefill``
     (inference shapes) over ShapeDtypeStruct inputs,
  4. ``.compile()`` — success proves the distribution config is coherent,
  5. record memory_analysis / cost_analysis / parsed collective wire bytes,
  6. run the Ridgeline classification (the paper's model) on the terms,
  7. persist a CellReport JSON under ``artifacts/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k --mesh 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
Artifacts are cached by cell key; --force recompiles.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.core import TPU_V5E, analyze_compiled, make_cell_report
from repro.core.report import CellReport
from repro.distributed.sharding import gqa_safe_rules, use_sharding
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models.common import ModelConfig
from repro.optim.optimizer import AdamW
from repro.serve import engine as serve_engine
from repro.train.loop import TrainStepConfig, build_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")
POD_SIZE = 256


def _mesh_from_name(mesh_name: str):
    """"16x16" / "2x16x16" are the production contract; other "AxB" splits
    of the same chips are §Perf variants (e.g. "64x4": trade TP degree for
    DP when head counts don't divide 16)."""
    if mesh_name == "2x16x16":
        return make_production_mesh(multi_pod=True)
    if mesh_name == "16x16":
        return make_production_mesh()
    from repro.launch.mesh import make_mesh
    dims = tuple(int(d) for d in mesh_name.split("x"))
    assert len(dims) == 2, mesh_name
    return make_mesh(dims, ("data", "model"))


def _prepare_cfg(cfg: ModelConfig, shape: ShapeSpec,
                 overrides: Optional[Dict[str, Any]] = None) -> ModelConfig:
    if cfg.pos_emb == "learned" and cfg.max_seq_len < shape.seq_len:
        cfg = cfg.replace(max_seq_len=shape.seq_len)
    if shape.kind == "train" and cfg.family not in ("mlp",):
        # baseline: full remat (16 GiB HBM budget; "dots" residuals measured
        # +17 GiB/dev on qwen2.5-3b — a §Perf lever where memory allows)
        cfg = cfg.replace(remat="full")
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def _rules_for(cfg: ModelConfig, mesh, shape: ShapeSpec):
    rules = gqa_safe_rules(cfg.n_kv_heads, mesh)
    model_size = mesh.shape.get("model", 1)
    if shape.kind == "train":
        # Megatron-SP-style hints: residual-stream activations (the tensors
        # the layer scan saves for backward) shard their seq axis over the
        # model axis; GSPMD inserts the all-gather at attention entry and
        # the reduce-scatter at block exit.  Cuts saved-activation memory
        # by the TP degree.
        rules["seq"] = "model"
    if cfg.n_heads and cfg.n_heads % model_size:
        # heads don't divide the TP axis (smollm 9H, qwen2-7b 28H, hymba
        # 25H): fall back to sequence-parallel activations so the O(S^2)
        # score tensor still shards; FFN TP stays (hidden dims divide).
        rules["heads"] = None
        rules["q_proj"] = None
        rules["seq"] = "model"
        rules["attn_seq"] = "model"
    if shape.kind == "decode":
        # decode memory = KV cache: shard its SEQ axis over the model axis
        # (SP-decode).  The cache write is an elementwise select (see
        # attention.decode_attention) so it partitions; softmax/output
        # reductions over the sharded S axis are tiny (B·H·dh) collectives.
        # All per-token projections are left local: sharding q heads while
        # the cache shards on seq makes GSPMD bounce tensors between
        # incompatible layouts (measured "involuntary full remat" warnings).
        rules["kv_seq"] = "model"
        rules["head_dim"] = None
    if shape.kind != "train" and shape.global_batch < 16:
        # long_500k has global_batch=1: nothing to shard on data
        rules["batch"] = None
    # MoE: EP when the (optionally padded) expert count divides the model
    # axis; otherwise TP the per-expert hidden dim (replicating 60 experts
    # measured 375 GiB/dev)
    e_eff = max(cfg.n_experts, cfg.pad_experts_to)
    if cfg.n_experts and e_eff % model_size:
        rules["experts"] = None
        rules["expert_ffn"] = "model"
    return rules


def _lower_one(cfg: ModelConfig, shape: ShapeSpec, mesh):
    if shape.kind == "train":
        return _lower_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return _lower_prefill(cfg, shape, mesh)
    return _lower_decode(cfg, shape, mesh)


def _probe_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """k-layer fully-unrolled config for cost probing (layers homogeneous)."""
    kw: Dict[str, Any] = dict(n_layers=k, scan_layers=False,
                              slstm_layers=(), global_attn_layers=())
    if cfg.family == "encdec":
        kw["encoder_layers"] = k
    return cfg.replace(**kw)


def probe_costs(cfg: ModelConfig, shape: ShapeSpec, mesh, mesh_name: str):
    """XLA cost_analysis counts while-loop bodies ONCE (verified in
    tests/test_hlo_analysis.py), so the scanned production artifact
    undercounts F / B_M / wire by ~the layer count.  Probe: compile k=2,4
    layers UNROLLED, fit cost(L) = a + b*L, extrapolate to the full depth.
    ``a`` captures the layer-independent part (embedding, logits+loss,
    optimizer, gradient all-reduce), ``b`` the per-layer part (block
    compute + TP/SP collectives).
    """
    samples = []
    for k in (2, 4):
        pcfg = _probe_cfg(cfg, k)
        compiled, _ = _lower_one(pcfg, shape, mesh)
        c = analyze_compiled(compiled, mesh.size,
                             pod_size=POD_SIZE if mesh_name == "2x16x16" else 0)
        samples.append((c.flops, c.mem_bytes, c.wire_bytes,
                        {kk: b for kk, (_, b) in
                         c.collectives.by_kind().items()},
                        c.collectives.cross_pod_wire_bytes))
    L = cfg.n_layers

    def fit(c2, c4):
        b = (c4 - c2) / 2.0
        return max(c2 - 2.0 * b + b * L, 0.0)

    f, m, w = (fit(samples[0][i], samples[1][i]) for i in range(3))
    kinds = {kk: fit(samples[0][3].get(kk, 0.0), samples[1][3].get(kk, 0.0))
             for kk in set(samples[0][3]) | set(samples[1][3])}
    cross = fit(samples[0][4], samples[1][4])
    return f, m, w, kinds, cross


def lower_cell(arch: str, shape_name: str, mesh_name: str,
               variant: str = "baseline",
               overrides: Optional[Dict[str, Any]] = None,
               probe: bool = True,
               rules_overrides: Optional[Dict[str, Any]] = None):
    """Lower + compile one cell; returns (CellReport, compiled).

    The production artifact (scan-over-layers) provides the compile proof +
    memory analysis; unrolled k-layer probes provide loop-corrected cost
    terms when the model scans (see probe_costs).
    """
    shape = SHAPES[shape_name]
    mesh = _mesh_from_name(mesh_name)
    cfg = _prepare_cfg(get_config(arch), shape, overrides)
    rules = _rules_for(cfg, mesh, shape)
    if rules_overrides:
        rules.update(rules_overrides)
    t0 = time.time()
    probe_note = "costs=unrolled-exact"
    probe_kinds = None
    cross_pod = None
    with use_sharding(mesh, rules):
        compiled, step_kind = _lower_one(cfg, shape, mesh)
        costs = analyze_compiled(
            compiled, mesh.size,
            pod_size=POD_SIZE if mesh_name == "2x16x16" else 0)
        if probe and cfg.scan_layers:
            try:
                f, m, w, probe_kinds, cross_pod = probe_costs(
                    cfg, shape, mesh, mesh_name)
                costs = dataclasses.replace(
                    costs, flops=f, mem_bytes=m, wire_bytes=w)
                probe_note = "costs=unroll-probe-fit"
            except Exception as e:  # noqa: BLE001 — probe is best-effort
                probe_note = f"costs=scan-counted(probe-failed:{type(e).__name__})"
    wall = time.time() - t0
    total, active = sp.param_counts(cfg)
    cross_note = ""
    if mesh_name == "2x16x16":
        cp = (cross_pod if cross_pod is not None
              else costs.collectives.cross_pod_wire_bytes)
        cross_note = f";cross_pod={cp/1e9:.3f}GB"
    report = make_cell_report(
        arch=arch, shape=shape_name, mesh=mesh_name, step_kind=step_kind,
        costs=costs, hw=TPU_V5E, model_flops=sp.model_flops(cfg, shape),
        params_total=total, params_active=active,
        tokens_per_step=(shape.global_batch * shape.seq_len
                         if shape.kind != "decode" else shape.global_batch),
        variant=variant, wall_compile_s=wall,
        notes=probe_note + cross_note)
    if probe_kinds is not None:
        report.wire_bytes_by_kind = probe_kinds
    return report, compiled


#: params above this count get FSDP (param DP-sharding) in the baseline —
#: fp32 master + grads of a >8B model don't fit 16 GiB at TP=16 alone.
FSDP_THRESHOLD = 8e9


def _lower_train(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 zero1: bool = True, fsdp: Optional[bool] = None,
                 n_micro: int = 1):
    opt = AdamW(learning_rate=1e-3)
    train_step = build_train_step(cfg, opt, TrainStepConfig(n_micro=n_micro))
    if fsdp is None:
        total, _ = sp.param_counts(cfg)
        fsdp = total > FSDP_THRESHOLD
    state_abs = sp.abstract_train_state(cfg, opt)
    state_sds = sp.attach(
        state_abs, sp.train_state_specs(cfg, zero1=zero1, fsdp=fsdp), mesh)
    batch_sds = sp.input_specs(cfg, shape, mesh)
    lowered = jax.jit(train_step, donate_argnums=(0,)).lower(state_sds, batch_sds)
    return lowered.compile(), "train_step"


def _bf16(tree):
    """Serving runs from bf16 weights (production standard): halves the
    per-device parameter footprint of the decode/prefill cells."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16, sharding=l.sharding)
        if l.dtype == jnp.float32 else l, tree)


def _lower_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh):
    from repro.models import transformer as lm_mod
    from repro.models import encdec as encdec_mod
    from repro.models import vlm as vlm_mod

    params_abs = sp.abstract_params(cfg)
    from repro.train.loop import model_param_specs
    params_sds = _bf16(sp.attach(params_abs, model_param_specs(cfg), mesh))
    batch = sp.input_specs(cfg, shape, mesh)

    if cfg.family == "encdec":
        fn = lambda p, b: encdec_mod.forward(p, b["tokens"], b["frames"], cfg)[0]
    elif cfg.family == "vlm":
        fn = lambda p, b: vlm_mod.forward(p, b["tokens"], b["patches"], cfg)[0]
    else:
        fn = lambda p, b: lm_mod.forward(p, b["tokens"], cfg)[0]
    lowered = jax.jit(fn).lower(params_sds, batch)
    return lowered.compile(), "prefill_step"


def _lower_decode(cfg: ModelConfig, shape: ShapeSpec, mesh):
    from repro.train.loop import model_param_specs

    params_abs = sp.abstract_params(cfg)
    params_sds = _bf16(sp.attach(params_abs, model_param_specs(cfg), mesh))
    cache_abs = sp.abstract_cache(cfg, params_abs, shape)
    cache_sds = sp.attach(cache_abs, sp.cache_logical_specs(cfg, cache_abs),
                          mesh)
    dec = sp.decode_input_specs(cfg, shape, mesh)
    serve_step = serve_engine.build_serve_step(cfg)
    lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
        params_sds, dec["tokens"], cache_sds, dec["pos"])
    return lowered.compile(), "serve_step"


def run_cell(arch: str, shape_name: str, mesh_name: str, force: bool = False,
             variant: str = "baseline",
             overrides: Optional[Dict[str, Any]] = None,
             rules_overrides: Optional[Dict[str, Any]] = None) -> CellReport:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(
        ARTIFACTS, f"{arch}__{shape_name}__{mesh_name}__{variant}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return CellReport.from_json(f.read())
    report, compiled = lower_cell(arch, shape_name, mesh_name,
                                  variant=variant, overrides=overrides,
                                  rules_overrides=rules_overrides)
    print(compiled.memory_analysis())
    report.save(ARTIFACTS)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="16x16",
                    help="16x16 | 2x16x16 | both | any AxB split (variants)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ModelConfig override, e.g. --set attn_impl=chunked")
    ap.add_argument("--rule", action="append", default=[], metavar="K=V",
                    help="sharding-rule override, e.g. --rule seq=none")
    args = ap.parse_args(argv)

    def _coerce(v: str):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                pass
        return {"true": True, "false": False}.get(v.lower(), v)

    overrides = dict(kv.split("=", 1) for kv in args.set)
    overrides = {k: _coerce(v) for k, v in overrides.items()} or None
    rules_ov = {k: (None if v.lower() == "none" else v)
                for k, v in (kv.split("=", 1) for kv in args.rule)} or None

    meshes = ["16x16", "2x16x16"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ASSIGNED
                 for s in SHAPES
                 if applicable(get_config(a).family, s)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for mesh_name in meshes:
        for arch, shape_name in cells:
            key = f"{arch} × {shape_name} × {mesh_name}"
            try:
                t0 = time.time()
                rep = run_cell(arch, shape_name, mesh_name, force=args.force,
                               variant=args.variant, overrides=overrides,
                               rules_overrides=rules_ov)
                print(f"[OK {time.time()-t0:7.1f}s] {key}: "
                      f"{rep.bottleneck}-bound, runtime {rep.runtime:.3e}s, "
                      f"{100*rep.peak_fraction:.1f}% peak, "
                      f"mem/dev {rep.peak_memory_per_device/2**30:.2f} GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report all cell failures
                failures.append((key, repr(e)))
                traceback.print_exc()
                print(f"[FAIL] {key}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for k, e in failures:
            print(f"  {k}: {e}")
        return 1
    print(f"\nall {len(cells) * len(meshes)} cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
