"""Per-chip working-set model: what a candidate mesh must *hold*, in bytes.

The Ridgeline bounds a candidate's step *time*; this module bounds whether
the candidate can execute at all.  ``HardwareSpec.hbm_capacity_bytes`` is
the per-chip budget, and the planner (``launch/plan_grid``) prunes every
(dp × tp × pp × m × zero) candidate whose modeled footprint exceeds it —
*before* the broadcast pricing passes, so infeasible candidates cost
nothing downstream.

Training footprint per chip (fp32 master weights + AdamW, matching
``models/common`` / ``optim/optimizer``):

    params      4 B/param · N_ep / (tp·pp)         [/ dp at ZeRO-3]
    grads       4 B/param · N_ep / (tp·pp)         [/ dp at ZeRO-2+]
    optimizer   8 B/param · N_ep / (tp·pp)  (μ+ν)  [/ dp at ZeRO-1+]
    activations coeff · ceil(L/pp) · tokens/(dp·m) · width · act_B / tp
                  · min(m, pp)  in-flight 1F1B microbatches

where ``N_ep = (N − N_experts) + N_experts/ep``: the routed expert
tensors (``launch/specs.expert_param_counts``) shard across the
expert-parallel axis while the dense remainder replicates over it, and
``ceil(L/pp)`` charges the widest stage when pp ∤ n_layers (uneven
ceil-split; exact L/pp when pp divides the stack).

where ``coeff`` is 2 saved boundary tensors per layer, dropping to 1 under
rematerialization (only the block boundary survives; everything else is
recomputed in backward at +1/3 step FLOPs — the planner's ``--remat``
moves candidates along the ridgeline, trading this footprint for compute).
The activation term shards by tp because the sharding layer runs
Megatron-SP (``launch/dryrun._rules_for``: saved residual-stream
activations shard their seq axis over the model axis).

ZeRO stages shard *state* across the dp axis (Rajbhandari et al.):
stage 1 the optimizer moments, stage 2 also the gradients, stage 3 also
the parameters.  The wire-byte price of the extra all-gather /
reduce-scatter traffic lives in ``distributed/collectives.zero_dp_sync``;
this module only accounts the bytes *resident*.

Decode (serving) footprint per chip: bf16 weights ``2·N/(tp·pp)`` plus the
KV cache ``(L/pp) · (batch/dp) · seq · 2 · kv_dim · 2 B / tp`` — no grads,
no optimizer states.

Everything is NumPy-vectorized: every mesh argument broadcasts, so the
whole planner candidate set prices its footprint in one pass, aligned
elementwise with ``plan_grid``'s struct-of-arrays.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Union

import numpy as np

from repro.analysis.contracts import shape_contract

if TYPE_CHECKING:  # jax-backed; the accounting itself is numpy-only
    from repro.models.common import ModelConfig

ArrayLike = Union[int, float, np.ndarray]

#: bytes per parameter, training (fp32 master weights — models/common keeps
#: param_dtype fp32; mixed precision casts activations, not weights)
PARAM_BYTES = 4.0
#: bytes per gradient element (optim/optimizer casts grads to fp32)
GRAD_BYTES = 4.0
#: bytes of AdamW optimizer state per parameter (μ + ν, both fp32)
OPT_BYTES = 8.0
#: bytes per parameter when serving (bf16 inference weights)
SERVE_PARAM_BYTES = 2.0
#: KV-cache element bytes (bf16 K and V)
KV_BYTES = 2.0

#: saved boundary activations per layer: 2 normally, 1 under remat
ACT_COEFF = 2.0
ACT_COEFF_REMAT = 1.0

#: extra step FLOPs under remat: backward recomputes the forward, taking
#: the classic 6·N·tokens accounting to 8·N·tokens
REMAT_FLOPS_FACTOR = 4.0 / 3.0


def _as_f64(x: ArrayLike) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def _act_bytes_per_token(cfg: ModelConfig) -> float:
    """Activation element bytes: fp32 MLP tower, bf16 everything else
    (mirrors the planner's ``act_dtype`` traffic accounting)."""
    return 4.0 if cfg.family == "mlp" else 2.0


def _model_width(cfg: ModelConfig) -> int:
    return cfg.mlp_widths[0] if cfg.family == "mlp" else cfg.d_model


def _tokens(cfg: ModelConfig, batch: np.ndarray, seq: float) -> np.ndarray:
    return batch if cfg.family == "mlp" else batch * float(seq)


@dataclasses.dataclass(frozen=True)
class WorkingSet:
    """Per-chip resident bytes, decomposed; every field broadcasts."""

    params: np.ndarray
    grads: np.ndarray
    opt: np.ndarray
    activations: np.ndarray
    kv_cache: np.ndarray

    @property
    def total(self) -> np.ndarray:
        return (self.params + self.grads + self.opt + self.activations
                + self.kv_cache)

    @property
    def persisted(self) -> np.ndarray:
        """Per-chip bytes a checkpoint must write: params + optimizer
        states (grads and activations are transient — the checkpointer
        saves exactly the ``TrainState`` leaves that survive a restart).
        Under ZeRO/tp/pp/ep sharding each chip persists only its own
        shard, which is what makes checkpoint time mesh-dependent
        (``repro.resilience.failures.ckpt_time_s``)."""
        return self.params + self.opt


@shape_contract("batch:(*g), dp:(*g), tp:(*g), pp:(*g), ep:(*g), "
                "microbatches:(*g), zero_stage:(*g) -> (*g)")
def training_working_set(cfg: ModelConfig, *, batch: ArrayLike,
                         seq: int = 1, dp: ArrayLike = 1, tp: ArrayLike = 1,
                         pp: ArrayLike = 1, ep: ArrayLike = 1,
                         microbatches: ArrayLike = 1,
                         zero_stage: ArrayLike = 0,
                         remat: bool = False) -> WorkingSet:
    """Per-chip training footprint of a (dp, tp, pp, ep, m, zero) candidate.

    All mesh arguments broadcast elementwise (the planner passes its flat
    candidate arrays); scalars price one candidate.  ``zero_stage`` shards
    optimizer states (≥1), gradients (≥2), parameters (≥3) across dp.
    ``ep`` shards the routed expert tensors (and their grads/optimizer
    states, via the same ``shard`` slice) across the expert-parallel axis;
    the dense remainder — attention, router, shared experts — replicates
    over ep exactly as before, so ep = 1 reproduces the prior accounting
    bit-for-bit.
    """
    from repro.launch.plan_grid import param_counts
    n_total, _ = param_counts(cfg)
    dp = _as_f64(dp)
    tp = _as_f64(tp)
    pp = _as_f64(pp)
    ep = _as_f64(ep)
    m = _as_f64(microbatches)
    zero = _as_f64(zero_stage)
    batch = _as_f64(batch)

    shard = n_total / (tp * pp)                 # this chip's model slice
    if (ep > 1.0).any():
        from repro.launch.specs import expert_param_counts
        e_total, _ = expert_param_counts(cfg)
        if e_total > 0.0:
            # the np.where overlay leaves every ep = 1 lane bit-untouched
            shard = np.where(
                ep > 1.0,
                ((n_total - e_total) + e_total / ep) / (tp * pp), shard)
    params = PARAM_BYTES * shard / np.where(zero >= 3, dp, 1.0)
    grads = GRAD_BYTES * shard / np.where(zero >= 2, dp, 1.0)
    opt = OPT_BYTES * shard / np.where(zero >= 1, dp, 1.0)

    tokens = _tokens(cfg, batch, seq)
    coeff = ACT_COEFF_REMAT if remat else ACT_COEFF
    inflight = np.minimum(m, pp)                # 1F1B holds ≤ pp microbatches
    # ceil: when pp ∤ n_layers the widest (first) stages bound the budget
    acts = (coeff * np.ceil(float(cfg.n_layers) / pp)
            * (tokens / (dp * m)) * float(_model_width(cfg))
            * _act_bytes_per_token(cfg) / tp * inflight)
    zeros = np.zeros(np.broadcast_shapes(params.shape, acts.shape))
    return WorkingSet(params=params + zeros, grads=grads + zeros,
                      opt=opt + zeros, activations=acts + zeros,
                      kv_cache=zeros)


@shape_contract("batch:(*g), dp:(*g), tp:(*g), pp:(*g) -> (*g)")
def decode_working_set(cfg: ModelConfig, *, batch: ArrayLike, seq: int,
                       dp: ArrayLike = 1, tp: ArrayLike = 1,
                       pp: ArrayLike = 1) -> WorkingSet:
    """Per-chip serving footprint: bf16 weights + the decode KV cache.

    The cache shards its batch over dp, its layers over pp, and (SP-decode,
    see ``launch/dryrun``) its seq axis over tp.  Families without
    attention KV (``kv_dim == 0``, e.g. the MLP tower) carry no cache.
    """
    from repro.launch.plan_grid import param_counts
    n_total, _ = param_counts(cfg)
    dp = _as_f64(dp)
    tp = _as_f64(tp)
    pp = _as_f64(pp)
    batch = _as_f64(batch)

    params = SERVE_PARAM_BYTES * n_total / (tp * pp)
    kv_dim = float(cfg.kv_dim) if cfg.n_heads else 0.0
    kv = ((float(cfg.n_layers) / pp) * (batch / dp) * float(seq)
          * 2.0 * kv_dim * KV_BYTES / tp)
    zeros = np.zeros(np.broadcast_shapes(params.shape, kv.shape))
    return WorkingSet(params=params + zeros, grads=zeros, opt=zeros,
                      activations=zeros, kv_cache=kv + zeros)


@shape_contract("batch:(*g), dp:(*g), tp:(*g), pp:(*g), ep:(*g), "
                "microbatches:(*g) -> (*g)")
def min_zero_stage(cfg: ModelConfig, capacity_bytes: float, *,
                   batch: ArrayLike, seq: int = 1, dp: ArrayLike = 1,
                   tp: ArrayLike = 1, pp: ArrayLike = 1, ep: ArrayLike = 1,
                   microbatches: ArrayLike = 1,
                   remat: bool = False) -> np.ndarray:
    """Smallest ZeRO stage at which each candidate fits; 4 when none does.

    Footprint is non-increasing in the stage (each stage shards strictly
    more state across dp), so the answer is the first of 0..3 that fits.
    ``capacity_bytes <= 0`` (unknown) makes everything stage 0.
    """
    shape = np.broadcast_shapes(*(np.shape(_as_f64(a)) for a in
                                  (batch, dp, tp, pp, ep, microbatches)))
    if capacity_bytes <= 0:
        return np.zeros(shape, dtype=np.int64)
    totals = np.stack([
        training_working_set(cfg, batch=batch, seq=seq, dp=dp, tp=tp, pp=pp,
                             ep=ep, microbatches=microbatches,
                             zero_stage=stage, remat=remat).total
        for stage in range(4)])
    fits = totals <= capacity_bytes
    return np.where(fits.any(axis=0), fits.argmax(axis=0), 4).astype(np.int64)
