"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  The production target is a TPU v5e pod of 16×16 = 256
chips; multi-pod doubles it with a leading "pod" axis (2 × 256 = 512 chips)
riding data-center interconnect (see core/hardware.py extra_links).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests / elastic-reshard experiments."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Single-device mesh (CPU smoke tests): both axes size 1."""
    return make_mesh((1, 1), ("data", "model"))
