"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  The production target is a TPU v5e pod of 16×16 = 256
chips; multi-pod doubles it with a leading "pod" axis (2 × 256 = 512 chips)
riding data-center interconnect (see core/hardware.py extra_links).

``AxisType`` landed in jax 0.5 (explicit-sharding work); on older jax every
mesh axis is implicitly Auto, so the fallback simply omits the argument.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType

    def _auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax <= 0.4.x: axes are Auto by default
    AxisType = None

    def _auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests / elastic-reshard experiments."""
    return _auto_mesh(shape, axes)


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-free AbstractMesh across the 0.4/0.5 constructor change.

    jax >= 0.5 takes ``(axis_sizes, axis_names)``; 0.4.x takes a single
    tuple of ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_host_mesh() -> Mesh:
    """Single-device mesh (CPU smoke tests): both axes size 1."""
    return make_mesh((1, 1), ("data", "model"))
