"""Parallelism planner: rank (dp, tp) meshes by Ridgeline-projected step time.

``plan(cfg, hw, chips, ...)`` enumerates every feasible ``dp × tp``
factorization of the chip budget, derives each candidate's per-chip
Ridgeline terms analytically —

  F    = 6 · N_active · tokens / (dp·tp)
  B_M  = params_bytes/tp  +  2 · L · boundary_act_bytes      (weights + acts)
  t_N  = DP grad all-reduce (params_bytes/tp over dp)
         + TP activation all-reduces (2×/layer MLP, 4×/layer attention),
         each priced α–β on the *link its mesh axis rides*:
         α(link)·steps + bytes/bandwidth(link)

— with collective wire bytes and hop counts coming from
``repro.distributed.collectives`` under the chosen algorithm, then evaluates
the whole candidate set in one :mod:`repro.core.sweep` pass and ranks by the
projected bound runtime.  With ``pod_size`` set, an axis whose ring extends
past one pod is priced at the ``pod`` link's (slower) bandwidth — the
slowest hop bounds a ring — instead of full ICI for everything, which is
what used to rank multi-pod dp meshes too optimistically.  A size-1 mesh
axis has no collective at all and is skipped outright — it pays neither
bytes nor α·steps.  Everything is closed-form + ``jax.eval_shape`` (for
exact parameter counts), so planning needs no accelerator and runs in
seconds.

**Algorithm selection.**  The collective *algorithm* is part of the cost
model: with a per-hop α, a log-step tree all-reduce beats rings below some
payload and a bandwidth-optimal ring wins above it.  The default
``"auto"`` picks the α–β argmin per mesh axis via
``collectives.best_all_reduce`` — each candidate's dp and tp axes may
select different algorithms (``MeshPlan.dp_algo``/``tp_algo``).  A concrete
algorithm name prices every axis with it, and ``--algo all`` enumerates
every algorithm as its own ranked candidate and reports the per-axis/link
flip payloads (``flip_points``).

Calibrated specs carry a ``model_rel_error`` (median |model-vs-measured|
on whole-step validation points); each ranked plan widens its point
estimate into the uncertainty band ``[runtime·(1−e), runtime·(1+e)]``.
Their size-dependent ``compute_eff`` ceiling flows through the sweep
automatically.

CLI::

    python -m repro.launch.plan --arch dlrm-mlp --chips 16
    python -m repro.launch.plan --arch dlrm-mlp --chips 32 --pod-size 16
    python -m repro.launch.plan --arch qwen2-7b --chips 32 --algo all
    python -m repro.launch.plan --arch dlrm-mlp --chips 16 --calibrated --json
    python -m repro.launch.plan --hardware list

``--hardware`` accepts any name from ``core.hardware.list_hardware()``
(datasheet presets and calibrated registry entries alike; ``list`` prints
them); ``--calibrated`` swaps in the measured twin of the named preset, so
rankings use achievable rather than vendor ceilings.  ``--json`` emits the
full ranking machine-readably for scripting.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import sweep as sweep_mod
from repro.core.hardware import HardwareSpec, get_hardware, list_hardware
from repro.core.report import CellReport, roofline_table
from repro.distributed import collectives

if TYPE_CHECKING:  # jax-backed; planning itself is numpy-only
    from repro.models.common import ModelConfig

#: families with attention/MoE blocks -> Megatron-style 4 syncs per layer
_ATTENTION_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


#: display shorthand for algorithm tags (table column stays narrow)
_ALGO_SHORT = {"ring": "ring", "bidir_ring": "bidir", "tree": "tree"}


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One ranked candidate: the mesh, its terms, and its projection."""

    dp: int
    tp: int
    algorithm: str               # requested: a concrete tag or "auto"
    flops: float                 # per chip
    mem_bytes: float
    net_bytes: float             # wire bytes across all axes
    t_compute: float
    t_memory: float
    t_network: float             # α–β time, per-axis links
    runtime: float               # projected step time (bound)
    bottleneck: str
    peak_fraction: float
    net_steps: float = 0.0       # serialized hops across all axes
    dp_link: str = "ici"         # link the dp grad sync rides
    tp_link: str = "ici"         # link the tp act syncs ride
    dp_algo: str = "ring"        # algorithm the dp grad sync uses ("-" when
    #                              the axis is size 1: no collective runs)
    tp_algo: str = "ring"        # algorithm the tp act syncs use
    runtime_lo: float = 0.0      # runtime·(1−e), e = hw.model_rel_error
    runtime_hi: float = 0.0      # runtime·(1+e); lo == hi == runtime when
    #                              the spec carries no measured error

    @property
    def chips(self) -> int:
        return self.dp * self.tp

    @property
    def mesh(self) -> str:
        return f"dp{self.dp}xtp{self.tp}"

    @property
    def algo_label(self) -> str:
        """Selected algorithms, compact: one tag when the axes agree."""
        axes = [_ALGO_SHORT.get(a, a) for a in (self.dp_algo, self.tp_algo)
                if a != "-"]
        if not axes:
            return "-"
        if len(set(axes)) == 1:
            return axes[0]
        return "+".join(axes)


def _factor_pairs(chips: int) -> List[Tuple[int, int]]:
    return [(chips // t, t) for t in range(1, chips + 1) if chips % t == 0]


def _model_width(cfg: ModelConfig) -> int:
    return cfg.mlp_widths[0] if cfg.family == "mlp" else cfg.d_model


def feasible_meshes(cfg: ModelConfig, chips: int,
                    batch: int) -> List[Tuple[int, int]]:
    """(dp, tp) with dp·tp == chips, dp | batch and tp | model width."""
    width = _model_width(cfg)
    return [(dp, tp) for dp, tp in _factor_pairs(chips)
            if batch % dp == 0 and width % tp == 0]


def param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameter counts; closed-form for the MLP family.

    The MLP tower is counted without jax so the planner CLI stays fast on a
    bare CPU box; every other family defers to the eval_shape-exact
    accounting in ``launch/specs``.
    """
    if cfg.family == "mlp":
        widths = cfg.mlp_widths
        n = 0.0
        for i, w in enumerate(widths):
            d_in = widths[i - 1] if i else widths[0]
            n += d_in * w + w
        n += widths[-1] * 1 + 1                     # head
        return n, n
    from repro.launch.specs import param_counts as exact
    return exact(cfg)


#: mesh-axis tag of the inter-pod link in ``HardwareSpec.extra_links``
POD_LINK = "pod"


def _axis_link(axis: int, inner: int, pod_size: Optional[int],
               hw: HardwareSpec) -> Optional[str]:
    """Link a ring over ``axis`` chips (stride ``inner``) is priced at.

    The mesh is laid out tp-inner / dp-outer.  A ring whose extent
    ``axis·inner`` exceeds the pod crosses a pod boundary somewhere, and a
    ring runs at its slowest hop — so the whole axis is priced at the
    ``pod`` link.  Returns None (primary link) for intra-pod axes, trivial
    axes, or when no ``pod_size`` is given.
    """
    if pod_size is None or axis <= 1 or axis * inner <= pod_size:
        return None
    hw.bandwidth_for(POD_LINK)      # actionable KeyError if the spec has none
    return POD_LINK


def _axis_collective(payload: float, n: int, link: Optional[str],
                     hw: HardwareSpec, algo: str, *, scale: float = 1.0
                     ) -> Tuple[str, "collectives.CollectiveCost"]:
    """(selected algorithm, cost) of one mesh axis's all-reduce traffic.

    ``algo == "auto"`` picks the α–β argmin for this axis's payload on the
    link it rides.  A size-1 axis runs no collective at all: zero bytes,
    zero hops, **zero α** — and reports its algorithm as ``"-"`` so nobody
    mistakes a no-op for a priced ring.
    """
    if n <= 1:
        return "-", collectives.CollectiveCost(0.0, 0.0).scaled(scale)
    if algo == "auto":
        picked, cost = collectives.best_all_reduce(
            payload, n, hw.bandwidth_for(link), hw.alpha_for(link))
    else:
        picked = collectives.canonical_algorithm(algo)
        cost = collectives.all_reduce(payload, n, picked)
    return picked, cost.scaled(scale)


def plan(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
         batch: int, seq: int = 1,
         algorithms: Sequence[str] = ("auto",),
         pod_size: Optional[int] = None) -> List[MeshPlan]:
    """Rank every feasible (dp, tp, algorithm) by projected step time.

    ``pod_size`` (chips per pod) routes each mesh axis onto the link it
    actually rides: axes contained in one pod use primary ICI, axes that
    span pods use the slower ``pod`` entry of ``hw.extra_links``.

    ``algorithms`` entries are concrete collective tags (including the
    ``bidir`` alias) or ``"auto"`` (the default): per-axis α–β argmin over
    the full menu, so the dp grad sync and the tp act syncs can pick
    different algorithms on the same candidate.
    """
    n_total, n_active = param_counts(cfg)
    tokens = float(batch) if cfg.family == "mlp" else float(batch) * seq
    width = _model_width(cfg)
    act_dtype = 4 if cfg.family == "mlp" else 2     # fp32 MLP, bf16 LMs
    syncs = 4.0 if cfg.family in _ATTENTION_FAMILIES else 2.0
    params_bytes = n_total * 4.0                    # fp32 master weights

    meshes = feasible_meshes(cfg, chips, batch)
    if not meshes:
        raise ValueError(
            f"no feasible (dp, tp) for chips={chips}, batch={batch}, "
            f"width={width}")
    cands = [(dp, tp, algo) for dp, tp in meshes for algo in algorithms]
    dp = np.array([c[0] for c in cands], dtype=np.float64)
    tp = np.array([c[1] for c in cands], dtype=np.float64)

    flops = 6.0 * n_active * tokens / (dp * tp)
    act_bytes = (tokens / dp) * width * act_dtype   # one boundary activation
    mem_bytes = params_bytes / tp + 2.0 * cfg.n_layers * act_bytes
    net_bytes = np.empty_like(dp)
    net_steps = np.empty_like(dp)
    t_network = np.empty_like(dp)
    links: List[Tuple[str, str]] = []
    algos: List[Tuple[str, str]] = []
    for i, (d, t, algo) in enumerate(cands):
        dp_link = _axis_link(d, t, pod_size, hw)    # dp outer, strides tp
        tp_link = _axis_link(t, 1, pod_size, hw)    # tp inner
        dp_algo, dp_cost = _axis_collective(params_bytes / t, d, dp_link,
                                            hw, algo)
        tp_algo, tp_cost = _axis_collective(act_bytes[i], t, tp_link,
                                            hw, algo,
                                            scale=syncs * cfg.n_layers)
        t_network[i] = (
            dp_cost.time(hw.bandwidth_for(dp_link), hw.alpha_for(dp_link))
            + tp_cost.time(hw.bandwidth_for(tp_link),
                           hw.alpha_for(tp_link)))
        net_bytes[i] = float(dp_cost.wire_bytes) + float(tp_cost.wire_bytes)
        net_steps[i] = float(dp_cost.steps) + float(tp_cost.steps)
        links.append((dp_link or "ici", tp_link or "ici"))
        algos.append((dp_algo, tp_algo))
    # fold per-axis α–β network time into primary-link-equivalent bytes so
    # one vectorized sweep classifies the whole candidate set consistently
    eff_net_bytes = t_network * hw.net_bw
    res = sweep_mod.sweep(flops, mem_bytes, eff_net_bytes, hw, net_steps=0.0)
    labels = res.labels()

    err = max(float(hw.model_rel_error), 0.0)
    plans = [MeshPlan(dp=c[0], tp=c[1], algorithm=c[2],
                      flops=float(res.flops[i]),
                      mem_bytes=float(res.mem_bytes[i]),
                      net_bytes=float(net_bytes[i]),
                      t_compute=float(res.t_compute[i]),
                      t_memory=float(res.t_memory[i]),
                      t_network=float(res.t_network[i]),
                      runtime=float(res.runtime[i]),
                      bottleneck=str(labels[i]),
                      peak_fraction=float(res.peak_fraction[i]),
                      net_steps=float(net_steps[i]),
                      dp_link=links[i][0], tp_link=links[i][1],
                      dp_algo=algos[i][0], tp_algo=algos[i][1],
                      runtime_lo=max(float(res.runtime[i]) * (1.0 - err),
                                     0.0),
                      runtime_hi=float(res.runtime[i]) * (1.0 + err))
             for i, c in enumerate(cands)]
    return sorted(plans, key=lambda p: (p.runtime, p.tp))


def flip_points(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
                batch: int, pod_size: Optional[int] = None) -> List[dict]:
    """Per mesh axis/link: where the best all-reduce algorithm flips.

    One row per distinct (axis kind, group size, link) among the feasible
    meshes, with the α–β flip payload from
    ``collectives.all_reduce_flip_payload``: the small-payload winner
    (log-step tree once α > 0) hands over to the bandwidth-optimal ring
    at ``flip_payload_bytes``.  ``None`` flip means one algorithm dominates
    every payload (e.g. α = 0); size-1 axes run no collective and are
    skipped.
    """
    seen = set()
    rows: List[dict] = []
    for d, t in feasible_meshes(cfg, chips, batch):
        for kind, n, inner in (("dp", d, t), ("tp", t, 1)):
            link = _axis_link(n, inner, pod_size, hw)
            key = (kind, n, link)
            if n <= 1 or key in seen:
                continue
            seen.add(key)
            bw, alpha = hw.bandwidth_for(link), hw.alpha_for(link)
            flip = collectives.all_reduce_flip_payload(n, bw, alpha)
            rows.append({
                "axis": kind, "group_size": n, "link": link or "ici",
                "bandwidth": bw, "alpha": alpha,
                "flip_payload_bytes": None if flip is None else flip[0],
                "small_payload_algo": None if flip is None else flip[1],
                "large_payload_algo": None if flip is None else flip[2],
            })
    return sorted(rows, key=lambda r: (r["axis"], r["group_size"]))


def best_step_time(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
                   batch: int, seq: int = 1,
                   algorithms: Sequence[str] = ("auto",),
                   pod_size: Optional[int] = None) -> float:
    return plan(cfg, hw, chips, batch=batch, seq=seq,
                algorithms=algorithms, pod_size=pod_size)[0].runtime


def to_cell_reports(arch: str, plans: Sequence[MeshPlan], hw: HardwareSpec,
                    *, batch: int, tokens: float, params_total: float,
                    params_active: float) -> List[CellReport]:
    """Planner candidates as the standard per-cell report artifact.

    ``wire_bytes`` are primary-link-equivalent (``t_network · net_bw``) so
    the report's projection matches the plan's per-axis α–β pricing; the
    raw per-axis wire bytes ride along in ``wire_bytes_by_kind``.
    """
    reports = []
    for p in plans:
        rep = CellReport(
            arch=arch, shape=f"plan_b{batch}", mesh=p.mesh,
            step_kind="train_step", num_devices=p.chips, hardware=hw.name,
            flops=p.flops, mem_bytes=p.mem_bytes,
            wire_bytes=p.t_network * hw.net_bw,
            wire_bytes_by_kind={"analytic-dp+tp": p.net_bytes},
            peak_memory_per_device=0.0,
            model_flops=6.0 * params_active * tokens,
            params_total=params_total, params_active=params_active,
            tokens_per_step=tokens, variant=p.algo_label,
            notes=f"rank by plan; {p.algorithm}->{p.algo_label}; links "
                  f"{p.dp_link}/{p.tp_link}")
        reports.append(rep.finalize(hw))
    return reports


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.3f}"


def format_plan_table(plans: Sequence[MeshPlan]) -> str:
    banded = any(p.runtime_hi > p.runtime for p in plans)
    head = (f"{'rank':>4} {'mesh':>12} {'algo':>10} {'t_comp ms':>9} "
            f"{'t_mem ms':>9} {'t_net ms':>9} {'step ms':>9} "
            + (f"{'band ms':>19} " if banded else "")
            + f"{'links':>9} {'bottleneck':>10} {'peak%':>6}")
    lines = [head, "-" * len(head)]
    for i, p in enumerate(plans):
        band = (f"{_fmt_ms(p.runtime_lo)}..{_fmt_ms(p.runtime_hi).strip():<8} "
                if banded else "")
        link = p.dp_link if p.dp_link == p.tp_link else \
            f"{p.dp_link}/{p.tp_link}"
        lines.append(
            f"{i + 1:>4} {p.mesh:>12} {p.algo_label:>10} "
            f"{_fmt_ms(p.t_compute)} {_fmt_ms(p.t_memory)} "
            f"{_fmt_ms(p.t_network)} {_fmt_ms(p.runtime)} "
            + band
            + f"{link:>9} {p.bottleneck:>10} {100 * p.peak_fraction:5.1f}%")
    return "\n".join(lines)


def format_flip_table(rows: Sequence[dict]) -> str:
    """Human-readable flip-point report (the ``--algo all`` extra)."""
    out = ["# all-reduce algorithm flip points (per mesh axis / link)"]
    if not rows:
        return "\n".join(out + ["  (no multi-chip axes)"])
    for r in rows:
        where = (f"  {r['axis']:>3} axis n={r['group_size']:<4} "
                 f"link={r['link']:<4} "
                 f"(bw {r['bandwidth']:.3g} B/s, alpha {r['alpha']:.3g} s)")
        if r["flip_payload_bytes"] is None:
            out.append(where + ": no flip (one algorithm dominates)")
        else:
            out.append(
                where + f": {r['small_payload_algo']} below "
                f"{r['flip_payload_bytes']:.4g} B, "
                f"{r['large_payload_algo']} above")
    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan",
        description="Rank (dp, tp) meshes by Ridgeline-projected step time.")
    ap.add_argument("--arch")
    ap.add_argument("--chips", type=int)
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default: 512 MLP / 256 LM)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--hardware", default="tpu_v5e",
                    help="spec name (datasheet preset or calibrated registry "
                         "entry), or 'list' to enumerate all of them")
    ap.add_argument("--calibrated", action="store_true",
                    help="use the calibrated twin of --hardware "
                         "(artifacts/calibration)")
    ap.add_argument("--pod-size", type=int, default=None,
                    help="chips per pod; mesh axes spanning pods are priced "
                         "at the spec's 'pod' link instead of primary ICI")
    ap.add_argument("--algo", default="auto",
                    choices=sorted(collectives.ALGORITHM_ALIASES)
                    + list(collectives.ALGORITHMS) + ["auto", "all"],
                    help="collective algorithm: a concrete tag, 'auto' "
                         "(per-axis α–β argmin, the default), or 'all' "
                         "(rank every algorithm and report flip points)")
    ap.add_argument("--top", type=int, default=0,
                    help="show only the best N candidates (0 = all)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (full ranking + spec)")
    args = ap.parse_args(argv)

    if args.hardware == "list":
        specs = list_hardware()
        if args.as_json:
            print(json.dumps(
                {name: {"source": src,
                        **dataclasses.asdict(get_hardware(name))}
                 for name, src in sorted(specs.items())}, indent=1))
        else:
            print(f"{'name':>16} {'source':>12} {'peak FLOP/s':>12} "
                  f"{'HBM B/s':>10} {'NET B/s':>10}")
            for name, src in sorted(specs.items()):
                s = get_hardware(name)
                print(f"{name:>16} {src:>12} {s.peak_flops:>12.3g} "
                      f"{s.hbm_bw:>10.3g} {s.net_bw:>10.3g}")
        return 0
    if args.arch is None or args.chips is None:
        ap.error("--arch and --chips are required (unless --hardware list)")

    from repro.configs import get_config, list_archs
    try:
        cfg = get_config(args.arch)
    except KeyError:
        print(f"unknown arch {args.arch!r}; have: {', '.join(list_archs())}",
              file=sys.stderr)
        return 2
    try:
        hw = get_hardware(args.hardware, calibrated=args.calibrated)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    batch = args.batch if args.batch is not None else (
        512 if cfg.family == "mlp" else 256)
    algos = collectives.ALGORITHMS if args.algo == "all" else (args.algo,)

    try:
        plans = plan(cfg, hw, args.chips, batch=batch, seq=args.seq,
                     algorithms=algos, pod_size=args.pod_size)
        flips = flip_points(cfg, hw, args.chips, batch=batch,
                            pod_size=args.pod_size)
    except (ValueError, KeyError) as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    shown = plans[:args.top] if args.top else plans
    tokens = float(batch) if cfg.family == "mlp" else float(batch) * args.seq
    if args.as_json:
        def plan_dict(p: MeshPlan) -> dict:
            return {"mesh": p.mesh, "chips": p.chips,
                    "algo_label": p.algo_label, **dataclasses.asdict(p)}

        print(json.dumps({
            "arch": args.arch, "chips": args.chips, "batch": batch,
            "seq": None if cfg.family == "mlp" else args.seq,
            "pod_size": args.pod_size,
            "algo": args.algo,
            "algorithms": list(algos),
            "flip_points": flips,
            "hardware": {"source": "calibrated" if args.calibrated
                         else list_hardware().get(hw.name, "datasheet"),
                         **dataclasses.asdict(hw)},
            "plans": [plan_dict(p) for p in shown],
            "best": plan_dict(plans[0]),
        }, indent=1))
        return 0
    print(f"# {args.arch} on {args.chips}x {hw.name}, "
          f"batch={batch}"
          + ("" if cfg.family == "mlp" else f", seq={args.seq}")
          + f", algo={args.algo}")
    print(format_plan_table(shown))
    if args.algo in ("all", "auto"):
        print()
        print(format_flip_table(flips))
    n_total, n_active = param_counts(cfg)
    print()
    print(roofline_table(to_cell_reports(
        args.arch, shown, hw, batch=batch, tokens=tokens,
        params_total=n_total, params_active=n_active)))
    best = plans[0]
    band = (f" (band {best.runtime_lo * 1e3:.3f}..{best.runtime_hi * 1e3:.3f}"
            f" ms from measured_rel_error)"
            if best.runtime_hi > best.runtime else "")
    print(f"\nbest: {best.mesh} ({best.algo_label}) -> "
          f"{best.runtime * 1e3:.3f} ms/step, {best.bottleneck}-bound{band}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
