"""Parallelism planner: rank (dp, tp, pp) meshes by Ridgeline step time.

``plan(cfg, hw, chips, ...)`` is a thin slice of the grid-scale vectorized
engine in :mod:`repro.launch.plan_grid` — one chips budget, one global
batch — kept as the ergonomic scalar API.  The engine enumerates every
feasible ``dp × tp × pp`` factorization (pp | n_layers) crossed with every
1F1B microbatch count (m | batch/dp) and collective algorithm, and derives
each candidate's per-chip Ridgeline terms analytically —

  F    = 6 · N_active · tokens / (dp·tp·pp)
  B_M  = params_bytes/(tp·pp) + 2 · (L/pp) · boundary_act_bytes   (per µbatch)
  t_N  = DP grad all-reduce (params_bytes/(tp·pp) over dp, once per step)
         + bubble · [ TP activation all-reduces (2×/layer MLP, 4×/layer
           attention, per stage per microbatch) + PP boundary p2p
           (2 hops · act_bytes/m) ],  each priced α–β on the *link its
           mesh axis rides*:  α(link)·steps + bytes/bandwidth(link)

— where ``bubble = (m + pp − 1)/m`` is the 1F1B pipeline-fill factor
(exactly 1 at pp = 1, recovering the non-pipelined model bit-for-bit).
Collective wire bytes and hop counts come from
``repro.distributed.collectives`` under the chosen algorithm, and the whole
candidate set is evaluated in one :mod:`repro.core.sweep` broadcast pass —
there is no per-candidate Python loop; grids of ≥10⁵ candidates/s are one
call (``plan_grid``).  With ``pod_size`` set, an axis whose ring extends
past one pod is priced at the ``pod`` link's (slower) bandwidth — the
slowest hop bounds a ring.  A size-1 mesh axis has no collective at all and
pays neither bytes nor α·steps.  Everything is closed-form +
``jax.eval_shape`` (for exact parameter counts, memoized per config), so
planning needs no accelerator and runs in milliseconds.

**Algorithm selection.**  The collective *algorithm* is part of the cost
model: with a per-hop α, a log-step tree all-reduce beats rings below some
payload and a bandwidth-optimal ring wins above it.  The default
``"auto"`` picks the α–β argmin per mesh axis via
``collectives.best_all_reduce_grid`` — each candidate's dp and tp axes may
select different algorithms (``MeshPlan.dp_algo``/``tp_algo``).  A concrete
algorithm name prices every axis with it, and ``--algo all`` enumerates
every algorithm as its own ranked candidate and reports the per-axis/link
flip payloads (``flip_points``).

Calibrated specs carry a ``model_rel_error`` (median |model-vs-measured|
on whole-step validation points); each ranked plan widens its point
estimate into the uncertainty band ``[runtime·(1−e), runtime·(1+e)]``.
Their size-dependent ``compute_eff`` ceiling flows through the sweep
automatically.

CLI::

    python -m repro.launch.plan --arch dlrm-mlp --chips 16
    python -m repro.launch.plan --arch dlrm-mlp --chips 32 --pod-size 16
    python -m repro.launch.plan --arch qwen2-7b --chips 32 --algo all
    python -m repro.launch.plan --arch qwen2-7b --chips 64 --pp 8
    python -m repro.launch.plan --arch qwen2-moe-a2.7b --chips 16 --ep 4
    python -m repro.launch.plan --arch qwen2-7b --chips 64 --pp 8 \\
        --interleave 2
    python -m repro.launch.plan --arch dlrm-mlp --chips-grid 8,16,32,64 \\
        --batch-grid 256,512,1024 --pp 4
    python -m repro.launch.plan --arch dlrm-mlp --chips 16 --calibrated --json
    python -m repro.launch.plan --arch qwen2-7b --chips 16 --zero auto --remat
    python -m repro.launch.plan --arch qwen2-7b --chips 16 --zero auto \\
        --explain --trace artifacts/traces/plan.trace.json
    python -m repro.launch.plan --arch dlrm-mlp --chips-grid 16,64 \\
        --goodput --mtbf-hours 2000
    python -m repro.launch.plan --hardware list

**Memory feasibility.**  When the spec carries a per-chip
``hbm_capacity_bytes`` (datasheet presets and calibrated entries do),
every candidate's working set (``launch/memory``: params + grads +
optimizer states + in-flight activations) is priced first and candidates
that cannot fit are pruned before ranking — the planner never recommends
a mesh that cannot hold its own state.  ``--zero auto`` (or a comma list
of stages) searches ZeRO sharding as a candidate axis, ``--remat`` trades
activation footprint for +1/3 recompute FLOPs, and
``--no-capacity-check`` keeps infeasible rows marked ``fit=NO`` instead
(the what-if view).

**Failure-aware goodput.**  ``--goodput`` (implied by ``--mtbf-hours H``)
prices failures into the ranking (:mod:`repro.resilience.failures`): each
candidate's persisted checkpoint bytes over the spec's ``ckpt_bw`` set its
checkpoint cost, Young/Daly sets the cadence, and the amortized per-step
checkpoint/rework/restart seconds are added to the step time before
ranking — so a smaller mesh with a cheaper failure bill can out-rank the
healthy winner.  Without ``--mtbf-hours`` the MTBF is infinite and the
ranking is bit-identical to the healthy one (goodput ≡ 1).

``--pp N`` admits pipeline axes up to N stages; ``--chips-grid`` /
``--batch-grid`` (comma lists) switch to grid mode: the whole scaling
surface in one vectorized pass, one best-plan row per grid point.
``--hardware`` accepts any name from ``core.hardware.list_hardware()``
(datasheet presets and calibrated registry entries alike; ``list`` prints
them); ``--calibrated`` swaps in the measured twin of the named preset, so
rankings use achievable rather than vendor ceilings.  ``--json`` emits the
full ranking machine-readably for scripting.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

from repro.core.hardware import HardwareSpec, get_hardware, list_hardware
from repro.core.report import CellReport, roofline_table
from repro.distributed import collectives
# the evaluation core + its vocabulary (re-exported: this module is the
# stable import surface; the engine lives in plan_grid)
from repro.launch.plan_grid import (MeshPlan, PlanGrid, POD_LINK,
                                    ZERO_STAGES, feasible_meshes,
                                    param_counts, plan_grid)
from repro.obs import trace as obs_trace
from repro.resilience.failures import FailureModel

if TYPE_CHECKING:  # jax-backed; planning itself is numpy-only
    from repro.models.common import ModelConfig

__all__ = ["MeshPlan", "PlanGrid", "plan", "plan_grid", "best_step_time",
           "feasible_meshes", "param_counts", "flip_points",
           "format_plan_table", "format_grid_table", "format_flip_table",
           "to_cell_reports", "main"]


def _axis_link(axis: int, inner: int, pod_size: Optional[int],
               hw: HardwareSpec) -> Optional[str]:
    """Link a ring over ``axis`` chips (stride ``inner``) is priced at.

    Scalar twin of the engine's boolean-mask routing, kept for the
    flip-point report: a ring whose extent ``axis·inner`` exceeds the pod
    crosses a pod boundary somewhere, and a ring runs at its slowest hop —
    so the whole axis is priced at the ``pod`` link.  Returns None
    (primary link) for intra-pod axes, trivial axes, or when no
    ``pod_size`` is given.
    """
    if pod_size is None or axis <= 1 or axis * inner <= pod_size:
        return None
    hw.bandwidth_for(POD_LINK)      # actionable KeyError if the spec has none
    return POD_LINK


def plan(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
         batch: int, seq: int = 1,
         algorithms: Sequence[str] = ("auto",),
         pod_size: Optional[int] = None,
         max_pp: int = 1, max_ep: int = 1, interleave: int = 1,
         zero_stages: Sequence[int] = (0,),
         remat: bool = False, check_capacity: bool = True,
         goodput: bool = False,
         failure: Optional["FailureModel"] = None) -> List[MeshPlan]:
    """Rank every feasible (dp, tp, pp, ep, m, algorithm) by step time.

    A single-point slice of :func:`repro.launch.plan_grid.plan_grid` (one
    chips budget, one batch) — same evaluation core, same numbers.

    ``pod_size`` (chips per pod) routes each mesh axis onto the link it
    actually rides: axes contained in one pod use primary ICI, axes that
    span pods use the slower ``pod`` entry of ``hw.extra_links``.

    ``algorithms`` entries are concrete collective tags (including the
    ``bidir`` alias) or ``"auto"`` (the default): per-axis α–β argmin over
    the full menu, so the dp grad sync and the tp act syncs can pick
    different algorithms on the same candidate.  ``max_pp`` admits
    pipeline-parallel axes up to that many stages (1 = the classic
    dp × tp space); ``max_ep`` admits expert-parallel axes dividing the
    padded expert count (MoE configs only — see
    :func:`repro.launch.plan_grid.plan_grid`); ``interleave`` prices the
    interleaved-1F1B schedule with that many virtual stages per chip.

    ``zero_stages``/``remat``/``check_capacity`` are the memory-feasibility
    controls (see :func:`repro.launch.plan_grid.plan_grid`): when the spec
    carries an ``hbm_capacity_bytes``, candidates whose working set cannot
    fit are pruned before pricing — the returned ranking never recommends
    a mesh that cannot hold its own state.

    ``goodput``/``failure`` fold the amortized failure bill
    (checkpoint overhead + expected rework + expected restart, see
    :func:`repro.launch.plan_grid.plan_grid`) into the ranked step times.
    """
    grid = plan_grid(cfg, hw, [chips], [batch], seq=seq,
                     algorithms=algorithms, pod_size=pod_size, max_pp=max_pp,
                     max_ep=max_ep, interleave=interleave,
                     zero_stages=zero_stages, remat=remat,
                     check_capacity=check_capacity,
                     goodput=goodput, failure=failure)
    return grid.plans()


def flip_points(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
                batch: int, pod_size: Optional[int] = None) -> List[dict]:
    """Per mesh axis/link: where the best all-reduce algorithm flips.

    One row per distinct (axis kind, group size, link) among the feasible
    meshes, with the α–β flip payload from
    ``collectives.all_reduce_flip_payload``: the small-payload winner
    (log-step tree once α > 0) hands over to the bandwidth-optimal ring
    at ``flip_payload_bytes``.  ``None`` flip means one algorithm dominates
    every payload (e.g. α = 0); size-1 axes run no collective and are
    skipped.  (The pp boundary p2p is a fixed 2-hop send — no algorithm
    menu, so no flip row.)
    """
    seen = set()
    rows: List[dict] = []
    for d, t in feasible_meshes(cfg, chips, batch):
        for kind, n, inner in (("dp", d, t), ("tp", t, 1)):
            link = _axis_link(n, inner, pod_size, hw)
            key = (kind, n, link)
            if n <= 1 or key in seen:
                continue
            seen.add(key)
            bw, alpha = hw.bandwidth_for(link), hw.alpha_for(link)
            flip = collectives.all_reduce_flip_payload(n, bw, alpha)
            rows.append({
                "axis": kind, "group_size": n, "link": link or "ici",
                "bandwidth": bw, "alpha": alpha,
                "flip_payload_bytes": None if flip is None else flip[0],
                "small_payload_algo": None if flip is None else flip[1],
                "large_payload_algo": None if flip is None else flip[2],
            })
    return sorted(rows, key=lambda r: (r["axis"], r["group_size"]))


def best_step_time(cfg: ModelConfig, hw: HardwareSpec, chips: int, *,
                   batch: int, seq: int = 1,
                   algorithms: Sequence[str] = ("auto",),
                   pod_size: Optional[int] = None,
                   max_pp: int = 1, max_ep: int = 1, interleave: int = 1,
                   zero_stages: Sequence[int] = (0,),
                   remat: bool = False,
                   check_capacity: bool = True) -> float:
    return plan(cfg, hw, chips, batch=batch, seq=seq,
                algorithms=algorithms, pod_size=pod_size,
                max_pp=max_pp, max_ep=max_ep, interleave=interleave,
                zero_stages=zero_stages, remat=remat,
                check_capacity=check_capacity)[0].runtime


def to_cell_reports(arch: str, plans: Sequence[MeshPlan], hw: HardwareSpec,
                    *, batch: int, tokens: float, params_total: float,
                    params_active: float) -> List[CellReport]:
    """Planner candidates as the standard per-cell report artifact.

    ``wire_bytes`` are primary-link-equivalent (``t_network · net_bw``) so
    the report's projection matches the plan's per-axis α–β pricing; the
    raw per-axis wire bytes ride along in ``wire_bytes_by_kind``.
    """
    reports = []
    for p in plans:
        rep = CellReport(
            arch=arch, shape=f"plan_b{batch}", mesh=p.mesh,
            step_kind="train_step", num_devices=p.chips, hardware=hw.name,
            flops=p.flops, mem_bytes=p.mem_bytes,
            wire_bytes=p.t_network * hw.net_bw,
            wire_bytes_by_kind={"analytic-dp+tp+pp": p.net_bytes},
            peak_memory_per_device=0.0,
            model_flops=6.0 * params_active * tokens,
            params_total=params_total, params_active=params_active,
            tokens_per_step=tokens, variant=p.algo_label,
            notes=f"rank by plan; {p.algorithm}->{p.algo_label}; links "
                  f"{p.dp_link}/{p.tp_link}"
                  + (f"; pp{p.pp} m{p.microbatches}" if p.pp > 1 else "")
                  + (f"; ep{p.ep} a2a on {p.ep_link}" if p.ep > 1 else ""))
        reports.append(rep.finalize(hw))
    return reports


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.3f}"


def format_plan_table(plans: Sequence[MeshPlan]) -> str:
    banded = any(p.runtime_hi > p.runtime for p in plans)
    piped = any(p.pp > 1 for p in plans)
    eped = any(p.ep > 1 for p in plans)
    zeroed = any(p.zero_stage > 0 for p in plans)
    capped = any(p.hbm_bytes > 0 for p in plans)
    misfit = any(not p.fits for p in plans)
    # a goodput-priced plan always carries a nonzero Young/Daly interval
    # (inf under an infinite MTBF); the healthy path leaves the default 0.0
    gooded = any(p.ckpt_interval_s != 0.0 for p in plans)
    head = (f"{'rank':>4} {'mesh':>12} "
            + (f"{'pp':>3} {'mb':>4} " if piped else "")
            + (f"{'ep':>3} " if eped else "")
            + (f"{'z':>2} " if zeroed else "")
            + f"{'algo':>10} {'t_comp ms':>9} "
            f"{'t_mem ms':>9} {'t_net ms':>9} {'step ms':>9} "
            + (f"{'band ms':>19} " if banded else "")
            + (f"{'gp%':>6} " if gooded else "")
            + (f"{'hbm GB':>7} " if capped else "")
            + (f"{'fit':>4} " if misfit else "")
            + f"{'links':>9} {'bottleneck':>10} {'peak%':>6}")
    lines = [head, "-" * len(head)]
    for i, p in enumerate(plans):
        band = (f"{_fmt_ms(p.runtime_lo)}..{_fmt_ms(p.runtime_hi).strip():<8} "
                if banded else "")
        pipe = f"{p.pp:>3} {p.microbatches:>4} " if piped else ""
        link = p.dp_link if p.dp_link == p.tp_link else \
            f"{p.dp_link}/{p.tp_link}"
        lines.append(
            f"{i + 1:>4} {p.mesh:>12} " + pipe
            + (f"{p.ep:>3} " if eped else "")
            + (f"{p.zero_stage:>2} " if zeroed else "")
            + f"{p.algo_label:>10} "
            f"{_fmt_ms(p.t_compute)} {_fmt_ms(p.t_memory)} "
            f"{_fmt_ms(p.t_network)} {_fmt_ms(p.runtime)} "
            + band
            + (f"{100 * p.goodput:5.1f}% " if gooded else "")
            + (f"{p.hbm_used_gb:7.1f} " if capped else "")
            + (f"{'yes' if p.fits else 'NO':>4} " if misfit else "")
            + f"{link:>9} {p.bottleneck:>10} {100 * p.peak_fraction:5.1f}%")
    return "\n".join(lines)


def format_grid_table(grid: PlanGrid, top: int = 1) -> str:
    """Grid mode: the ``top`` best plans per (chips, batch) point."""
    top = max(1, top)
    ranked = top > 1
    zeroed = any(z > 0 for z in grid.zero_stages)
    capped = grid.hbm_capacity_bytes > 0
    gooded = grid.goodput is not None
    head = (f"{'chips':>6} {'batch':>7} "
            + (f"{'rank':>4} " if ranked else "")
            + f"{'mesh':>14} {'mb':>4} "
            + (f"{'z':>2} " if zeroed else "")
            + f"{'algo':>10} {'step ms':>9} "
            + (f"{'gp%':>6} " if gooded else "")
            + (f"{'hbm GB':>7} " if capped else "")
            + f"{'bottleneck':>10} {'peak%':>6}")
    lines = [head, "-" * len(head)]
    for chips in grid.chips_list:
        for batch in grid.batch_list:
            for r, p in enumerate(grid.plans(chips, batch)[:top]):
                lines.append(
                    f"{chips:>6} {batch:>7} "
                    + (f"{r + 1:>4} " if ranked else "")
                    + f"{p.mesh:>14} {p.microbatches:>4} "
                    + (f"{p.zero_stage:>2} " if zeroed else "")
                    + f"{p.algo_label:>10} {_fmt_ms(p.runtime)} "
                    + (f"{100 * p.goodput:5.1f}% " if gooded else "")
                    + (f"{p.hbm_used_gb:7.1f} " if capped else "")
                    + f"{p.bottleneck:>10} {100 * p.peak_fraction:5.1f}%")
    return "\n".join(lines)


def format_flip_table(rows: Sequence[dict]) -> str:
    """Human-readable flip-point report (the ``--algo all`` extra)."""
    out = ["# all-reduce algorithm flip points (per mesh axis / link)"]
    if not rows:
        return "\n".join(out + ["  (no multi-chip axes)"])
    for r in rows:
        where = (f"  {r['axis']:>3} axis n={r['group_size']:<4} "
                 f"link={r['link']:<4} "
                 f"(bw {r['bandwidth']:.3g} B/s, alpha {r['alpha']:.3g} s)")
        if r["flip_payload_bytes"] is None:
            out.append(where + ": no flip (one algorithm dominates)")
        else:
            out.append(
                where + f": {r['small_payload_algo']} below "
                f"{r['flip_payload_bytes']:.4g} B, "
                f"{r['large_payload_algo']} above")
    return "\n".join(out)


def _plan_dict(p: MeshPlan) -> dict:
    return {"mesh": p.mesh, "chips": p.chips,
            "algo_label": p.algo_label, "hbm_used_gb": p.hbm_used_gb,
            **dataclasses.asdict(p)}


def _capacity_dict(grid: PlanGrid) -> dict:
    """Machine-readable summary of the feasibility cut (JSON outputs)."""
    return {
        "hbm_capacity_bytes": grid.hbm_capacity_bytes,
        "checked": grid.check_capacity,
        "n_enumerated": grid.n_enumerated,
        "n_pruned": int(grid.n_pruned.sum()),
        "pruned_fraction": grid.pruned_fraction,
        "min_zero_to_fit": grid.min_zero_to_fit.tolist(),
    }


def _failure_json(goodput: bool,
                  failure: Optional[FailureModel]) -> dict:
    """The ``failure`` block of ``--json`` output (empty when healthy).
    An infinite MTBF serializes as ``null`` to keep the JSON strict."""
    if not goodput:
        return {}
    import math
    fm = failure if failure is not None else FailureModel()
    return {"failure": {
        "mtbf_chip_s": (fm.mtbf_chip_s
                        if math.isfinite(fm.mtbf_chip_s) else None),
        "restart_s": fm.restart_s, "reshard_s": fm.reshard_s}}


def _parse_grid(arg: Optional[str], name: str) -> Optional[List[int]]:
    if arg is None:
        return None
    try:
        vals = [int(v) for v in arg.split(",") if v.strip()]
    except ValueError:
        raise ValueError(f"--{name} wants a comma list of ints, got {arg!r}")
    if not vals:
        raise ValueError(f"--{name} is empty")
    return vals


def _explain_dict(grid: PlanGrid) -> dict:
    from repro.obs import explain as explain_mod
    return explain_mod.explain_dict(grid)


def _print_explain(grid: PlanGrid) -> None:
    """The --explain section: per-point tables + the machine JSON block."""
    from repro.obs import explain as explain_mod
    d = explain_mod.explain_dict(grid)
    print()
    print("# --- explain: cost attribution "
          "(breakdown terms sum to step time) ---")
    for pt in d["points"]:
        print(explain_mod.format_prune_reasons(pt))
        print(explain_mod.format_explain_table(pt["candidates"]))
    print()
    print("# explain JSON")
    print(json.dumps(d, indent=1, sort_keys=True))


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry point: parse, plan, print; flush the tracer on the way out
    (``--trace PATH`` spans cover everything the run did, even on error)."""
    try:
        return _main(argv)
    finally:
        t = obs_trace.active()
        if t is not None and t.path:
            try:
                t.write()
            except OSError as e:
                print(f"warning: could not write trace: {e}", file=sys.stderr)


def _main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan",
        description="Rank (dp, tp, pp) meshes by Ridgeline-projected step "
                    "time; grid mode sweeps chips × batch in one pass.")
    ap.add_argument("--arch")
    ap.add_argument("--chips", type=int)
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default: 512 MLP / 256 LM)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--hardware", default="tpu_v5e",
                    help="spec name (datasheet preset or calibrated registry "
                         "entry), or 'list' to enumerate all of them")
    ap.add_argument("--calibrated", action="store_true",
                    help="use the calibrated twin of --hardware "
                         "(artifacts/calibration)")
    ap.add_argument("--pod-size", type=int, default=None,
                    help="chips per pod; mesh axes spanning pods are priced "
                         "at the spec's 'pod' link instead of primary ICI")
    ap.add_argument("--pp", type=int, default=1,
                    help="max pipeline-parallel stages to search; stage "
                         "counts not dividing n_layers (or the chip "
                         "budget) are skipped, and 1F1B microbatch counts "
                         "are searched automatically (default 1 = no "
                         "pipeline axis)")
    ap.add_argument("--ep", type=int, default=1,
                    help="max expert-parallel axis size to search; ep must "
                         "divide the padded expert count E_pad = "
                         "max(n_experts, pad_experts_to), so this only "
                         "widens the space for MoE archs (default 1 = no "
                         "ep axis)")
    ap.add_argument("--interleave", type=int, default=1,
                    help="interleaved-1F1B virtual stages per chip: divides "
                         "the pipeline ramp bubble by min(N, layers/pp) at "
                         "the cost of that many times the boundary p2p "
                         "traffic (default 1 = classic 1F1B)")
    ap.add_argument("--chips-grid", default=None,
                    help="comma list of chip budgets -> grid mode "
                         "(one vectorized pass over every point)")
    ap.add_argument("--batch-grid", default=None,
                    help="comma list of global batches -> grid mode")
    ap.add_argument("--zero", default="0",
                    help="ZeRO stages to search: a comma list of 0-3, or "
                         "'auto' (all stages; stage 1/2/3 shard optimizer "
                         "states/gradients/parameters over dp). Default 0 "
                         "= no sharding")
    ap.add_argument("--remat", action="store_true",
                    help="rematerialize activations: half the saved-"
                         "activation footprint at +1/3 recompute FLOPs")
    ap.add_argument("--no-capacity-check", action="store_true",
                    help="keep candidates exceeding the spec's "
                         "hbm_capacity_bytes (marked fit=NO) instead of "
                         "pruning them — the what-if view")
    ap.add_argument("--goodput", action="store_true",
                    help="price failures into the ranking: amortized "
                         "checkpoint + rework + restart seconds (Young/Daly "
                         "cadence over the spec's ckpt_bw) are added to each "
                         "candidate's step time; without --mtbf-hours the "
                         "MTBF is infinite and the ranking is unchanged")
    ap.add_argument("--mtbf-hours", type=float, default=None,
                    help="per-chip mean time between failures, hours "
                         "(implies --goodput); the mesh fails chips x "
                         "faster")
    ap.add_argument("--restart-s", type=float, default=60.0,
                    help="seconds from failure to training again "
                         "(respawn + checkpoint read-back; default 60)")
    ap.add_argument("--reshard-s", type=float, default=30.0,
                    help="extra elastic-reshard seconds charged per "
                         "restart (default 30)")
    ap.add_argument("--algo", default="auto",
                    choices=sorted(collectives.ALGORITHM_ALIASES)
                    + list(collectives.ALGORITHMS) + ["auto", "all"],
                    help="collective algorithm: a concrete tag, 'auto' "
                         "(per-axis α–β argmin, the default), or 'all' "
                         "(rank every algorithm and report flip points)")
    ap.add_argument("--top", type=int, default=0,
                    help="show only the best N candidates (0 = all)")
    ap.add_argument("--explain", action="store_true",
                    help="decompose every candidate's step time into its "
                         "additive terms (compute/memory α vs work, per-axis "
                         "network α·steps vs bytes/bw, pipeline bubble, ZeRO "
                         "sync) plus structured prune reasons; adds an "
                         "'explain' block to --json output")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome-trace-event JSON of this run's "
                         "planner spans to PATH (loads in ui.perfetto.dev "
                         "or chrome://tracing)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (full ranking + spec)")
    args = ap.parse_args(argv)
    if args.trace:
        obs_trace.enable(args.trace)

    if args.hardware == "list":
        specs = list_hardware()
        if args.as_json:
            print(json.dumps(
                {name: {"source": src,
                        **dataclasses.asdict(get_hardware(name))}
                 for name, src in sorted(specs.items())}, indent=1))
        else:
            print(f"{'name':>16} {'source':>12} {'peak FLOP/s':>12} "
                  f"{'HBM B/s':>10} {'NET B/s':>10}")
            for name, src in sorted(specs.items()):
                s = get_hardware(name)
                print(f"{name:>16} {src:>12} {s.peak_flops:>12.3g} "
                      f"{s.hbm_bw:>10.3g} {s.net_bw:>10.3g}")
        return 0
    grid_mode = args.chips_grid is not None or args.batch_grid is not None
    if args.arch is None or (args.chips is None and args.chips_grid is None):
        ap.error("--arch and --chips (or --chips-grid) are required "
                 "(unless --hardware list)")

    from repro.configs import get_config, list_archs
    try:
        cfg = get_config(args.arch)
    except KeyError:
        print(f"unknown arch {args.arch!r}; have: {', '.join(list_archs())}",
              file=sys.stderr)
        return 2
    try:
        hw = get_hardware(args.hardware, calibrated=args.calibrated)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    batch = args.batch if args.batch is not None else (
        512 if cfg.family == "mlp" else 256)
    algos = collectives.ALGORITHMS if args.algo == "all" else (args.algo,)
    if args.zero.strip().lower() == "auto":
        zero_stages: Tuple[int, ...] = ZERO_STAGES
    else:
        try:
            zero_stages = tuple(int(v) for v in args.zero.split(",")
                                if v.strip())
        except ValueError:
            ap.error(f"--zero wants 'auto' or a comma list of stages "
                     f"0-3, got {args.zero!r}")
        if not zero_stages:
            ap.error("--zero is empty")
    check_capacity = not args.no_capacity_check
    goodput = args.goodput or args.mtbf_hours is not None
    failure = None
    if args.mtbf_hours is not None:
        if args.mtbf_hours <= 0:
            ap.error(f"--mtbf-hours must be > 0, got {args.mtbf_hours}")
        failure = FailureModel.from_mtbf_hours(
            args.mtbf_hours, restart_s=args.restart_s,
            reshard_s=args.reshard_s)

    if grid_mode:
        try:
            chips_list = _parse_grid(args.chips_grid, "chips-grid") \
                or [args.chips]
            batch_list = _parse_grid(args.batch_grid, "batch-grid") or [batch]
            grid = plan_grid(cfg, hw, chips_list, batch_list, seq=args.seq,
                             algorithms=algos, pod_size=args.pod_size,
                             max_pp=args.pp, max_ep=args.ep,
                             interleave=args.interleave,
                             zero_stages=zero_stages,
                             remat=args.remat,
                             check_capacity=check_capacity,
                             explain=args.explain,
                             goodput=goodput, failure=failure)
        except (ValueError, KeyError) as e:
            print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
            return 2
        # flip points across the whole grid, deduped by (axis, n, link)
        flip_rows = {}
        for c in grid.chips_list:
            for b in grid.batch_list:
                for r in flip_points(cfg, hw, c, batch=b,
                                     pod_size=args.pod_size):
                    flip_rows[(r["axis"], r["group_size"], r["link"])] = r
        flips = [flip_rows[k] for k in sorted(flip_rows)]
        if args.as_json:
            def point_dict(c: int, b: int) -> dict:
                pts = grid.plans(c, b)
                d = {"chips": c, "batch": b, "best": _plan_dict(pts[0])}
                if args.top:
                    d["plans"] = [_plan_dict(p) for p in pts[:args.top]]
                return d

            print(json.dumps({
                "mode": "grid", "arch": args.arch,
                "chips_grid": list(grid.chips_list),
                "batch_grid": list(grid.batch_list),
                "seq": None if cfg.family == "mlp" else args.seq,
                "pod_size": args.pod_size, "max_pp": args.pp,
                "max_ep": args.ep, "interleave": args.interleave,
                "algo": args.algo, "algorithms": list(algos),
                "zero_stages": list(grid.zero_stages),
                "remat": grid.remat,
                "capacity": _capacity_dict(grid),
                **_failure_json(goodput, failure),
                "n_candidates": grid.n_candidates,
                "flip_points": flips,
                "hardware": {"source": "calibrated" if args.calibrated
                             else list_hardware().get(hw.name, "datasheet"),
                             **dataclasses.asdict(hw)},
                "points": [point_dict(c, b) for c in grid.chips_list
                           for b in grid.batch_list],
                **({"explain": _explain_dict(grid)} if args.explain else {}),
            }, indent=1))
            return 0
        print(f"# {args.arch} grid on {hw.name}: "
              f"chips {list(grid.chips_list)} x batch {list(grid.batch_list)}"
              + ("" if cfg.family == "mlp" else f", seq={args.seq}")
              + f", algo={args.algo}, max_pp={args.pp}"
              + (f", max_ep={args.ep}" if args.ep > 1 else "")
              + (f", interleave={args.interleave}"
                 if args.interleave > 1 else "")
              + (f", zero={args.zero}" if args.zero != "0" else "")
              + (", remat" if args.remat else "")
              + ((f", goodput (mtbf {args.mtbf_hours:g} h/chip)"
                  if args.mtbf_hours is not None else ", goodput")
                 if goodput else "")
              + f" ({grid.n_candidates} candidates, one pass)")
        if grid.hbm_capacity_bytes > 0 and grid.check_capacity \
                and grid.n_pruned.sum():
            print(f"# capacity {grid.hbm_capacity_bytes / 1e9:.1f} GB/chip: "
                  f"{int(grid.n_pruned.sum())} of {grid.n_enumerated} "
                  f"candidates infeasible, pruned before pricing")
        print(format_grid_table(grid, top=args.top or 1))
        if args.algo in ("all", "auto"):
            print()
            print(format_flip_table(flips))
        if args.explain:
            _print_explain(grid)
        return 0

    try:
        grid = plan_grid(cfg, hw, [args.chips], [batch], seq=args.seq,
                         algorithms=algos, pod_size=args.pod_size,
                         max_pp=args.pp, max_ep=args.ep,
                         interleave=args.interleave,
                         zero_stages=zero_stages,
                         remat=args.remat, check_capacity=check_capacity,
                         explain=args.explain,
                         goodput=goodput, failure=failure)
        plans = grid.plans()
        flips = flip_points(cfg, hw, args.chips, batch=batch,
                            pod_size=args.pod_size)
    except (ValueError, KeyError) as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    shown = plans[:args.top] if args.top else plans
    tokens = float(batch) if cfg.family == "mlp" else float(batch) * args.seq
    if args.as_json:
        print(json.dumps({
            "arch": args.arch, "chips": args.chips, "batch": batch,
            "seq": None if cfg.family == "mlp" else args.seq,
            "pod_size": args.pod_size,
            "max_pp": args.pp,
            "max_ep": args.ep,
            "interleave": args.interleave,
            "algo": args.algo,
            "algorithms": list(algos),
            "zero_stages": list(grid.zero_stages),
            "remat": grid.remat,
            "capacity": _capacity_dict(grid),
            **_failure_json(goodput, failure),
            "flip_points": flips,
            "hardware": {"source": "calibrated" if args.calibrated
                         else list_hardware().get(hw.name, "datasheet"),
                         **dataclasses.asdict(hw)},
            "plans": [_plan_dict(p) for p in shown],
            "best": _plan_dict(plans[0]),
            **({"explain": _explain_dict(grid)} if args.explain else {}),
        }, indent=1))
        return 0
    print(f"# {args.arch} on {args.chips}x {hw.name}, "
          f"batch={batch}"
          + ("" if cfg.family == "mlp" else f", seq={args.seq}")
          + f", algo={args.algo}"
          + (f", max_pp={args.pp}" if args.pp > 1 else "")
          + (f", max_ep={args.ep}" if args.ep > 1 else "")
          + (f", interleave={args.interleave}" if args.interleave > 1
             else "")
          + (f", zero={args.zero}" if args.zero != "0" else "")
          + (", remat" if args.remat else "")
          + ((f", goodput (mtbf {args.mtbf_hours:g} h/chip)"
              if args.mtbf_hours is not None else ", goodput")
             if goodput else ""))
    print(format_plan_table(shown))
    if args.algo in ("all", "auto"):
        print()
        print(format_flip_table(flips))
    n_total, n_active = param_counts(cfg)
    print()
    print(roofline_table(to_cell_reports(
        args.arch, shown, hw, batch=batch, tokens=tokens,
        params_total=n_total, params_active=n_active)))
    best = plans[0]
    band = (f" (band {best.runtime_lo * 1e3:.3f}..{best.runtime_hi * 1e3:.3f}"
            f" ms from measured_rel_error)"
            if best.runtime_hi > best.runtime else "")
    bubble = (f", pp{best.pp} m{best.microbatches} "
              f"({100 * best.bubble_fraction:.0f}% bubble)"
              if best.pp > 1 else "")
    zero_note = f", ZeRO-{best.zero_stage}" if best.zero_stage else ""
    ep_note = (f", ep{best.ep} (dispatch a2a on {best.ep_link})"
               if best.ep > 1 else "")
    good_note = (f", goodput {100 * best.goodput:.1f}% "
                 f"(ckpt {best.ckpt_overhead_s * 1e3:.3f} + rework "
                 f"{best.rework_s * 1e3:.3f} + restart "
                 f"{best.restart_s * 1e3:.3f} ms/step)"
                 if best.ckpt_interval_s != 0.0 else "")
    print(f"\nbest: {best.mesh} ({best.algo_label}) -> "
          f"{best.runtime * 1e3:.3f} ms/step, {best.bottleneck}-bound"
          f"{zero_note}{ep_note}{bubble}{band}{good_note}")
    if grid.hbm_capacity_bytes > 0:
        cap_gb = grid.hbm_capacity_bytes / 1e9
        note = (f"capacity: best uses {best.hbm_used_gb:.1f} of "
                f"{cap_gb:.1f} GB/chip")
        pruned = int(grid.n_pruned.sum())
        if pruned:
            note += (f"; {pruned} of {grid.n_enumerated} candidates "
                     f"infeasible, pruned")
        k = int(grid.min_zero_to_fit[0, 0])
        if grid.check_capacity and 0 < k <= 3:
            note += f"; infeasible without ZeRO-{k}"
        print(note)
    if args.explain:
        _print_explain(grid)
    return 0


if __name__ == "__main__":
    sys.exit(main())
