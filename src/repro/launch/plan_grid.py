"""Grid-scale vectorized planning engine: the planner's evaluation core.

``plan_grid(cfg, hw, chips_list, batch_list, ...)`` evaluates the full
cartesian candidate space

    (dp × tp × pp) × microbatch × collective-algorithm × batch × chips

in NumPy broadcast passes — no per-candidate Python loop anywhere on the
evaluation path.  Candidate enumeration (divisor lists, feasibility
filters) is plain integer bookkeeping; everything priced — collective
wire bytes, α–β link times, algorithm argmins, the Ridgeline sweep — runs
on flat float64 arrays over the whole candidate set at once, which is
what turns N separate ``plan()`` calls into one pass at ≥10⁵
candidates/s (see ``BENCH_ridgeline.json`` → ``planner_grid_*``).

``repro.launch.plan.plan`` is a thin slice of this engine (one chips, one
batch, ``max_pp=1``), so there is exactly one evaluation core; its
``pp = 1`` output is regression-pinned bit-identical to the PR 4
per-candidate planner (``tests/test_plan_grid.py``).

**Mesh layout.**  Axes nest tp-inner / pp-middle / dp-outer, so a ring
over the tp axis has stride 1, the pp axis stride tp, and the dp axis
stride tp·pp.  With ``pod_size`` set, any axis whose extent
(size · stride) exceeds the pod is priced at the spec's ``pod`` link —
the slowest hop bounds a ring — expressed here as a boolean mask per
candidate with the link bandwidth/α gathered elementwise.

**Pipeline parallelism (1F1B).**  A pp-way candidate splits the layer
stack into ``pp`` stages (pp must divide ``n_layers``) and the per-dp
batch into ``m`` microbatches (m must divide ``batch/dp``).  The 1F1B
schedule keeps ``pp − 1`` microbatch slots of bubble at the ramp, so the
step time inflates by the bubble factor

    t_step ≈ (m + pp − 1)/m · t_microbatch_work

equivalently ``t_step = (m + pp − 1) · t_microbatch`` — with each
microbatch additionally paying 2 point-to-point activation hops
(boundary activation forward, its gradient backward) priced α–β on the
link the pp axis rides.  The fill factor ``m + pp − 1`` enters the
Ridgeline sweep as a per-candidate *derating of the machine peaks*
(peak/fill, hbm/fill, α·fill against per-microbatch work), so
classification and projected runtime stay one ``core.sweep`` call; at
pp = m = 1 the fill is exactly 1.0 and every number is bit-for-bit the
non-pipelined model.  The dp gradient
all-reduce runs once per step (after the last microbatch) and is not
bubbled.  Per-microbatch memory re-streams the stage weights
(weights + boundary activations per traversal), which reduces exactly to
the PR 4 accounting at pp = m = 1.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import sweep as sweep_mod
from repro.core.hardware import HardwareSpec, get_hardware
from repro.distributed import collectives

if TYPE_CHECKING:  # jax-backed; planning itself is numpy-only
    from repro.models.common import ModelConfig

#: families with attention/MoE blocks -> Megatron-style 4 syncs per layer
_ATTENTION_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")

#: display shorthand for algorithm tags (table column stays narrow)
_ALGO_SHORT = {"ring": "ring", "bidir_ring": "bidir", "tree": "tree"}

#: mesh-axis tag of the inter-pod link in ``HardwareSpec.extra_links``
POD_LINK = "pod"


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One ranked candidate: the mesh, its terms, and its projection."""

    dp: int
    tp: int
    algorithm: str               # requested: a concrete tag or "auto"
    flops: float                 # per chip, per step
    mem_bytes: float
    net_bytes: float             # wire bytes across all axes
    t_compute: float
    t_memory: float
    t_network: float             # α–β time, per-axis links (+ pipeline bubble)
    runtime: float               # projected step time (bound)
    bottleneck: str
    peak_fraction: float
    net_steps: float = 0.0       # serialized hops across all axes
    dp_link: str = "ici"         # link the dp grad sync rides
    tp_link: str = "ici"         # link the tp act syncs ride
    dp_algo: str = "ring"        # algorithm the dp grad sync uses ("-" when
    #                              the axis is size 1: no collective runs)
    tp_algo: str = "ring"        # algorithm the tp act syncs use
    runtime_lo: float = 0.0      # runtime·(1−e), e = hw.model_rel_error
    runtime_hi: float = 0.0      # runtime·(1+e); lo == hi == runtime when
    #                              the spec carries no measured error
    pp: int = 1                  # pipeline stages (1 = no pipeline axis)
    microbatches: int = 1        # 1F1B microbatch count m
    pp_link: str = "ici"         # link the pp boundary p2p rides

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def mesh(self) -> str:
        base = f"dp{self.dp}xtp{self.tp}"
        return base + (f"xpp{self.pp}" if self.pp > 1 else "")

    @property
    def bubble_fraction(self) -> float:
        """Fraction of the pipelined step spent in the 1F1B ramp bubble."""
        return (self.pp - 1.0) / (self.microbatches + self.pp - 1.0)

    @property
    def algo_label(self) -> str:
        """Selected algorithms, compact: one tag when the axes agree."""
        axes = [_ALGO_SHORT.get(a, a) for a in (self.dp_algo, self.tp_algo)
                if a != "-"]
        if not axes:
            return "-"
        if len(set(axes)) == 1:
            return axes[0]
        return "+".join(axes)


@functools.lru_cache(maxsize=None)
def _divisors(n: int) -> Tuple[int, ...]:
    """All divisors of n, ascending, by O(√n) enumeration."""
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


def _factor_pairs(chips: int) -> List[Tuple[int, int]]:
    """(chips//t, t) for every divisor t, t ascending — O(√chips)."""
    return [(chips // t, t) for t in _divisors(chips)]


def _model_width(cfg: ModelConfig) -> int:
    return cfg.mlp_widths[0] if cfg.family == "mlp" else cfg.d_model


@functools.lru_cache(maxsize=None)
def param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameter counts; closed-form for the MLP family.

    The MLP tower is counted without jax so the planner CLI stays fast on a
    bare CPU box; every other family defers to the eval_shape-exact
    accounting in ``launch/specs``.  Memoized on the (frozen, hashable)
    config, so the eval_shape trace runs once per model per process no
    matter how many ``plan``/``plan_grid`` calls follow.
    """
    if cfg.family == "mlp":
        widths = cfg.mlp_widths
        n = 0.0
        for i, w in enumerate(widths):
            d_in = widths[i - 1] if i else widths[0]
            n += d_in * w + w
        n += widths[-1] * 1 + 1                     # head
        return n, n
    from repro.launch.specs import param_counts as exact
    return exact(cfg)


def feasible_meshes(cfg: ModelConfig, chips: int,
                    batch: int) -> List[Tuple[int, int]]:
    """(dp, tp) with dp·tp == chips, dp | batch and tp | model width."""
    width = _model_width(cfg)
    return [(dp, tp) for dp, tp in _factor_pairs(chips)
            if batch % dp == 0 and width % tp == 0]


def pp_choices(cfg: ModelConfig, chips: int, max_pp: int) -> List[int]:
    """Pipeline sizes: divide both the chip budget and the layer stack."""
    return [p for p in _divisors(chips)
            if p <= max_pp and cfg.n_layers % p == 0]


def microbatch_choices(batch_per_dp: int, pp: int) -> Tuple[int, ...]:
    """1F1B microbatch counts m: divisors of the per-dp batch.

    A pp = 1 candidate has no pipeline to fill, so splitting the batch
    only adds dispatch α without changing any bandwidth term — m is
    pinned to 1 there (which is also what keeps the pp = 1 slice
    bit-identical to the pre-grid planner).
    """
    if pp <= 1:
        return (1,)
    return _divisors(batch_per_dp)


# --- the broadcast evaluation core --------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanGrid:
    """Flat struct-of-arrays result of one ``plan_grid`` pass.

    Every field of length ``n_candidates`` lines up elementwise;
    ``chips_idx``/``batch_idx`` map each candidate back to its grid point.
    ``plans(chips, batch)`` materializes ranked :class:`MeshPlan` rows for
    one point (that is the only per-candidate Python in the module, and it
    is display-path only); ``best_runtime_grid()`` reduces the whole grid
    without materializing anything.
    """

    cfg_name: str
    hardware: str
    chips_list: Tuple[int, ...]
    batch_list: Tuple[int, ...]
    seq: int
    pod_size: Optional[int]
    max_pp: int
    algorithms: Tuple[str, ...]          # requested, raw (may include "auto")

    chips_idx: np.ndarray                # int, index into chips_list
    batch_idx: np.ndarray                # int, index into batch_list
    dp: np.ndarray
    tp: np.ndarray
    pp: np.ndarray
    microbatches: np.ndarray
    req_idx: np.ndarray                  # index into `algorithms`
    dp_algo_idx: np.ndarray              # into collectives.ALGORITHMS
    tp_algo_idx: np.ndarray
    dp_pod: np.ndarray                   # bool: axis priced at the pod link
    tp_pod: np.ndarray
    pp_pod: np.ndarray

    flops: np.ndarray                    # per chip per step
    mem_bytes: np.ndarray
    net_bytes: np.ndarray
    net_steps: np.ndarray
    t_compute: np.ndarray
    t_memory: np.ndarray
    t_network: np.ndarray
    runtime: np.ndarray
    bottleneck: np.ndarray               # int8 codes into sweep.RESOURCE_ORDER
    peak_fraction: np.ndarray
    runtime_lo: np.ndarray
    runtime_hi: np.ndarray

    @property
    def n_candidates(self) -> int:
        return int(self.runtime.size)

    def labels(self) -> np.ndarray:
        return sweep_mod._LABELS[self.bottleneck]

    def _point(self, chips: Optional[int], batch: Optional[int]
               ) -> Tuple[int, int]:
        ci = 0 if chips is None else self.chips_list.index(chips)
        bi = 0 if batch is None else self.batch_list.index(batch)
        return ci, bi

    def point_indices(self, chips: Optional[int] = None,
                      batch: Optional[int] = None) -> np.ndarray:
        ci, bi = self._point(chips, batch)
        return np.nonzero((self.chips_idx == ci)
                          & (self.batch_idx == bi))[0]

    def _mesh_plan(self, i: int) -> MeshPlan:
        dp, tp, pp = int(self.dp[i]), int(self.tp[i]), int(self.pp[i])
        algs = collectives.ALGORITHMS
        return MeshPlan(
            dp=dp, tp=tp,
            algorithm=self.algorithms[int(self.req_idx[i])],
            flops=float(self.flops[i]),
            mem_bytes=float(self.mem_bytes[i]),
            net_bytes=float(self.net_bytes[i]),
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_network=float(self.t_network[i]),
            runtime=float(self.runtime[i]),
            bottleneck=str(self.labels()[i]),
            peak_fraction=float(self.peak_fraction[i]),
            net_steps=float(self.net_steps[i]),
            dp_link=POD_LINK if self.dp_pod[i] else "ici",
            tp_link=POD_LINK if self.tp_pod[i] else "ici",
            dp_algo="-" if dp <= 1 else algs[int(self.dp_algo_idx[i])],
            tp_algo="-" if tp <= 1 else algs[int(self.tp_algo_idx[i])],
            runtime_lo=float(self.runtime_lo[i]),
            runtime_hi=float(self.runtime_hi[i]),
            pp=pp, microbatches=int(self.microbatches[i]),
            pp_link=POD_LINK if self.pp_pod[i] else "ici")

    def plans(self, chips: Optional[int] = None,
              batch: Optional[int] = None) -> List[MeshPlan]:
        """Ranked candidates of one grid point (runtime, then smaller tp)."""
        idx = self.point_indices(chips, batch)
        order = sorted(idx.tolist(),
                       key=lambda i: (self.runtime[i], self.tp[i]))
        return [self._mesh_plan(i) for i in order]

    def best(self, chips: Optional[int] = None,
             batch: Optional[int] = None) -> MeshPlan:
        idx = self.point_indices(chips, batch)
        i = min(idx.tolist(), key=lambda i: (self.runtime[i], self.tp[i]))
        return self._mesh_plan(i)

    def best_runtime_grid(self) -> np.ndarray:
        """min projected step time per grid point — (n_chips, n_batch)."""
        out = np.full((len(self.chips_list), len(self.batch_list)), np.inf)
        np.minimum.at(out, (self.chips_idx, self.batch_idx), self.runtime)
        return out


@functools.lru_cache(maxsize=4096)
def _point_candidates(width: int, n_layers: int, chips: int, batch: int,
                      max_pp: int) -> Tuple[np.ndarray, ...]:
    """(dp, tp, pp, m) arrays for one grid point — pure integer work.

    Keyed on the integers that actually determine feasibility (model
    width, layer count, chip budget, batch, pp cap), so repeated grid
    points — N ``plan()`` calls over the same configs, or overlapping
    grids — enumerate once per process.  Callers must treat the returned
    arrays as immutable (they are shared cache entries).
    """
    dp_l: List[int] = []
    tp_l: List[int] = []
    pp_l: List[int] = []
    m_l: List[int] = []
    for pp in _divisors(chips):
        if pp > max_pp or n_layers % pp:
            continue
        for dp, tp in _factor_pairs(chips // pp):
            if batch % dp or width % tp:
                continue
            for m in microbatch_choices(batch // dp, pp):
                dp_l.append(dp)
                tp_l.append(tp)
                pp_l.append(pp)
                m_l.append(m)
    return (np.asarray(dp_l, dtype=np.int64),
            np.asarray(tp_l, dtype=np.int64),
            np.asarray(pp_l, dtype=np.int64),
            np.asarray(m_l, dtype=np.int64))


def _enumerate_candidates(cfg: ModelConfig, chips_list: Sequence[int],
                          batch_list: Sequence[int], max_pp: int,
                          algo_codes: Sequence[int]
                          ) -> Dict[str, np.ndarray]:
    """Flat candidate index arrays over the whole grid.

    Per-point enumeration is cached integer bookkeeping
    (:func:`_point_candidates`); the algorithm axis and the grid-point
    index columns are tiled on with numpy, so the warm path does no
    per-candidate Python at all.  Raises when a grid point has no
    feasible mesh, naming the point.
    """
    width = _model_width(cfg)
    n_req = len(algo_codes)
    req_range = np.arange(n_req, dtype=np.intp)
    cols: List[List[np.ndarray]] = [[] for _ in range(7)]
    for ci, chips in enumerate(chips_list):
        for bi, batch in enumerate(batch_list):
            dp_a, tp_a, pp_a, m_a = _point_candidates(
                width, cfg.n_layers, int(chips), int(batch), max_pp)
            if dp_a.size == 0:
                raise ValueError(
                    f"no feasible (dp, tp, pp) for chips={chips}, "
                    f"batch={batch}, width={width}")
            n = dp_a.size * n_req
            cols[0].append(np.full(n, ci, dtype=np.intp))
            cols[1].append(np.full(n, bi, dtype=np.intp))
            # mesh-major, algorithm-minor — the scalar planner's order
            cols[2].append(np.repeat(dp_a, n_req))
            cols[3].append(np.repeat(tp_a, n_req))
            cols[4].append(np.repeat(pp_a, n_req))
            cols[5].append(np.repeat(m_a, n_req))
            cols[6].append(np.tile(req_range, dp_a.size))
    names = ("chips_idx", "batch_idx", "dp", "tp", "pp", "microbatches",
             "req_idx")
    return {name: np.concatenate(parts)
            for name, parts in zip(names, cols)}


def plan_grid(cfg: ModelConfig, hw: Union[HardwareSpec, str],
              chips_list: Sequence[int], batch_list: Sequence[int], *,
              seq: int = 1, algorithms: Sequence[str] = ("auto",),
              pod_size: Optional[int] = None, max_pp: int = 1) -> PlanGrid:
    """Evaluate every (dp × tp × pp) × m × algorithm × batch × chips
    candidate in one broadcast pass.

    ``algorithms`` entries are concrete collective tags (including the
    ``bidir`` alias) or ``"auto"`` (per-axis α–β argmin over the full
    menu); each entry is its own candidate row, exactly like the scalar
    planner.  ``max_pp = 1`` (the default) reproduces the PR 4 candidate
    space bit-for-bit; larger values add every pipeline size that divides
    both the chip budget and ``cfg.n_layers``, crossed with every 1F1B
    microbatch count dividing the per-dp batch.
    """
    if isinstance(hw, str):
        hw = get_hardware(hw)
    if not chips_list or not batch_list:
        raise ValueError("chips_list and batch_list must be non-empty")
    if not algorithms:
        raise ValueError("need at least one algorithm (or 'auto')")
    menu = collectives.ALGORITHMS
    algo_codes = [-1 if a == "auto"
                  else menu.index(collectives.canonical_algorithm(a))
                  for a in algorithms]

    cand = _enumerate_candidates(cfg, chips_list, batch_list, max_pp,
                                 algo_codes)
    dp = cand["dp"].astype(np.float64)
    tp = cand["tp"].astype(np.float64)
    pp = cand["pp"].astype(np.float64)
    m = cand["microbatches"].astype(np.float64)
    code = np.asarray(algo_codes, dtype=np.int64)[cand["req_idx"]]
    batch = np.asarray(batch_list, dtype=np.float64)[cand["batch_idx"]]

    n_total, n_active = param_counts(cfg)
    width = _model_width(cfg)
    tokens = batch if cfg.family == "mlp" else batch * float(seq)
    act_dtype = 4 if cfg.family == "mlp" else 2     # fp32 MLP, bf16 LMs
    syncs = 4.0 if cfg.family in _ATTENTION_FAMILIES else 2.0
    params_bytes = n_total * 4.0                    # fp32 master weights

    # --- per-candidate work terms (step- and microbatch-level) ---------------
    flops_step = 6.0 * n_active * tokens / (dp * tp * pp)
    flops_mb = flops_step / m
    act_bytes = (tokens / dp) * width * act_dtype   # one boundary activation
    act_mb = act_bytes / m
    stage_layers = float(cfg.n_layers) / pp
    mem_mb = params_bytes / (tp * pp) + 2.0 * stage_layers * act_mb

    # --- per-axis link routing as boolean masks ------------------------------
    # extents: tp rides stride 1, pp stride tp, dp stride tp·pp
    if pod_size is None:
        dp_pod = tp_pod = pp_pod = np.zeros(dp.shape, dtype=bool)
    else:
        dp_pod = (dp > 1) & (dp * tp * pp > pod_size)
        pp_pod = (pp > 1) & (pp * tp > pod_size)
        tp_pod = (tp > 1) & (tp > pod_size)
        if bool(dp_pod.any() | pp_pod.any() | tp_pod.any()):
            hw.bandwidth_for(POD_LINK)  # actionable KeyError if spec has none
    bw_pri, a_pri = hw.bandwidth_for(None), hw.alpha_for(None)
    if pod_size is not None and POD_LINK in hw.extra_links:
        bw_pod, a_pod = hw.bandwidth_for(POD_LINK), hw.alpha_for(POD_LINK)
    else:
        bw_pod, a_pod = bw_pri, a_pri
    dp_bw = np.where(dp_pod, bw_pod, bw_pri)
    dp_alpha = np.where(dp_pod, a_pod, a_pri)
    tp_bw = np.where(tp_pod, bw_pod, bw_pri)
    tp_alpha = np.where(tp_pod, a_pod, a_pri)
    pp_bw = np.where(pp_pod, bw_pod, bw_pri)
    pp_alpha = np.where(pp_pod, a_pod, a_pri)

    # --- collective algorithm selection, per axis, whole grid at once --------
    # "auto" rows see the full menu; fixed rows see exactly their algorithm
    allowed = (code[None, :] < 0) | \
        (np.arange(len(menu))[:, None] == code[None, :])
    dp_wire, dp_steps, dp_sel = collectives.best_all_reduce_grid(
        params_bytes / (tp * pp), dp, dp_bw, dp_alpha, menu, allowed=allowed)
    tp_wire, tp_steps, tp_sel = collectives.best_all_reduce_grid(
        act_mb, tp, tp_bw, tp_alpha, menu, allowed=allowed)
    dp_time = dp_alpha * dp_steps + dp_wire / dp_bw
    tp_scale = syncs * stage_layers                 # syncs per microbatch
    tp_wire_mb = tp_scale * tp_wire
    tp_steps_mb = tp_scale * tp_steps
    tp_time = tp_alpha * tp_steps_mb + tp_wire_mb / tp_bw

    # pp boundary p2p: 2 hops (act fwd + grad bwd) per microbatch
    pp_bytes_mb = collectives.pp_boundary_bytes(act_mb, pp)
    pp_steps_mb = 2.0 * np.where(pp > 1.0, 1.0, 0.0)
    pp_time = pp_alpha * pp_steps_mb + pp_bytes_mb / pp_bw

    # --- 1F1B pipeline fill + one Ridgeline sweep over the candidate set -----
    # The serialized critical path holds m + pp − 1 microbatch slots
    # (t_step = (m + pp − 1) · t_microbatch = (m + pp − 1)/m · t_work), so
    # each per-microbatch resource time scales by `fill`; expressed as a
    # per-candidate derating of the machine peaks (peak/fill, α·fill) so
    # one vectorized sweep prices and classifies everything.  At
    # pp = m = 1 the fill is exactly 1.0 and every number is bit-for-bit
    # the PR 4 non-pipelined model.
    fill = m + pp - 1.0
    # dp grad sync runs once per step (after the last backward), unfilled;
    # per-axis α–β times fold into primary-link-equivalent bytes
    t_net_step = fill * (tp_time + pp_time) + dp_time
    eff_net_bytes = t_net_step * hw.net_bw
    res = sweep_mod.sweep(
        flops_mb, mem_mb, eff_net_bytes, hw,
        peak_flops=hw.peak_flops / fill, hbm_bw=hw.hbm_bw / fill,
        alpha_compute=hw.alpha_compute * fill,
        alpha_memory=hw.alpha_memory * fill, net_steps=0.0)

    attained = np.where(res.runtime > 0,
                        sweep_mod._safe_div(flops_step, res.runtime), 0.0)
    err = max(float(hw.model_rel_error), 0.0)
    return PlanGrid(
        cfg_name=cfg.name, hardware=hw.name,
        chips_list=tuple(int(c) for c in chips_list),
        batch_list=tuple(int(b) for b in batch_list),
        seq=seq, pod_size=pod_size, max_pp=max_pp,
        algorithms=tuple(algorithms),
        chips_idx=cand["chips_idx"], batch_idx=cand["batch_idx"],
        dp=cand["dp"], tp=cand["tp"], pp=cand["pp"],
        microbatches=cand["microbatches"], req_idx=cand["req_idx"],
        dp_algo_idx=dp_sel, tp_algo_idx=tp_sel,
        dp_pod=dp_pod, tp_pod=tp_pod, pp_pod=pp_pod,
        flops=flops_step, mem_bytes=m * mem_mb,
        net_bytes=dp_wire + m * tp_wire_mb + m * pp_bytes_mb,
        net_steps=dp_steps + m * tp_steps_mb + m * pp_steps_mb,
        t_compute=res.t_compute, t_memory=res.t_memory,
        t_network=res.t_network, runtime=res.runtime,
        bottleneck=res.bottleneck,
        peak_fraction=sweep_mod._safe_div(attained, hw.peak_flops),
        runtime_lo=np.maximum(res.runtime * (1.0 - err), 0.0),
        runtime_hi=res.runtime * (1.0 + err))
