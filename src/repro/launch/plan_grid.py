"""Grid-scale vectorized planning engine: the planner's evaluation core.

``plan_grid(cfg, hw, chips_list, batch_list, ...)`` evaluates the full
cartesian candidate space

    (dp × tp × pp × ep) × microbatch × collective-algorithm × batch × chips

in NumPy broadcast passes — no per-candidate Python loop anywhere on the
evaluation path.  Candidate enumeration (divisor lists, feasibility
filters) is plain integer bookkeeping; everything priced — collective
wire bytes, α–β link times, algorithm argmins, the Ridgeline sweep — runs
on flat float64 arrays over the whole candidate set at once, which is
what turns N separate ``plan()`` calls into one pass at ≥10⁵
candidates/s (see ``BENCH_ridgeline.json`` → ``planner_grid_*``).

``repro.launch.plan.plan`` is a thin slice of this engine (one chips, one
batch, ``max_pp=1``), so there is exactly one evaluation core; its
``pp = 1`` output is regression-pinned bit-identical to the PR 4
per-candidate planner (``tests/test_plan_grid.py``).

**Mesh layout.**  Axes nest tp-inner / ep-next / pp-middle / dp-outer,
so a ring over the tp axis has stride 1, the ep axis stride tp, the pp
axis stride tp·ep, and the dp axis stride tp·ep·pp.  With ``pod_size``
set, any axis whose extent (size · stride) exceeds the pod is priced at
the spec's ``pod`` link — the slowest hop bounds a ring — expressed here
as a boolean mask per candidate with the link bandwidth/α gathered
elementwise.

**Expert parallelism (ISSUE 9).**  ``max_ep > 1`` admits an ep axis for
MoE configs: ep must divide the padded expert count
``E_pad = max(n_experts, pad_experts_to)`` (mirroring the GQA
head-divisibility gate), the routed expert weights/grads/optimizer
states shard over ep (``launch/memory`` and the streamed-weights term
here), and every MoE layer pays a capacity-factor-aware dispatch +
combine all-to-all on the ep axis's own pod-routed link
(``collectives.ep_dispatch_combine``, α·steps + bytes/bw like every
other axis).  Top-k routing imbalance enters as a ``max_load/mean_load``
derate (:func:`moe_routing_derate`) multiplying both the per-chip expert
FLOPs and the dispatch wire bytes; dense blocks (attention, router,
shared experts) are priced as replicated across ep — the conservative
GShard accounting, where ep buys expert-side compute/memory sharding at
the price of all-to-all traffic.  Every ep = 1 lane is overlaid with
``np.where``/additive-zero identities, so the default ``max_ep = 1``
search stays bit-identical to the PR 4/5/6 goldens.

**Pipeline parallelism (1F1B).**  A pp-way candidate splits the layer
stack into ``pp`` stages (``pp ≤ n_layers``; when pp ∤ n_layers the
stack ceil-splits unevenly and the widest ``ceil(L/pp)``-layer stage
sets the critical path — per-stage work scales by
``ceil(L/pp)·pp/L ≥ 1``, exactly 1.0 when pp divides L) and the per-dp
batch into ``m`` microbatches (m must divide ``batch/dp``).  The 1F1B
schedule keeps ``pp − 1`` microbatch slots of bubble at the ramp, so the
step time inflates by the bubble factor

    t_step ≈ (m + pp − 1)/m · t_microbatch_work

equivalently ``t_step = (m + pp − 1) · t_microbatch`` — with each
microbatch additionally paying 2 point-to-point activation hops
(boundary activation forward, its gradient backward) priced α–β on the
link the pp axis rides.  The fill factor ``m + pp − 1`` enters the
Ridgeline sweep as a per-candidate *derating of the machine peaks*
(peak/fill, hbm/fill, α·fill against per-microbatch work), so
classification and projected runtime stay one ``core.sweep`` call; at
pp = m = 1 the fill is exactly 1.0 and every number is bit-for-bit the
non-pipelined model.  The dp gradient
all-reduce runs once per step (after the last microbatch) and is not
bubbled.  Per-microbatch memory re-streams the stage weights
(weights + boundary activations per traversal), which reduces exactly to
the PR 4 accounting at pp = m = 1.  ``interleave = v > 1`` prices the
interleaved-1F1B schedule: each chip holds ``v_eff = min(v, L // pp)``
virtual stage chunks, shrinking the ramp bubble to ``(pp − 1)/v_eff``
microbatch slots at the cost of ``v_eff×`` the boundary p2p traffic
(every chunk boundary crosses chips).  ``interleave = 1`` (default) is
the classic schedule, bit-for-bit.

**Memory feasibility (ISSUE 6).**  Before any pricing pass, every
candidate's per-chip working set (``launch/memory``: params + grads +
optimizer states over tp·pp, activations × in-flight 1F1B microbatches) is
checked against ``hw.hbm_capacity_bytes``; candidates that cannot fit are
pruned from the struct-of-arrays — they shrink every downstream broadcast
pass instead of being ranked as "fastest".  ``zero_stages`` adds ZeRO
sharding as a candidate axis: stage 1/2/3 shard optimizer states /
gradients / parameters across dp, shrinking the footprint while the dp
sync is repriced as reduce-scatter + all-gather traffic
(``collectives.zero_dp_sync`` — structural, not an algorithm choice).
``remat=True`` halves the saved-activation footprint at +1/3 recompute
FLOPs.  The default ``zero_stages=(0,)``/``remat=False`` keeps the
zero-0 slice bit-identical to the PR 4/5 goldens; a spec with capacity 0
(unknown — every custom spec's default) disables the cut entirely.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.contracts import shape_contract
from repro.core import sweep as sweep_mod
from repro.core.hardware import HardwareSpec, get_hardware
from repro.distributed import collectives
from repro.launch import memory as memory_mod
from repro.obs import trace
from repro.resilience.failures import FailureModel
from repro.resilience import failures as failures_mod

if TYPE_CHECKING:  # jax-backed; planning itself is numpy-only
    from repro.models.common import ModelConfig

#: families with attention/MoE blocks -> Megatron-style 4 syncs per layer
_ATTENTION_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")

#: display shorthand for algorithm tags (table column stays narrow)
_ALGO_SHORT = {"ring": "ring", "bidir_ring": "bidir", "tree": "tree"}

#: mesh-axis tag of the inter-pod link in ``HardwareSpec.extra_links``
POD_LINK = "pod"

#: the ZeRO stages a candidate axis may take (0 = unsharded states)
ZERO_STAGES = (0, 1, 2, 3)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One ranked candidate: the mesh, its terms, and its projection."""

    dp: int
    tp: int
    algorithm: str               # requested: a concrete tag or "auto"
    flops: float                 # per chip, per step
    mem_bytes: float
    net_bytes: float             # wire bytes across all axes
    t_compute: float
    t_memory: float
    t_network: float             # α–β time, per-axis links (+ pipeline bubble)
    runtime: float               # projected step time (bound); under
    #                              goodput planning the failure overhead
    #                              terms are folded in (effective step time)
    bottleneck: str
    peak_fraction: float
    net_steps: float = 0.0       # serialized hops across all axes
    dp_link: str = "ici"         # link the dp grad sync rides
    tp_link: str = "ici"         # link the tp act syncs ride
    dp_algo: str = "ring"        # algorithm the dp grad sync uses ("-" when
    #                              the axis is size 1: no collective runs)
    tp_algo: str = "ring"        # algorithm the tp act syncs use
    runtime_lo: float = 0.0      # runtime·(1−e), e = hw.model_rel_error
    runtime_hi: float = 0.0      # runtime·(1+e); lo == hi == runtime when
    #                              the spec carries no measured error
    pp: int = 1                  # pipeline stages (1 = no pipeline axis)
    microbatches: int = 1        # 1F1B microbatch count m
    pp_link: str = "ici"         # link the pp boundary p2p rides
    zero_stage: int = 0          # ZeRO: 1/2/3 shard opt/grads/params over dp
    hbm_bytes: float = 0.0       # modeled per-chip working set
    fits: bool = True            # hbm_bytes <= hw.hbm_capacity_bytes (or
    #                              the spec carries no capacity: trivially True)
    remat: bool = False          # activations rematerialized (+1/3 FLOPs)
    ep: int = 1                  # expert-parallel axis (1 = no ep axis)
    ep_link: str = "ici"         # link the ep dispatch/combine a2a rides
    vstages: int = 1             # interleaved-1F1B virtual stages per chip
    goodput: float = 1.0         # delivered share of wall clock (1.0 when
    #                              failures are unmodeled or MTBF = inf)
    ckpt_overhead_s: float = 0.0  # per-step amortized checkpoint write
    rework_s: float = 0.0        # per-step expected replayed work
    restart_s: float = 0.0       # per-step expected restart + reshard
    ckpt_interval_s: float = 0.0  # Young/Daly τ* (0 when failure-free)

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.pp * self.ep

    @property
    def hbm_used_gb(self) -> float:
        """The working set in decimal gigabytes (display convenience)."""
        return self.hbm_bytes / 1e9

    @property
    def mesh(self) -> str:
        base = f"dp{self.dp}xtp{self.tp}"
        return (base + (f"xpp{self.pp}" if self.pp > 1 else "")
                + (f"xep{self.ep}" if self.ep > 1 else ""))

    @property
    def bubble_fraction(self) -> float:
        """Fraction of the pipelined step spent in the 1F1B ramp bubble
        (interleaving divides the ramp by the virtual-stage count)."""
        ramp = (self.pp - 1.0) / self.vstages
        return ramp / (self.microbatches + ramp)

    @property
    def algo_label(self) -> str:
        """Selected algorithms, compact: one tag when the axes agree."""
        axes = [_ALGO_SHORT.get(a, a) for a in (self.dp_algo, self.tp_algo)
                if a != "-"]
        if not axes:
            return "-"
        if len(set(axes)) == 1:
            return axes[0]
        return "+".join(axes)


@functools.lru_cache(maxsize=None)
def _divisors(n: int) -> Tuple[int, ...]:
    """All divisors of n, ascending, by O(√n) enumeration."""
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


def _factor_pairs(chips: int) -> List[Tuple[int, int]]:
    """(chips//t, t) for every divisor t, t ascending — O(√chips)."""
    return [(chips // t, t) for t in _divisors(chips)]


def _model_width(cfg: ModelConfig) -> int:
    return cfg.mlp_widths[0] if cfg.family == "mlp" else cfg.d_model


@functools.lru_cache(maxsize=None)
def param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameter counts; closed-form for the MLP family.

    The MLP tower is counted without jax so the planner CLI stays fast on a
    bare CPU box; every other family defers to the eval_shape-exact
    accounting in ``launch/specs``.  Memoized on the (frozen, hashable)
    config, so the eval_shape trace runs once per model per process no
    matter how many ``plan``/``plan_grid`` calls follow.
    """
    if cfg.family == "mlp":
        widths = cfg.mlp_widths
        n = 0.0
        for i, w in enumerate(widths):
            d_in = widths[i - 1] if i else widths[0]
            n += d_in * w + w
        n += widths[-1] * 1 + 1                     # head
        return n, n
    from repro.launch.specs import param_counts as exact
    return exact(cfg)


def _tp_ok(tp: int, width: int, n_heads: int, n_kv_heads: int) -> bool:
    """Can a tp-way split actually shard the model (integer form)?

    Beyond ``tp | width``, attention models split Megatron-TP by *heads*:
    tp must divide ``n_heads``, and — where GQA defines a smaller KV head
    count — ``n_kv_heads`` too, or the sharding layer
    (``launch/dryrun._rules_for`` / ``distributed.sharding.gqa_safe_rules``)
    falls back to a different layout than the one the planner prices.
    Head-less families (``n_heads == 0``, e.g. the MLP tower) only need
    the width check.
    """
    if width % tp:
        return False
    if tp <= 1 or not n_heads:
        return True
    if n_heads % tp:
        return False
    return not (0 < n_kv_heads < n_heads and n_kv_heads % tp)


def feasible_meshes(cfg: ModelConfig, chips: int,
                    batch: int) -> List[Tuple[int, int]]:
    """(dp, tp) with dp·tp == chips, dp | batch, tp | width (and heads)."""
    width = _model_width(cfg)
    return [(dp, tp) for dp, tp in _factor_pairs(chips)
            if batch % dp == 0
            and _tp_ok(tp, width, cfg.n_heads, cfg.n_kv_heads)]


def pp_choices(cfg: ModelConfig, chips: int, max_pp: int) -> List[int]:
    """Pipeline sizes: divide the chip budget, fit inside the layer stack.

    Stage counts need not divide ``n_layers`` — the stack ceil-splits,
    with the widest stage setting the critical path — but a stage count
    beyond the layer count would leave empty stages, so ``pp ≤ n_layers``.
    """
    return [p for p in _divisors(chips)
            if p <= max_pp and p <= cfg.n_layers]


def _padded_experts(cfg: ModelConfig) -> int:
    """E_pad = max(n_experts, pad_experts_to); 0 for expert-less configs."""
    if getattr(cfg, "n_experts", 0) <= 0:
        return 0
    return max(cfg.n_experts, cfg.pad_experts_to)


def ep_choices(cfg: ModelConfig, chips: int, max_ep: int) -> List[int]:
    """Expert-parallel sizes: divide the chip budget and the padded expert
    count ``E_pad`` (padding experts buy divisibility; a shard boundary
    through an expert tensor would not).  ep = 1 is always feasible."""
    e_pad = _padded_experts(cfg)
    return [e for e in _divisors(chips)
            if e <= max_ep and (e == 1 or (e_pad > 0 and e_pad % e == 0))]


def microbatch_choices(batch_per_dp: int, pp: int) -> Tuple[int, ...]:
    """1F1B microbatch counts m: divisors of the per-dp batch with m ≥ pp.

    A pp = 1 candidate has no pipeline to fill, so splitting the batch
    only adds dispatch α without changing any bandwidth term — m is
    pinned to 1 there (which is also what keeps the pp = 1 slice
    bit-identical to the pre-grid planner).  For pp > 1, m < pp describes
    a pipeline that never fills — the 1F1B schedule holds
    ``m + pp − 1`` slots but fewer than pp stages ever run concurrently,
    and the fill algebra would price phantom overlap — so those divisors
    are excluded (possibly leaving no choice at all, which removes the
    (dp, pp) pair from the candidate space).
    """
    if pp <= 1:
        return (1,)
    return tuple(m for m in _divisors(batch_per_dp) if m >= pp)


# --- the broadcast evaluation core --------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExplainTerms:
    """Additive attribution terms, elementwise-aligned with the grid arrays.

    Computed only under ``plan_grid(..., explain=True)``; every array has
    length ``n_candidates``.  The splits are exact complements of the
    engine's own numbers — ``comp_flops_s = t_compute − comp_alpha_s``
    etc. — so whichever resource bound a candidate, that resource's terms
    sum to the priced time (``repro.obs.explain`` builds the per-candidate
    ``breakdown`` from these; the network side sums to ``t_network`` only
    within float tolerance, because the engine folds the α–β axis times
    through a net_bw multiply/divide round-trip).

    Every field is SECONDS (the ``_s`` suffix is a units-lint declaration):
    the ``*_bytes_s``/``*_flops_s`` halves are the traffic-over-bandwidth /
    work-over-ceiling *times*, not the raw traffic.
    """

    comp_alpha_s: np.ndarray             # α_C·fill dispatch share of t_compute
    comp_flops_s: np.ndarray             # F/(peak·eff) share (t_compute − α)
    mem_alpha_s: np.ndarray
    mem_bytes_s: np.ndarray
    net_dp_alpha_s: np.ndarray           # dp grad sync: α·steps (once/step)
    net_dp_bytes_s: np.ndarray           # dp grad sync: wire/bw
    net_tp_alpha_s: np.ndarray           # tp act syncs: fill·α·steps
    net_tp_bytes_s: np.ndarray           # tp act syncs: fill·wire/bw
    net_pp_alpha_s: np.ndarray           # pp boundary p2p: fill·α·hops
    net_pp_bytes_s: np.ndarray           # pp boundary p2p: fill·bytes/bw
    net_ep_alpha_s: np.ndarray           # ep dispatch a2a: fill·α·hops
    net_ep_bytes_s: np.ndarray           # ep dispatch a2a: fill·wire/bw


@dataclasses.dataclass(frozen=True)
class PlanGrid:
    """Flat struct-of-arrays result of one ``plan_grid`` pass.

    Every field of length ``n_candidates`` lines up elementwise;
    ``chips_idx``/``batch_idx`` map each candidate back to its grid point.
    ``plans(chips, batch)`` materializes ranked :class:`MeshPlan` rows for
    one point (that is the only per-candidate Python in the module, and it
    is display-path only); ``best_runtime_grid()`` reduces the whole grid
    without materializing anything.
    """

    cfg_name: str
    hardware: str
    chips_list: Tuple[int, ...]
    batch_list: Tuple[int, ...]
    seq: int
    pod_size: Optional[int]
    max_pp: int
    max_ep: int
    interleave: int                      # interleaved-1F1B virtual stage cap
    algorithms: Tuple[str, ...]          # requested, raw (may include "auto")
    zero_stages: Tuple[int, ...]         # searched ZeRO stages
    remat: bool
    hbm_capacity_bytes: float            # the budget candidates were cut by
    check_capacity: bool                 # False: infeasible rows kept, marked

    chips_idx: np.ndarray                # int, index into chips_list
    batch_idx: np.ndarray                # int, index into batch_list
    dp: np.ndarray
    tp: np.ndarray
    pp: np.ndarray
    ep: np.ndarray
    microbatches: np.ndarray
    zero: np.ndarray                     # per-candidate ZeRO stage
    req_idx: np.ndarray                  # index into `algorithms`
    dp_algo_idx: np.ndarray              # into collectives.ALGORITHMS
    tp_algo_idx: np.ndarray
    dp_pod: np.ndarray                   # bool: axis priced at the pod link
    tp_pod: np.ndarray
    pp_pod: np.ndarray
    ep_pod: np.ndarray
    vstages: np.ndarray                  # interleaved virtual stages (int)

    flops: np.ndarray                    # per chip per step
    mem_bytes: np.ndarray
    net_bytes: np.ndarray
    net_steps: np.ndarray
    t_compute: np.ndarray
    t_memory: np.ndarray
    t_network: np.ndarray
    runtime: np.ndarray
    bottleneck: np.ndarray               # int8 codes into sweep.RESOURCE_ORDER
    peak_fraction: np.ndarray
    runtime_lo: np.ndarray
    runtime_hi: np.ndarray

    hbm_bytes: np.ndarray                # per-candidate working set (memory.py)
    fits: np.ndarray                     # bool; all True after a capacity cut
    n_enumerated: int                    # candidates before the capacity cut
    n_pruned: np.ndarray                 # (n_chips, n_batch) cut per point
    min_zero_to_fit: np.ndarray          # (n_chips, n_batch) smallest surviving
    #                                      ZeRO stage per point (the
    #                                      "infeasible without ZeRO-k" k)

    # attribution payload — populated only under explain=True (obs.explain)
    explain_terms: Optional[ExplainTerms] = None
    prune_reasons: Optional[Dict[Tuple[int, int], Dict[str, int]]] = None
    #                                    ^ (ci, bi) -> enumeration prune counts

    # failure-aware goodput overlay — populated only under goodput=True
    # (repro.resilience.failures); `runtime` then carries the overhead
    # terms additively: runtime = max(t_C, t_M, t_N) + ckpt + rework +
    # restart, which is what flips rankings toward smaller meshes
    failure: Optional[FailureModel] = None
    goodput: Optional[np.ndarray] = None
    ckpt_overhead_s: Optional[np.ndarray] = None
    rework_s: Optional[np.ndarray] = None
    restart_s: Optional[np.ndarray] = None
    ckpt_interval_s: Optional[np.ndarray] = None

    @property
    def n_candidates(self) -> int:
        return int(self.runtime.size)

    @property
    def pruned_fraction(self) -> float:
        """Share of enumerated candidates the capacity mask removed."""
        if self.n_enumerated <= 0:
            return 0.0
        return 1.0 - self.n_candidates / self.n_enumerated

    def labels(self) -> np.ndarray:
        return sweep_mod._LABELS[self.bottleneck]

    def _point(self, chips: Optional[int], batch: Optional[int]
               ) -> Tuple[int, int]:
        ci = 0 if chips is None else self.chips_list.index(chips)
        bi = 0 if batch is None else self.batch_list.index(batch)
        return ci, bi

    def point_indices(self, chips: Optional[int] = None,
                      batch: Optional[int] = None) -> np.ndarray:
        ci, bi = self._point(chips, batch)
        return np.nonzero((self.chips_idx == ci)
                          & (self.batch_idx == bi))[0]

    def _mesh_plan(self, i: int) -> MeshPlan:
        dp, tp, pp = int(self.dp[i]), int(self.tp[i]), int(self.pp[i])
        zero = int(self.zero[i])
        algs = collectives.ALGORITHMS
        return MeshPlan(
            dp=dp, tp=tp,
            algorithm=self.algorithms[int(self.req_idx[i])],
            flops=float(self.flops[i]),
            mem_bytes=float(self.mem_bytes[i]),
            net_bytes=float(self.net_bytes[i]),
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_network=float(self.t_network[i]),
            runtime=float(self.runtime[i]),
            bottleneck=str(self.labels()[i]),
            peak_fraction=float(self.peak_fraction[i]),
            net_steps=float(self.net_steps[i]),
            dp_link=POD_LINK if self.dp_pod[i] else "ici",
            tp_link=POD_LINK if self.tp_pod[i] else "ici",
            # ZeRO's RS+AG dp sync is structural, not an algorithm choice
            dp_algo="-" if dp <= 1 else
            ("rs+ag" if zero >= 1 else algs[int(self.dp_algo_idx[i])]),
            tp_algo="-" if tp <= 1 else algs[int(self.tp_algo_idx[i])],
            runtime_lo=float(self.runtime_lo[i]),
            runtime_hi=float(self.runtime_hi[i]),
            pp=pp, microbatches=int(self.microbatches[i]),
            pp_link=POD_LINK if self.pp_pod[i] else "ici",
            zero_stage=zero, hbm_bytes=float(self.hbm_bytes[i]),
            fits=bool(self.fits[i]), remat=self.remat,
            ep=int(self.ep[i]),
            ep_link=POD_LINK if self.ep_pod[i] else "ici",
            vstages=int(self.vstages[i]),
            goodput=(1.0 if self.goodput is None
                     else float(self.goodput[i])),
            ckpt_overhead_s=(0.0 if self.ckpt_overhead_s is None
                             else float(self.ckpt_overhead_s[i])),
            rework_s=(0.0 if self.rework_s is None
                      else float(self.rework_s[i])),
            restart_s=(0.0 if self.restart_s is None
                       else float(self.restart_s[i])),
            ckpt_interval_s=(0.0 if self.ckpt_interval_s is None
                             else float(self.ckpt_interval_s[i])))

    def plans(self, chips: Optional[int] = None,
              batch: Optional[int] = None) -> List[MeshPlan]:
        """Ranked candidates of one grid point (runtime, then smaller tp)."""
        idx = self.point_indices(chips, batch)
        order = sorted(idx.tolist(),
                       key=lambda i: (self.runtime[i], self.tp[i],
                                      self.zero[i]))
        return [self._mesh_plan(i) for i in order]

    def best(self, chips: Optional[int] = None,
             batch: Optional[int] = None) -> MeshPlan:
        idx = self.point_indices(chips, batch)
        i = min(idx.tolist(), key=lambda i: (self.runtime[i], self.tp[i],
                                             self.zero[i]))
        return self._mesh_plan(i)

    def best_runtime_grid(self) -> np.ndarray:
        """min projected step time per grid point — (n_chips, n_batch)."""
        out = np.full((len(self.chips_list), len(self.batch_list)), np.inf)
        np.minimum.at(out, (self.chips_idx, self.batch_idx), self.runtime)
        return out


@functools.lru_cache(maxsize=4096)
def _point_candidates(width: int, n_heads: int, n_kv_heads: int,
                      n_layers: int, e_pad: int, chips: int, batch: int,
                      max_pp: int, max_ep: int) -> Tuple[np.ndarray, ...]:
    """(dp, tp, pp, ep, m) arrays for one grid point — pure integer work.

    Keyed on the integers that actually determine feasibility (model
    width, head counts, layer count, padded expert count, chip budget,
    batch, pp/ep caps), so repeated grid points — N ``plan()`` calls over
    the same configs, or overlapping grids — enumerate once per process.
    Callers must treat the returned arrays as immutable (they are shared
    cache entries).  The ep gate mirrors the GQA head gate: ep must
    divide ``e_pad`` (an ep > 1 axis on an expert-less config is never
    feasible); ep = 1 is always kept, so ``max_ep = 1`` reproduces the
    three-axis candidate space exactly.
    """
    dp_l: List[int] = []
    tp_l: List[int] = []
    pp_l: List[int] = []
    ep_l: List[int] = []
    m_l: List[int] = []
    for pp in _divisors(chips):
        if pp > max_pp or pp > n_layers:
            continue
        for ep in _divisors(chips // pp):
            if ep > max_ep:
                continue
            if ep > 1 and (e_pad <= 0 or e_pad % ep):
                continue
            for dp, tp in _factor_pairs(chips // pp // ep):
                if batch % dp or not _tp_ok(tp, width, n_heads, n_kv_heads):
                    continue
                for m in microbatch_choices(batch // dp, pp):
                    dp_l.append(dp)
                    tp_l.append(tp)
                    pp_l.append(pp)
                    ep_l.append(ep)
                    m_l.append(m)
    return (np.asarray(dp_l, dtype=np.int64),
            np.asarray(tp_l, dtype=np.int64),
            np.asarray(pp_l, dtype=np.int64),
            np.asarray(ep_l, dtype=np.int64),
            np.asarray(m_l, dtype=np.int64))


@functools.lru_cache(maxsize=4096)
def _point_prune_stats(width: int, n_heads: int, n_kv_heads: int,
                       n_layers: int, e_pad: int, chips: int, batch: int,
                       max_pp: int, max_ep: int
                       ) -> Tuple[Tuple[str, int], ...]:
    """Why raw tuples fell out of one grid point's enumeration, by gate.

    The shadow of :func:`_point_candidates`: walks the same divisor space
    but counts what each feasibility gate rejected instead of keeping the
    survivors — the structured half of ``--explain``'s prune account (the
    capacity cut is the other half; it happens downstream on enumerated
    candidates and is reported from ``PlanGrid.n_pruned``).  Units: the
    two pp gates count (dp, tp) pairs under the rejected pp (at ep = 1);
    the two ep gates count (dp, tp) pairs under the rejected (pp, ep);
    the dp/tp gates count (dp, tp, pp, ep) tuples; ``microbatch_lt_pp``
    counts (dp, tp, pp, ep, m) tuples whose 1F1B pipeline would never
    fill (m < pp); ``kept_mesh_tuples`` counts the (dp, tp, pp, ep, m)
    tuples that reached pricing — before the zero/algorithm axes are
    tiled on.  Cached alongside the candidate cache; kept separate so the
    hot enumeration path never pays for bookkeeping it only needs under
    ``explain=True``.
    """
    stats = {"pp_exceeds_max_pp": 0, "pp_exceeds_layers": 0,
             "ep_exceeds_max_ep": 0, "ep_expert_indivisible": 0,
             "batch_dp_indivisible": 0, "tp_shard_infeasible": 0,
             "microbatch_lt_pp": 0, "kept_mesh_tuples": 0}
    for pp in _divisors(chips):
        n_pairs = len(_divisors(chips // pp))
        if pp > max_pp:
            stats["pp_exceeds_max_pp"] += n_pairs
            continue
        if pp > n_layers:
            stats["pp_exceeds_layers"] += n_pairs
            continue
        for ep in _divisors(chips // pp):
            n_sub = len(_divisors(chips // pp // ep))
            if ep > max_ep:
                stats["ep_exceeds_max_ep"] += n_sub
                continue
            if ep > 1 and (e_pad <= 0 or e_pad % ep):
                stats["ep_expert_indivisible"] += n_sub
                continue
            for dp, tp in _factor_pairs(chips // pp // ep):
                if batch % dp:
                    stats["batch_dp_indivisible"] += 1
                    continue
                if not _tp_ok(tp, width, n_heads, n_kv_heads):
                    stats["tp_shard_infeasible"] += 1
                    continue
                if pp > 1:
                    divs = _divisors(batch // dp)
                    stats["microbatch_lt_pp"] += sum(1 for m in divs
                                                     if m < pp)
                    stats["kept_mesh_tuples"] += sum(1 for m in divs
                                                     if m >= pp)
                else:
                    stats["kept_mesh_tuples"] += 1
    return tuple(sorted(stats.items()))


def _enumerate_candidates(cfg: ModelConfig, chips_list: Sequence[int],
                          batch_list: Sequence[int], max_pp: int,
                          algo_codes: Sequence[int],
                          zero_stages: Sequence[int] = (0,),
                          max_ep: int = 1) -> Dict[str, np.ndarray]:
    """Flat candidate index arrays over the whole grid.

    Per-point enumeration is cached integer bookkeeping
    (:func:`_point_candidates`); the ZeRO axis, the algorithm axis, and
    the grid-point index columns are tiled on with numpy, so the warm
    path does no per-candidate Python at all.  Ordering is mesh-major,
    zero-middle, algorithm-minor; a zero > 0 row with dp == 1 would be
    numerically identical to its zero = 0 twin (nothing to shard over a
    size-1 axis), so those duplicates are dropped here.  Raises when a
    grid point has no feasible mesh, naming the point.
    """
    width = _model_width(cfg)
    e_pad = _padded_experts(cfg)
    n_req = len(algo_codes)
    req_range = np.arange(n_req, dtype=np.intp)
    zs = np.asarray(zero_stages, dtype=np.int64)
    cols: List[List[np.ndarray]] = [[] for _ in range(9)]
    for ci, chips in enumerate(chips_list):
        for bi, batch in enumerate(batch_list):
            dp_a, tp_a, pp_a, ep_a, m_a = _point_candidates(
                width, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers, e_pad,
                int(chips), int(batch), max_pp, max_ep)
            if dp_a.size == 0:
                raise ValueError(
                    f"no feasible (dp, tp, pp, ep) for chips={chips}, "
                    f"batch={batch}, width={width}"
                    + (f" (tp must divide n_heads={cfg.n_heads}"
                       + (f", n_kv_heads={cfg.n_kv_heads}"
                          if 0 < cfg.n_kv_heads < cfg.n_heads else "")
                       + ")" if cfg.n_heads else ""))
            # cross mesh rows with the ZeRO axis, dropping dp = 1 dupes
            dp_z = np.repeat(dp_a, zs.size)
            z_col = np.tile(zs, dp_a.size)
            keep = (dp_z > 1) | (z_col == zs[0]) \
                if (zs > 0).any() else slice(None)
            dp_z = dp_z[keep]
            tp_z = np.repeat(tp_a, zs.size)[keep]
            pp_z = np.repeat(pp_a, zs.size)[keep]
            ep_z = np.repeat(ep_a, zs.size)[keep]
            m_z = np.repeat(m_a, zs.size)[keep]
            z_col = z_col[keep]
            n = dp_z.size * n_req
            cols[0].append(np.full(n, ci, dtype=np.intp))
            cols[1].append(np.full(n, bi, dtype=np.intp))
            # mesh-major, algorithm-minor — the scalar planner's order
            cols[2].append(np.repeat(dp_z, n_req))
            cols[3].append(np.repeat(tp_z, n_req))
            cols[4].append(np.repeat(pp_z, n_req))
            cols[5].append(np.repeat(ep_z, n_req))
            cols[6].append(np.repeat(m_z, n_req))
            cols[7].append(np.repeat(z_col, n_req))
            cols[8].append(np.tile(req_range, dp_z.size))
    names = ("chips_idx", "batch_idx", "dp", "tp", "pp", "ep",
             "microbatches", "zero", "req_idx")
    return {name: np.concatenate(parts)
            for name, parts in zip(names, cols)}


def _capacity_error(cfg: ModelConfig, capacity: float, chips: int,
                    batch: int, seq: int, max_pp: int, remat: bool,
                    zero_stages: Sequence[int],
                    max_ep: int = 1) -> ValueError:
    """Actionable error for a grid point the capacity cut emptied."""
    width = _model_width(cfg)
    dp_a, tp_a, pp_a, ep_a, m_a = _point_candidates(
        width, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers,
        _padded_experts(cfg), int(chips), int(batch), max_pp, max_ep)
    need = memory_mod.min_zero_stage(
        cfg, capacity, batch=batch, seq=seq, dp=dp_a, tp=tp_a, pp=pp_a,
        ep=ep_a, microbatches=m_a, remat=remat)
    k = int(need.min()) if need.size else 4
    if k <= 3:
        hint = (f"infeasible without ZeRO-{k}: pass zero_stages "
                f"including {k} (CLI: --zero auto)")
    else:
        hint = ("no candidate fits even at ZeRO-3; try remat=True, "
                "more chips, or a smaller batch")
    return ValueError(
        f"no candidate fits in hbm_capacity_bytes={capacity:.3g} for "
        f"chips={chips}, batch={batch} "
        f"(searched zero_stages={tuple(zero_stages)}, remat={remat}) — "
        + hint)


@shape_contract("dp:(*g), tp:(*g), pp:(*g), ep:(*g) "
                "-> (*g), (*g), (*g), (*g)")
def _pod_masks(dp: np.ndarray, tp: np.ndarray, pp: np.ndarray,
               ep: np.ndarray, pod_size: Optional[int]
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Which mesh axes spill past the pod boundary onto the pod link.

    Extents along the chip grid: tp rides stride 1, ep stride tp, pp
    stride tp·ep, dp stride tp·ep·pp — an axis routes over the pod link
    when its outermost chip index exceeds ``pod_size``.  Returns
    ``(dp_pod, tp_pod, pp_pod, ep_pod)`` boolean masks of the broadcast
    candidate shape; ``pod_size=None`` (single-pod machine) keeps every
    axis on the primary link.  At ep = 1 every mask reduces exactly to
    the pre-ep three-axis layout.
    """
    if pod_size is None:
        z = np.zeros(np.broadcast_shapes(np.shape(dp), np.shape(tp),
                                         np.shape(pp), np.shape(ep)),
                     dtype=bool)
        return z, z, z, z
    dp_pod = (dp > 1) & (dp * tp * pp * ep > pod_size)
    pp_pod = (pp > 1) & (pp * ep * tp > pod_size)
    ep_pod = (ep > 1) & (ep * tp > pod_size)
    tp_pod = (tp > 1) & (tp > pod_size)
    return dp_pod, tp_pod, pp_pod, ep_pod


@shape_contract("ep:(*g), tokens_mb:(*g) -> (*g)")
def moe_routing_derate(ep: np.ndarray, tokens_mb: np.ndarray, *,
                       n_experts: int, pad_experts: int, top_k: int,
                       capacity_factor: float) -> np.ndarray:
    """Top-k routing-imbalance derate: expected max_load/mean_load per chip.

    Two multiplicative terms, both dimensionless and ≥ 1:

    * **padding skew** — experts shard ``E_pad / ep`` per chip but only
      ``E`` of them ever receive routing mass, so the most-loaded chip
      hosts up to ``min(E_pad/ep, E)`` live experts against a mean of
      ``E/ep``: derate ``min(E_pad/ep, E) · ep / E`` (exactly 1.0 when
      ``E_pad == E``).
    * **stochastic skew** — balanced routing still leaves balls-in-bins
      variance across ep chips; with ``λ = tokens_mb·k/ep`` expected
      choices per chip, ``max/mean ≈ 1 + sqrt(2·ln(ep)·(1 − 1/ep)/λ)``
      (Gaussian maximum of ep near-independent Poisson loads), capped by
      ``max(capacity_factor, 1.0)`` — the dispatch buffers physically
      drop anything beyond capacity.

    Every ep = 1 lane returns exactly 1.0 (``np.where`` overlay), so the
    derate is bit-invisible to non-ep candidates.
    """
    e = float(max(n_experts, 1))
    e_pad = float(max(n_experts, pad_experts, 1))
    k = float(max(top_k, 1))
    pad_derate = np.minimum(e_pad / ep, e) * ep / e
    lam = np.maximum(tokens_mb * k / ep, 1.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        stoch = 1.0 + np.sqrt(2.0 * np.log(ep) * (1.0 - 1.0 / ep) / lam)
    stoch = np.minimum(stoch, max(float(capacity_factor), 1.0))
    return np.where(ep > 1.0, pad_derate * stoch, 1.0)


def plan_grid(cfg: ModelConfig, hw: Union[HardwareSpec, str],
              chips_list: Sequence[int], batch_list: Sequence[int], *,
              seq: int = 1, algorithms: Sequence[str] = ("auto",),
              pod_size: Optional[int] = None, max_pp: int = 1,
              max_ep: int = 1, interleave: int = 1,
              zero_stages: Sequence[int] = (0,), remat: bool = False,
              check_capacity: bool = True, explain: bool = False,
              goodput: bool = False,
              failure: Optional[FailureModel] = None) -> PlanGrid:
    """Evaluate every (dp × tp × pp × ep) × m × zero × algorithm × batch
    × chips candidate in one broadcast pass.

    ``algorithms`` entries are concrete collective tags (including the
    ``bidir`` alias) or ``"auto"`` (per-axis α–β argmin over the full
    menu); each entry is its own candidate row, exactly like the scalar
    planner.  ``max_pp = 1`` (the default) reproduces the PR 4 candidate
    space bit-for-bit; larger values add every pipeline size that divides
    the chip budget and fits the layer stack (``pp ≤ n_layers``; an
    uneven ceil-split prices pp ∤ n_layers), crossed with every 1F1B
    microbatch count dividing the per-dp batch.  ``max_ep > 1`` admits
    expert-parallel sizes dividing both the chip budget and the padded
    expert count; ``interleave = v > 1`` prices the interleaved-1F1B
    schedule (ramp bubble ÷ ``min(v, L // pp)`` virtual stages at v×
    boundary p2p traffic).

    ``zero_stages`` adds ZeRO sharding stages as a candidate axis (the
    default ``(0,)`` searches none); ``remat=True`` rematerializes
    activations everywhere (half the saved-activation footprint, +1/3
    FLOPs).  When the spec carries a positive ``hbm_capacity_bytes`` and
    ``check_capacity`` is True, every candidate's working set
    (``launch/memory``) is priced first and infeasible candidates are
    pruned *before* the broadcast pricing passes; a grid point left with
    no feasible candidate raises a ValueError naming the point and the
    smallest ZeRO stage (or remat) that would save it.
    ``check_capacity=False`` keeps infeasible rows, merely marking
    ``fits``/``hbm_bytes`` — the what-if view.

    ``explain=True`` additionally carries the attribution payload:
    per-candidate additive term splits (:class:`ExplainTerms`) and
    per-point prune-reason counts (:func:`_point_prune_stats`), consumed
    by ``repro.obs.explain`` / CLI ``--explain``.  The flag never touches
    the priced numbers — every array the default path returns is
    bit-identical either way.

    ``goodput=True`` prices failures on top of the healthy step
    (``repro.resilience.failures``): each candidate's persisted
    checkpoint bytes (params + optimizer states under its ZeRO/tp/pp/ep
    sharding) over ``hw.ckpt_bw`` give its checkpoint cost, the Young/Daly
    interval sets the cadence, and the amortized per-step overheads —
    checkpoint write, expected rework, expected restart — are *added to*
    ``runtime`` before ranking, so a smaller mesh with a cheaper failure
    bill can beat the healthy winner.  ``failure`` supplies the mesh
    failure statistics (default: infinite per-chip MTBF, under which
    every overhead term is exactly 0.0 and the ranking is bit-identical
    to ``goodput=False``).

    Every pass runs under named trace spans (``plan_grid`` →
    ``enumerate`` / ``feasibility`` / ``price_collectives`` /
    ``sweep_classify``; see :mod:`repro.obs.trace`) that are no-ops
    unless tracing is enabled.
    """
    with trace.span("plan_grid", arch=getattr(cfg, "name", "?"),
                    n_chips=len(chips_list), n_batch=len(batch_list),
                    max_pp=max_pp, explain=explain) as sp:
        grid = _plan_grid_impl(
            cfg, hw, chips_list, batch_list, seq=seq, algorithms=algorithms,
            pod_size=pod_size, max_pp=max_pp, max_ep=max_ep,
            interleave=interleave, zero_stages=zero_stages,
            remat=remat, check_capacity=check_capacity, explain=explain,
            goodput=goodput, failure=failure)
        if trace.enabled():
            sp.set(n_enumerated=grid.n_enumerated,
                   n_candidates=grid.n_candidates,
                   n_pruned=int(grid.n_pruned.sum()))
            trace.count("planner.candidates_enumerated", grid.n_enumerated)
            trace.count("planner.candidates_evaluated", grid.n_candidates)
        return grid


def _plan_grid_impl(cfg: ModelConfig, hw: Union[HardwareSpec, str],
                    chips_list: Sequence[int], batch_list: Sequence[int], *,
                    seq: int, algorithms: Sequence[str],
                    pod_size: Optional[int], max_pp: int, max_ep: int,
                    interleave: int, zero_stages: Sequence[int],
                    remat: bool, check_capacity: bool, explain: bool,
                    goodput: bool = False,
                    failure: Optional[FailureModel] = None) -> PlanGrid:
    if isinstance(hw, str):
        hw = get_hardware(hw)
    if not chips_list or not batch_list:
        raise ValueError("chips_list and batch_list must be non-empty")
    if not algorithms:
        raise ValueError("need at least one algorithm (or 'auto')")
    if max_ep < 1:
        raise ValueError(f"max_ep must be >= 1, got {max_ep}")
    if interleave < 1:
        raise ValueError(f"interleave must be >= 1, got {interleave}")
    if not zero_stages:
        raise ValueError("need at least one ZeRO stage (0 = unsharded)")
    bad = [z for z in zero_stages if z not in ZERO_STAGES]
    if bad:
        raise ValueError(f"unknown ZeRO stage(s) {bad}; valid: "
                         f"{ZERO_STAGES}")
    menu = collectives.ALGORITHMS
    algo_codes = [-1 if a == "auto"
                  else menu.index(collectives.canonical_algorithm(a))
                  for a in algorithms]

    with trace.span("plan_grid.enumerate") as sp:
        cand = _enumerate_candidates(cfg, chips_list, batch_list, max_pp,
                                     algo_codes, tuple(int(z) for z in
                                                       zero_stages),
                                     max_ep=max_ep)
        n_enumerated = int(cand["dp"].size)
        sp.set(n_enumerated=n_enumerated)
    point_shape = (len(chips_list), len(batch_list))
    n_pruned = np.zeros(point_shape, dtype=np.int64)

    # --- memory feasibility: price the working set, cut before pricing -------
    with trace.span("plan_grid.feasibility") as sp:
        capacity = float(hw.hbm_capacity_bytes)
        batch_arr = np.asarray(batch_list, dtype=np.float64)
        ws = memory_mod.training_working_set(
            cfg, batch=batch_arr[cand["batch_idx"]], seq=seq,
            dp=cand["dp"], tp=cand["tp"], pp=cand["pp"], ep=cand["ep"],
            microbatches=cand["microbatches"], zero_stage=cand["zero"],
            remat=remat)
        hbm = ws.total
        # checkpoint bytes ride along so the goodput overlay (if any)
        # prices each surviving candidate's own sharded persisted state
        persisted = ws.persisted + np.zeros_like(hbm)
        fits = hbm <= capacity if capacity > 0 else \
            np.ones(hbm.shape, dtype=bool)
        if check_capacity and capacity > 0 and not fits.all():
            np.add.at(n_pruned, (cand["chips_idx"][~fits],
                                 cand["batch_idx"][~fits]), 1)
            survivors = np.zeros(point_shape, dtype=np.int64)
            np.add.at(survivors, (cand["chips_idx"], cand["batch_idx"]),
                      fits.astype(np.int64))
            if (survivors == 0).any():
                ci, bi = np.argwhere(survivors == 0)[0]
                raise _capacity_error(cfg, capacity, chips_list[ci],
                                      batch_list[bi], seq, max_pp, remat,
                                      zero_stages, max_ep=max_ep)
            cand = {k: v[fits] for k, v in cand.items()}
            hbm = hbm[fits]
            persisted = persisted[fits]
            fits = np.ones(hbm.shape, dtype=bool)
        min_zero_to_fit = np.full(point_shape, np.iinfo(np.int64).max)
        np.minimum.at(min_zero_to_fit,
                      (cand["chips_idx"], cand["batch_idx"]),
                      np.where(fits, cand["zero"],
                               np.iinfo(np.int64).max))
        sp.set(n_pruned=int(n_pruned.sum()), n_kept=int(cand["dp"].size))

    _sp_price = trace.span("plan_grid.price_collectives")
    _sp_price.__enter__()
    dp = cand["dp"].astype(np.float64)
    tp = cand["tp"].astype(np.float64)
    pp = cand["pp"].astype(np.float64)
    ep = cand["ep"].astype(np.float64)
    m = cand["microbatches"].astype(np.float64)
    zero = cand["zero"]
    code = np.asarray(algo_codes, dtype=np.int64)[cand["req_idx"]]
    batch = batch_arr[cand["batch_idx"]]

    n_total, n_active = param_counts(cfg)
    width = _model_width(cfg)
    tokens = batch if cfg.family == "mlp" else batch * float(seq)
    act_dtype = 4 if cfg.family == "mlp" else 2     # fp32 MLP, bf16 LMs
    syncs = 4.0 if cfg.family in _ATTENTION_FAMILIES else 2.0
    params_bytes = n_total * 4.0                    # fp32 master weights

    # --- per-candidate work terms (step- and microbatch-level) ---------------
    # ceil: when pp ∤ n_layers the widest stage sets the pipeline critical
    # path, inflating per-stage work by ceil(L/pp)·pp/L (exactly 1.0, and
    # bit-identical, when pp divides L)
    stage_layers = np.ceil(float(cfg.n_layers) / pp)
    uneven = stage_layers * pp / float(cfg.n_layers)
    flops_step = 6.0 * n_active * tokens / (dp * tp * pp) * uneven
    if remat:   # backward recomputes the forward: 6·N·tokens → 8·N·tokens
        flops_step = flops_step * memory_mod.REMAT_FLOPS_FACTOR
    # ep shards the routed experts: each chip holds E_pad/ep experts and
    # computes only its shard's routed FLOPs, derated by routing imbalance
    # (expert FLOPs are exp_share of active; the dense remainder — attention,
    # router, shared experts — replicates over ep).  The overlay leaves
    # every ep = 1 lane bit-untouched.
    ep_mask = ep > 1.0
    e_total = 0.0
    derate = 1.0
    if ep_mask.any():
        from repro.launch.specs import expert_param_counts
        e_total, e_active = expert_param_counts(cfg)
        tokens_mb = tokens / (dp * m)
        derate = moe_routing_derate(
            ep, tokens_mb, n_experts=cfg.n_experts,
            pad_experts=cfg.pad_experts_to, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor)
        exp_flops = 6.0 * e_active * tokens / (dp * tp * pp) * uneven
        if remat:
            exp_flops = exp_flops * memory_mod.REMAT_FLOPS_FACTOR
        flops_step = np.where(
            ep_mask, flops_step + exp_flops * (derate / ep - 1.0),
            flops_step)
    flops_mb = flops_step / m
    act_bytes = (tokens / dp) * width * act_dtype   # one boundary activation
    act_mb = act_bytes / m
    # ep also shards the streamed expert weights (fp32 master copies)
    params_stream = params_bytes
    if ep_mask.any() and e_total > 0.0:
        params_stream = np.where(
            ep_mask, params_bytes - e_total * 4.0 + e_total * 4.0 / ep,
            params_bytes)
    mem_mb = params_stream / (tp * pp) + 2.0 * stage_layers * act_mb

    # --- per-axis link routing as boolean masks ------------------------------
    dp_pod, tp_pod, pp_pod, ep_pod = _pod_masks(dp, tp, pp, ep, pod_size)
    if pod_size is not None and \
            bool(dp_pod.any() | pp_pod.any() | tp_pod.any()
                 | ep_pod.any()):
        hw.bandwidth_for(POD_LINK)  # actionable KeyError if spec has none
    bw_pri, a_pri = hw.bandwidth_for(None), hw.alpha_for(None)
    if pod_size is not None and POD_LINK in hw.extra_links:
        bw_pod, a_pod = hw.bandwidth_for(POD_LINK), hw.alpha_for(POD_LINK)
    else:
        bw_pod, a_pod = bw_pri, a_pri
    dp_bw = np.where(dp_pod, bw_pod, bw_pri)
    dp_alpha = np.where(dp_pod, a_pod, a_pri)
    tp_bw = np.where(tp_pod, bw_pod, bw_pri)
    tp_alpha = np.where(tp_pod, a_pod, a_pri)
    pp_bw = np.where(pp_pod, bw_pod, bw_pri)
    pp_alpha = np.where(pp_pod, a_pod, a_pri)
    ep_bw = np.where(ep_pod, bw_pod, bw_pri)
    ep_alpha = np.where(ep_pod, a_pod, a_pri)

    # --- collective algorithm selection, per axis, whole grid at once --------
    # "auto" rows see the full menu; fixed rows see exactly their algorithm
    allowed = (code[None, :] < 0) | \
        (np.arange(len(menu))[:, None] == code[None, :])
    dp_wire, dp_steps, dp_sel = collectives.best_all_reduce_grid(
        params_stream / (tp * pp), dp, dp_bw, dp_alpha, menu,
        allowed=allowed)
    tp_wire, tp_steps, tp_sel = collectives.best_all_reduce_grid(
        act_mb, tp, tp_bw, tp_alpha, menu, allowed=allowed)
    # ZeRO rows pin the dp sync to the structural RS+AG schedule — the
    # np.where overlay leaves every zero = 0 element bit-untouched, and
    # the guard skips the pass entirely on the default (0,) search
    zmask = zero >= 1
    if zmask.any():
        zcost = collectives.zero_dp_sync(params_stream / (tp * pp), dp,
                                         zero)
        dp_wire = np.where(zmask, zcost.wire_bytes, dp_wire)
        dp_steps = np.where(zmask, zcost.steps, dp_steps)
    dp_time = dp_alpha * dp_steps + dp_wire / dp_bw
    tp_scale = syncs * stage_layers                 # syncs per microbatch
    tp_wire_mb = tp_scale * tp_wire
    tp_steps_mb = tp_scale * tp_steps
    tp_time = tp_alpha * tp_steps_mb + tp_wire_mb / tp_bw

    # pp boundary p2p: 2 hops (act fwd + grad bwd) per microbatch; the
    # interleaved schedule multiplies boundary traffic by its virtual
    # stage count (every chunk boundary crosses chips)
    pp_bytes_mb = collectives.pp_boundary_bytes(act_mb, pp)
    pp_steps_mb = 2.0 * np.where(pp > 1.0, 1.0, 0.0)
    if interleave > 1:
        vstages = np.where(
            pp > 1.0,
            np.maximum(1.0, np.minimum(float(interleave),
                                       np.floor(float(cfg.n_layers) / pp))),
            1.0)
        pp_bytes_mb = pp_bytes_mb * vstages
        pp_steps_mb = pp_steps_mb * vstages
    else:
        vstages = np.ones_like(pp)
    pp_time = pp_alpha * pp_steps_mb + pp_bytes_mb / pp_bw

    # ep dispatch + combine: one capacity-factor-sized all-to-all pair per
    # MoE layer on the ep axis's own link, wire bytes derated by routing
    # imbalance.  Scalar zeros on an ep-less grid keep every downstream
    # sum bit-identical (x + 0.0 is bitwise identity for finite x ≥ 0).
    if bool(np.any(ep_mask)):
        payload_mb = act_mb * float(cfg.moe_top_k) * float(
            cfg.capacity_factor)
        ecost = collectives.ep_dispatch_combine(payload_mb, ep)
        ep_wire_mb = stage_layers * ecost.wire_bytes * derate
        ep_steps_mb = stage_layers * ecost.steps
        ep_time = ep_alpha * ep_steps_mb + ep_wire_mb / ep_bw
    else:
        ep_wire_mb = ep_steps_mb = ep_time = 0.0
    _sp_price.set(n_candidates=int(dp.size))
    _sp_price.__exit__(None, None, None)

    # --- 1F1B pipeline fill + one Ridgeline sweep over the candidate set -----
    # The serialized critical path holds m + pp − 1 microbatch slots
    # (t_step = (m + pp − 1) · t_microbatch = (m + pp − 1)/m · t_work), so
    # each per-microbatch resource time scales by `fill`; expressed as a
    # per-candidate derating of the machine peaks (peak/fill, α·fill) so
    # one vectorized sweep prices and classifies everything.  At
    # pp = m = 1 the fill is exactly 1.0 and every number is bit-for-bit
    # the PR 4 non-pipelined model.
    # interleaving shrinks the ramp to (pp − 1)/vstages microbatch slots;
    # the default interleave = 1 branch keeps the classic expression (and
    # its bit-exact association) untouched
    if interleave > 1:
        fill = m + (pp - 1.0) / vstages
    else:
        fill = m + pp - 1.0
    # dp grad sync runs once per step (after the last backward), unfilled;
    # per-axis α–β times fold into primary-link-equivalent bytes
    t_net_step = fill * (tp_time + pp_time + ep_time) + dp_time
    eff_net_bytes = t_net_step * hw.net_bw
    with trace.span("plan_grid.sweep_classify", n_candidates=int(dp.size)):
        res = sweep_mod.sweep(
            flops_mb, mem_mb, eff_net_bytes, hw,
            peak_flops=hw.peak_flops / fill, hbm_bw=hw.hbm_bw / fill,
            alpha_compute=hw.alpha_compute * fill,
            alpha_memory=hw.alpha_memory * fill, net_steps=0.0)

    # --- attribution payload (explain=True only; never touches the numbers) --
    explain_terms = prune_reasons = None
    if explain:
        comp_alpha_s = np.where(flops_mb > 0, hw.alpha_compute * fill, 0.0)
        mem_alpha_s = np.where(mem_mb > 0, hw.alpha_memory * fill, 0.0)
        explain_terms = ExplainTerms(
            comp_alpha_s=comp_alpha_s,
            comp_flops_s=res.t_compute - comp_alpha_s,
            mem_alpha_s=mem_alpha_s,
            mem_bytes_s=res.t_memory - mem_alpha_s,
            net_dp_alpha_s=dp_alpha * dp_steps,
            net_dp_bytes_s=dp_wire / dp_bw,
            net_tp_alpha_s=fill * tp_alpha * tp_steps_mb,
            net_tp_bytes_s=fill * tp_wire_mb / tp_bw,
            net_pp_alpha_s=fill * pp_alpha * pp_steps_mb,
            net_pp_bytes_s=fill * pp_bytes_mb / pp_bw,
            net_ep_alpha_s=(np.zeros_like(dp_time)
                            if np.isscalar(ep_steps_mb)
                            else fill * ep_alpha * ep_steps_mb),
            net_ep_bytes_s=(np.zeros_like(dp_time)
                            if np.isscalar(ep_wire_mb)
                            else fill * ep_wire_mb / ep_bw))
        prune_reasons = {
            (ci, bi): dict(_point_prune_stats(
                width, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers,
                _padded_experts(cfg), int(c), int(b), max_pp, max_ep))
            for ci, c in enumerate(chips_list)
            for bi, b in enumerate(batch_list)}

    attained = np.where(res.runtime > 0,
                        sweep_mod._safe_div(flops_step, res.runtime), 0.0)

    # --- failure-aware goodput overlay (goodput=True only) ------------------
    # Folds the amortized failure bill into the effective step time the
    # ranking sees.  Every overhead term is exactly +0.0 under an infinite
    # MTBF, so the default FailureModel keeps runtime (and therefore the
    # committed plan goldens) bit-identical.
    runtime = res.runtime
    fmodel = goodput_arr = ckpt_ov_s = rework_arr_s = restart_arr_s = None
    interval_arr_s = None
    if goodput:
        fmodel = failure if failure is not None else FailureModel()
        with trace.span("plan_grid.goodput", n_candidates=int(dp.size)):
            (ckpt_ov_s, rework_arr_s, restart_arr_s, interval_arr_s,
             goodput_arr) = failures_mod.goodput_terms(
                res.runtime, persisted, dp * tp * pp * ep,
                ckpt_bw=hw.ckpt_bw, model=fmodel)
        runtime = res.runtime + ckpt_ov_s + rework_arr_s + restart_arr_s

    err = max(float(hw.model_rel_error), 0.0)
    return PlanGrid(
        cfg_name=cfg.name, hardware=hw.name,
        chips_list=tuple(int(c) for c in chips_list),
        batch_list=tuple(int(b) for b in batch_list),
        seq=seq, pod_size=pod_size, max_pp=max_pp, max_ep=max_ep,
        interleave=interleave,
        algorithms=tuple(algorithms),
        zero_stages=tuple(int(z) for z in zero_stages), remat=remat,
        hbm_capacity_bytes=capacity, check_capacity=check_capacity,
        chips_idx=cand["chips_idx"], batch_idx=cand["batch_idx"],
        dp=cand["dp"], tp=cand["tp"], pp=cand["pp"], ep=cand["ep"],
        microbatches=cand["microbatches"], zero=cand["zero"],
        req_idx=cand["req_idx"],
        dp_algo_idx=dp_sel, tp_algo_idx=tp_sel,
        dp_pod=dp_pod, tp_pod=tp_pod, pp_pod=pp_pod, ep_pod=ep_pod,
        vstages=vstages.astype(np.int64),
        flops=flops_step, mem_bytes=m * mem_mb,
        net_bytes=dp_wire + m * tp_wire_mb + m * pp_bytes_mb
        + m * ep_wire_mb,
        net_steps=dp_steps + m * tp_steps_mb + m * pp_steps_mb
        + m * ep_steps_mb,
        t_compute=res.t_compute, t_memory=res.t_memory,
        t_network=res.t_network, runtime=runtime,
        bottleneck=res.bottleneck,
        peak_fraction=sweep_mod._safe_div(attained, hw.peak_flops),
        runtime_lo=np.maximum(runtime * (1.0 - err), 0.0),
        runtime_hi=runtime * (1.0 + err),
        hbm_bytes=hbm, fits=fits, n_enumerated=n_enumerated,
        n_pruned=n_pruned, min_zero_to_fit=min_zero_to_fit,
        explain_terms=explain_terms, prune_reasons=prune_reasons,
        failure=fmodel, goodput=goodput_arr, ckpt_overhead_s=ckpt_ov_s,
        rework_s=rework_arr_s, restart_s=restart_arr_s,
        ckpt_interval_s=interval_arr_s)
