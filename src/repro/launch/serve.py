"""Serving launcher: batched greedy decoding against the KV-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --batch 4 --prompt-len 8 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.distributed.sharding import gqa_safe_rules, use_sharding
from repro.launch.mesh import make_mesh
from repro.optim.optimizer import AdamW
from repro.serve.engine import greedy_generate
from repro.train.loop import init_train_state


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(compute_dtype=jnp.float32)
    dims = tuple(int(d) for d in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))

    with use_sharding(mesh, gqa_safe_rules(cfg.n_kv_heads, mesh)):
        params = init_train_state(
            jax.random.PRNGKey(args.seed), cfg, AdamW()).params
        prompt = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab_size)
        t0 = time.perf_counter()
        out = greedy_generate(params, cfg, prompt, steps=args.new_tokens,
                              max_len=args.prompt_len + args.new_tokens)
        dt = time.perf_counter() - t0
        tok_s = args.batch * args.new_tokens / dt
        print(f"{args.arch}: batch={args.batch} +{args.new_tokens} tokens "
              f"in {dt:.2f}s ({tok_s:.0f} tok/s)")
        print("first sequence:", out[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
