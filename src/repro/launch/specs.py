"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns the exact batch pytree a train/serve step
consumes, as ShapeDtypeStructs (weak-type-correct, shardable, zero device
allocation).  ``state_specs`` / ``cache_specs`` do the same for the train
state and the decode cache via ``jax.eval_shape`` over the real constructors,
so dry-run shapes can never drift from what the runtime would build.

Also home to the MODEL_FLOPS accounting (6·N·D dense / 6·N_active·D MoE)
used by the §Roofline useful-flops ratio.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import logical_spec
from repro.models import transformer as lm_mod
from repro.models import encdec as encdec_mod
from repro.models import vlm as vlm_mod
from repro.models.common import ModelConfig
from repro.serve import engine as serve_engine
from repro.train.loop import TrainState, init_train_state, model_param_specs


def _sds(shape, dtype, mesh: Optional[Mesh], axes) -> jax.ShapeDtypeStruct:
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    from repro.distributed.sharding import _drop_nondividing
    spec = _drop_nondividing(logical_spec(axes), shape, mesh)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """Training/prefill batch stand-ins keyed by family."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda: _sds((B, S), jnp.int32, mesh, ("batch", "seq"))
    if cfg.family == "mlp":
        return {"features": _sds((B, cfg.mlp_widths[0]), jnp.float32, mesh,
                                 ("batch", None)),
                "click": _sds((B,), jnp.float32, mesh, ("batch",))}
    out = {"tokens": tok(), "labels": tok()}
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32,
                             mesh, ("batch", "seq", "embed"))
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.visual_tokens, cfg.visual_width),
                              jnp.float32, mesh, ("batch", "seq", None))
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                       mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    B = shape.global_batch
    return {"tokens": _sds((B, 1), jnp.int32, mesh, ("batch", None)),
            "pos": _sds((), jnp.int32, mesh, ())}


# --- eval_shape-derived pytrees ---------------------------------------------------


def abstract_params(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    from repro.models import mlp_dlrm as mlp_mod
    init = {"encdec": encdec_mod.init_encdec, "vlm": vlm_mod.init_vlm,
            "mlp": mlp_mod.init_mlp}.get(cfg.family, lm_mod.init_lm)
    return jax.eval_shape(lambda k: init(k, cfg), key)


def abstract_train_state(cfg: ModelConfig, optimizer) -> TrainState:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, optimizer), key)


def abstract_cache(cfg: ModelConfig, params_abs, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        frames = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                      jnp.float32)
        return jax.eval_shape(
            lambda p, f: serve_engine.init_cache(p, cfg, B, S, frames=f),
            params_abs, frames)
    return jax.eval_shape(
        lambda: serve_engine.init_cache(None, cfg, B, S))


# --- sharding attachment ----------------------------------------------------------

def attach(tree_abs, specs, mesh: Mesh):
    """Zip a ShapeDtypeStruct pytree with a logical-spec pytree.

    Mesh axes that don't divide a dimension are dropped per-dim (odd vocab
    sizes, 60-expert MoE, 9-head attention are the norm in the assigned
    configs; dropping to replication is the standard fallback).
    """
    from repro.distributed.sharding import _drop_nondividing

    def one(abs_leaf, axes):
        spec = _drop_nondividing(logical_spec(axes), abs_leaf.shape, mesh)
        return jax.ShapeDtypeStruct(
            abs_leaf.shape, abs_leaf.dtype,
            sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, tree_abs, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def _augment_data_axis(pspecs):
    """ZeRO-style: additionally shard the first free dim over "dp_shard".

    "dp_shard" is a logical alias the launcher maps to the data axis; dims
    that don't divide fall back to replication inside ``attach``.  Tensors
    with no free dim (MoE expert weights: experts × embed × expert_ffn)
    donate their "embed" dim — embed is replicated by the activation rules,
    so DP-sharding it on the *storage* side is always safe.
    """

    def one(axes):
        axes = tuple(axes)
        for i, a in enumerate(axes):
            if a is None:
                return axes[:i] + ("dp_shard",) + axes[i + 1:]
        for i, a in enumerate(axes):
            if a == "embed":
                return axes[:i] + ("dp_shard",) + axes[i + 1:]
        return axes

    return jax.tree.map(one, pspecs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def train_state_specs(cfg: ModelConfig, zero1: bool = True,
                      fsdp: bool = False) -> TrainState:
    """Logical-axis pytree matching TrainState (params + AdamW mu/nu).

    ``zero1`` (baseline default): optimizer moments additionally sharded
    over the DP axis — free memory, GSPMD turns the gradient all-reduce
    into reduce-scatter (+ all-gather of the final update).
    ``fsdp``: the parameters themselves also DP-sharded (ZeRO-3-style),
    needed for the biggest assigned archs on 16 GiB chips.
    """
    from repro.optim.optimizer import AdamWState
    pspecs = model_param_specs(cfg)
    popt = _augment_data_axis(pspecs) if (zero1 or fsdp) else pspecs
    pmain = _augment_data_axis(pspecs) if fsdp else pspecs
    return TrainState(
        params=pmain,
        opt_state=AdamWState(step=(), mu=popt, nu=popt),
        step=(), rng=(None,))


def cache_logical_specs(cfg: ModelConfig, cache_abs) -> Any:
    """Logical axes for the decode cache: rank-driven defaults.

    KV buffers (L,B,S,K,dh) or (B,S,K,dh) shard batch over DP and expose
    both "kv_seq" and "head_dim" axes; the serve rules map kv_seq -> model
    (SP-decode).  The cache write is an elementwise select at the decode
    position — a dynamic-update-slice on the sharded axis would make GSPMD
    all-gather the whole cache into temps (measured +7.5 GiB/dev on
    qwen2-7b decode_32k).

    Recurrent states (B,H,dk,dv)/(B,H,dk)/(B,D) -> batch (+ heads).
    """

    def axes_for(leaf):
        r = len(leaf.shape)
        if r == 5:
            return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        if r == 4:
            # could be (B,S,K,dh) kv or (B,H,dk,dv) state: kv if dim1 large
            if leaf.shape[1] > 64:
                return ("batch", "kv_seq", "kv_heads", "head_dim")
            return ("batch", "heads", None, None)
        if r == 3:
            return ("batch", "heads", None)
        if r == 2:
            return ("batch", None)
        return tuple([None] * r)

    return jax.tree.map(axes_for, cache_abs)


# --- MODEL_FLOPS accounting ---------------------------------------------------------

@functools.lru_cache(maxsize=None)
def param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active-per-token) parameter counts from abstract shapes.

    Active excludes the embedding gather but includes the LM head matmul;
    MoE expert tensors count at top_k/E (+ shared experts fully).
    Memoized on the (frozen, hashable) config: the ``jax.eval_shape``
    trace behind ``abstract_params`` runs once per model per process, not
    once per ``plan()``/``model_flops`` call.
    """
    params = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = 0.0
    active = 0.0
    for path, leaf in flat:
        n = 1.0
        for d in leaf.shape:
            n *= d
        keys = "/".join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                        for p in path)
        total += n
        if "embed" in keys and "lm_head" not in keys and "pos" not in keys:
            if cfg.tie_embeddings and not cfg.family == "mlp":
                active += n       # tied head matmul
            continue              # gather costs ~0 flops
        if "pos_embed" in keys or "dec_pos" in keys:
            continue
        if any(k in keys for k in ("w_gate", "w_up", "w_down")) and \
                "moe" in keys and "shared" not in keys:
            # the tensors hold E_pad = max(n_experts, pad_experts_to)
            # experts (init_moe pads for EP divisibility), so the active
            # fraction is top_k over the padded count actually allocated —
            # dividing by the true n_experts would inflate active FLOPs by
            # E_pad/E (padding experts never receive routing mass)
            active += n * cfg.moe_top_k / max(cfg.n_experts,
                                              cfg.pad_experts_to, 1)
            continue
        active += n
    return total, active


@functools.lru_cache(maxsize=None)
def expert_param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameters of the *routed* expert tensors only.

    The slice of :func:`param_counts` that an expert-parallel axis shards:
    routed ``w_gate``/``w_up``/``w_down`` stacks at their padded
    ``E_pad = max(n_experts, pad_experts_to)`` allocation, excluding the
    router and shared experts (those replicate over ep).  Non-MoE configs
    return ``(0.0, 0.0)``.  Same memoization contract as
    :func:`param_counts`.
    """
    if cfg.n_experts <= 0:
        return 0.0, 0.0
    params = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = 0.0
    for path, leaf in flat:
        n = 1.0
        for d in leaf.shape:
            n *= d
        keys = "/".join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                        for p in path)
        if any(k in keys for k in ("w_gate", "w_up", "w_down")) and \
                "moe" in keys and "shared" not in keys:
            total += n
    active = total * cfg.moe_top_k / max(cfg.n_experts,
                                         cfg.pad_experts_to, 1)
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for serve decode."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per seq
