"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 64 --reduced --ckpt-dir /tmp/run1

On the CPU container, use ``--reduced`` (CPU-sized config of the same
family); on a real pod, omit it and pass ``--mesh data,model`` sizes.  The
launcher wires together the full substrate: mesh + logical sharding rules,
deterministic per-host data pipeline, AdamW with warmup-cosine, the
fault-tolerant runner (auto-resume from the latest committed checkpoint,
periodic async saves, straggler flags), and a closing Ridgeline report of
the compiled step.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, get_reduced
from repro.core import TPU_V5E, WorkUnit, analyze
from repro.core.hlo_analysis import analyze_compiled
from repro.data.pipeline import DataConfig, make_stream
from repro.distributed.sharding import gqa_safe_rules, use_sharding
from repro.launch.mesh import make_mesh
from repro.optim.optimizer import AdamW, warmup_cosine
from repro.train.fault_tolerance import ResilientRunner, RunnerConfig
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model split, e.g. 16x16 on a pod")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(compute_dtype=jnp.float32)
    dims = tuple(int(d) for d in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model"))

    opt = AdamW(learning_rate=warmup_cosine(args.lr, 20, args.steps))
    step_cfg = TrainStepConfig(n_micro=args.n_micro)

    with use_sharding(mesh, gqa_safe_rules(cfg.n_kv_heads, mesh)):
        train_step = jax.jit(build_train_step(cfg, opt, step_cfg),
                             donate_argnums=(0,))
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt)
        stream = make_stream(cfg, DataConfig(
            seed=args.seed, global_batch=args.batch, seq_len=args.seq))
        runner = ResilientRunner(
            train_step, Checkpointer(args.ckpt_dir, keep=3),
            RunnerConfig(ckpt_every=args.ckpt_every),
            on_straggler=lambda ev: print(
                f"[straggler] step {ev.step}: {ev.step_time:.2f}s "
                f"vs EWMA {ev.ewma:.2f}s", file=sys.stderr))
        state, history = runner.run(state, stream, n_steps=args.steps)

        if history:
            first = np.mean([h["ce"] for h in history[:10]])
            last = np.mean([h["ce"] for h in history[-10:]])
            print(f"steps {history[0]['step']}..{history[-1]['step']}  "
                  f"CE {first:.4f} -> {last:.4f}")

        # closing Ridgeline report of the compiled step
        batch_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.asarray(x).dtype),
            stream.batch(0))
        state_abs = jax.eval_shape(lambda s: s, state)
        compiled = jax.jit(build_train_step(cfg, opt, step_cfg)).lower(
            state_abs, batch_abs).compile()
        costs = analyze_compiled(compiled, mesh.size)
        print(analyze(WorkUnit(f"{args.arch}/train", costs.flops,
                               costs.mem_bytes, costs.wire_bytes),
                      TPU_V5E).summary())
    return 0


if __name__ == "__main__":
    sys.exit(main())
