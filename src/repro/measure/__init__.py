"""Empirical measurement & calibration: the model <-> hardware loop.

``timers`` (robust wall clocks) -> ``microbench`` ((WorkUnit, seconds)
pairs) -> ``calibrate`` (achievable PEAK/HBM/NET ceilings + JSON registry)
-> ``overlay`` (measured dots and model error on reports and figures).

Re-exports are lazy (PEP 562) so importing the package never imports the
submodules; the benches import jax lazily on top of that, letting the
calibrate CLI pin the backend/device count before jax initializes.
"""
_EXPORTS = {
    "Calibration": "repro.measure.calibrate",
    "fit_ceilings": "repro.measure.calibrate",
    "Measurement": "repro.measure.microbench",
    "default_suite": "repro.measure.microbench",
    "TimingStats": "repro.measure.timers",
    "robust_stats": "repro.measure.timers",
    "time_callable": "repro.measure.timers",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(module), name)
