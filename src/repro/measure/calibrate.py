"""Fit achievable α–β ceilings from measured (WorkUnit, seconds).

The Ridgeline's projection is ``t = max(t_C, t_M, t_N)``; the datasheet
presets in ``core/hardware`` put vendor peaks on the right-hand side, which
makes every projection a *lower* bound — often a loose one.  Following the
time-based-roofline line of work (Wang et al.) and the α–β collective
models (Chan et al.), this module replaces the vendor peaks with what the
machine actually achieves, *including latency*:

  1. group fit measurements by the resource their bench *saturates by
     construction* (``Measurement.category``: compute / memory / network —
     the v1 Lloyd-style re-assignment is gone, because a 2-parameter model
     lets a large fitted α on one resource swallow the small-payload
     benches of every other resource, which is exactly the regime the α
     fit needs),
  2. per resource, solve the 2-parameter least-squares ``t ≈ α·u + q/peak``
     over the group — ``u = 1`` per execution for compute/memory (dispatch
     overhead), ``u = steps`` (serialized hops) for the network — with α
     clamped to ≥ 0; degenerate systems (one point, collinear regressors)
     fall back to the v1 bandwidth-only closed form,
  3. network points are further grouped by the mesh-axis ``link`` tag they
     rode (``Measurement.link``), and each link's (α, bandwidth) pair is
     fitted *independently* — the primary link updates
     ``net_bw``/``alpha_network`` and every other tag updates that named
     ``extra_links`` entry, so a slower ``pod``/DCI axis is measured, not
     scaled by one NET ratio,
  4. the compute group additionally tries the **size-dependent efficiency
     ceiling** ``t ≈ F/(peak·eff(F))`` (``EfficiencyModel``, fitted from
     the sized-GEMM benches via :func:`_fit_efficiency`); whichever of the
     constant-intercept α–β model and the saturating curve prices the
     compute points with less squared error wins, so machines whose small
     GEMMs never approach PEAK get a curve and everything else keeps the
     intercept.

A resource (or link) with no measurements keeps its prior value and is
reported as ``datasheet`` rather than ``measured`` — e.g. NET on a
single-device host where there is no wire to time.  The bottleneck
*argmax* under the fitted parameters is still reported per measurement
(the ``assigned`` registry field), as the model's own view of each point.

The result persists as one JSON file per spec under
``artifacts/calibration/`` (schema ``repro.calibration/v3``; v1/v2 entries
still load — v1 with α = 0, both with the identity efficiency curve); the
loader side lives in ``core/hardware`` so any consumer can
``get_hardware(name, calibrated=True)`` without importing jax.

CLI::

    python -m repro.measure.calibrate --backend cpu --smoke
    python -m repro.measure.calibrate --backend cpu --devices 4 --hardware clx
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import (CALIBRATED_SUFFIX, CALIBRATION_SCHEMA,
                                 EfficiencyModel, HardwareSpec,
                                 calibration_dir, get_hardware)
from repro.measure.microbench import Measurement
from repro.obs import trace
from repro.obs.metrics import provenance

_RESOURCES = ("peak_flops", "hbm_bw", "net_bw")
_ALPHAS = ("alpha_compute", "alpha_memory", "alpha_network")

#: which wall-time statistic a calibration trusts per bench:
#: 'best' (fastest sample — robust to contention on shared boxes, the
#: classic bandwidth-benchmark convention) or 'median' (typical operating
#: point, right for dedicated nodes)
ESTIMATORS = ("best", "median")


def _quantities(m: Measurement) -> Tuple[float, float, float]:
    return (m.work.flops, m.work.mem_bytes, m.work.net_bytes)


def _observed(m: Measurement, estimator: str) -> float:
    return m.best if estimator == "best" else m.seconds


def _is_primary(link: Optional[str]) -> bool:
    return link in HardwareSpec.PRIMARY_LINKS


@dataclasses.dataclass
class _Params:
    """Mutable fit state: the α–β(+efficiency) parameters of one machine."""

    peaks: List[float]               # [peak_flops, hbm_bw, net_bw]
    alphas: List[float]              # [alpha_compute, alpha_memory, alpha_network]
    link_bws: Dict[str, float]       # extra (non-primary) link bandwidths
    link_alphas: Dict[str, float]    # per-hop α of those links
    compute_eff: EfficiencyModel = EfficiencyModel()

    @staticmethod
    def from_spec(hw: HardwareSpec) -> "_Params":
        return _Params(
            peaks=[hw.peak_flops, hw.hbm_bw, hw.net_bw],
            alphas=[hw.alpha_compute, hw.alpha_memory, hw.alpha_network],
            link_bws=dict(hw.extra_links),
            link_alphas={k: hw.link_alphas.get(k, hw.alpha_network)
                         for k in hw.extra_links},
            compute_eff=hw.compute_eff)

    def spec(self) -> HardwareSpec:
        """The current fit state as a HardwareSpec (for shared pricing).

        Cached after first use: pricing only happens once the parameters
        are final (the fit loop mutates fields but never prices mid-fit).
        """
        if getattr(self, "_spec_cache", None) is None:
            self._spec_cache = HardwareSpec(
                name="_fit", peak_flops=self.peaks[0], hbm_bw=self.peaks[1],
                net_bw=self.peaks[2], extra_links=dict(self.link_bws),
                alpha_compute=self.alphas[0], alpha_memory=self.alphas[1],
                alpha_network=self.alphas[2],
                link_alphas=dict(self.link_alphas),
                compute_eff=self.compute_eff)
        return self._spec_cache

    def times(self, m: Measurement) -> Tuple[float, float, float]:
        from repro.core.ridgeline import resource_times
        link = m.link
        if not _is_primary(link) and link not in self.link_bws:
            link = None    # link never seen (not even in the datasheet):
            #                price at the primary until a fit learns it
        return resource_times(m.work, self.spec(), link=link)


def _model_seconds(m: Measurement, params: _Params) -> float:
    return max(params.times(m))


def _assign(m: Measurement, params: _Params) -> int:
    times = params.times(m)
    return max(range(3), key=lambda r: (times[r], -r))


def _fit_alpha_beta(points: Sequence[Tuple[float, float, float]],
                    prior_peak: float) -> Tuple[float, float]:
    """Least-squares (α, peak) for ``t ≈ α·u + q/peak`` over (u, q, t).

    Physical constraints: α ≥ 0, peak > 0, and — since every observation
    satisfies ``t_i = α·u_i + q_i/peak ≥ α·u_i`` — the per-unit α cannot
    exceed ``min(t_i/u_i)``; a noisy intercept above that bound is clamped
    there and the peak refitted (noisy small boxes routinely produce such
    intercepts).  Degenerate systems (collinear regressors, a single point)
    drop the α column and reduce to the v1 bandwidth-only closed form
    ``1/peak = Σq·t / Σq²``.  ``prior_peak`` (the incoming ceiling) is kept
    whenever the data cannot determine the peak at all.
    """
    # absolute-error LS, deliberately: relative weighting would give the
    # latency-dominated small points decades more weight, and on noisy
    # shared boxes their jitter then whipsaws the fitted peak; absolute
    # weighting anchors the ceiling on the saturating sizes and lets the
    # intercept soak up what the small points agree on
    su2 = sq2 = suq = sut = sqt = 0.0
    for u, q, t in points:
        su2 += u * u
        sq2 += q * q
        suq += u * q
        sut += u * t
        sqt += q * t
    alpha_max = min((t / u for u, q, t in points if u > 0), default=0.0)
    times = [t for _, _, t in points if t > 0]
    # identifiability guard: separating an intercept from a slope needs
    # observed times spanning real dynamic range, otherwise measurement
    # noise lands almost entirely in α (two same-scale points fit *any*
    # intercept exactly); below the threshold fall back to β-only
    identifiable = bool(times) and max(times) >= 3.0 * min(times)

    def beta_only() -> Tuple[float, float]:
        if sq2 > 0 and sqt > 0:
            return 0.0, sq2 / sqt
        return 0.0, prior_peak

    def with_alpha(alpha: float) -> Tuple[float, float]:
        """Refit the peak with α held fixed (boundary of the constraint)."""
        resid = sqt - alpha * suq
        if sq2 > 0 and resid > 0:
            return alpha, sq2 / resid
        return alpha, prior_peak

    det = su2 * sq2 - suq * suq
    if not identifiable or det <= 1e-12 * max(su2 * sq2, 1e-300):
        return beta_only()
    alpha = (sut * sq2 - sqt * suq) / det
    c = (su2 * sqt - suq * sut) / det           # c = 1/peak
    if alpha < 0:
        return beta_only()
    if alpha > alpha_max:
        return with_alpha(alpha_max)
    if c <= 0:
        # all observed time is latency: α alone, peak stays at the prior
        resid = sut - suq / prior_peak if prior_peak > 0 else sut
        return min(max(resid / su2, 0.0), alpha_max), prior_peak
    return alpha, 1.0 / c


#: points at/above this achieved fraction count as saturated — they anchor
#: the peak but carry no shape information for the efficiency curve
_EFF_SATURATED = 0.97

#: fitted Hill exponents are confined to (0, 1]: below 0.1 is a noise
#: artifact, and p > 1 with a zero floor would price time *non-monotone*
#: in F (tinier work diverges) — p = 1 already equals the α–β intercept
#: model, so data steeper than that falls back to the intercept fit
_EFF_P_RANGE = (0.1, 1.0)


def _fit_efficiency(points: Sequence[Tuple[float, float, float]]
                    ) -> Optional[Tuple[float, EfficiencyModel]]:
    """Fit ``t ≈ q / (peak · eff(q))`` with the Hill efficiency curve.

    ``points`` are the same (u, q, t) triples the α–β fit sees; the
    efficiency model replaces the constant intercept with a size-dependent
    achievable ceiling (eff_min pinned at 0 — two shape parameters are all
    four-ish GEMM sizes can support):

      1. the achievable peak is the best observed rate ``max(q/t)``
         (time-based-roofline convention), refined below;
      2. per-point efficiencies ``e_i = (q_i/t_i)/peak`` are log-odds
         linearized — ``ln(1/e − 1) = p·ln f_half − p·ln q`` is a straight
         line in ln q — and (p, f_half) solved by least squares over the
         sub-saturated points;
      3. the peak is re-fitted by least squares with the shape held fixed
         (``t ≈ g(q)/peak, g = q/eff(q)``), which un-biases it from step 1's
         max-of-noisy-rates estimate.

    Returns None when the data cannot support the curve: fewer than three
    usable points, fewer than two meaningfully sub-saturated ones, or a
    fitted exponent outside the physical range (``p ≤ 0`` would be
    non-monotone).  The caller compares the result's squared error against
    the α–β fit and keeps the better model.
    """
    pos = [(q, t) for _, q, t in points if q > 0 and t > 0]
    if len(pos) < 3:
        return None
    peak = max(q / t for q, t in pos)
    for _ in range(2):                       # shape fit <-> peak refit
        reg = [(math.log(q), math.log(1.0 / e - 1.0))
               for q, t in pos
               for e in [(q / t) / peak]
               if e < _EFF_SATURATED]
        if len(reg) < 2:
            return None
        n = float(len(reg))
        sx = sum(x for x, _ in reg)
        sy = sum(y for _, y in reg)
        sxx = sum(x * x for x, _ in reg)
        sxy = sum(x * y for x, y in reg)
        det = n * sxx - sx * sx
        if det <= 0:
            return None
        p = -(n * sxy - sx * sy) / det       # slope is −p
        if not _EFF_P_RANGE[0] <= p <= _EFF_P_RANGE[1]:
            return None
        # intercept = p·ln f_half  ->  f_half
        f_half = math.exp((sy + p * sx) / (n * p))
        model = EfficiencyModel(f_half=f_half, p=p)
        # peak refit: t ≈ g(q)/peak with g = q/eff(q)
        sg2 = sum((q / model.eff(q)) ** 2 for q, _ in pos)
        sgt = sum((q / model.eff(q)) * t for q, t in pos)
        if sg2 <= 0 or sgt <= 0:
            return None
        peak = sg2 / sgt
    return peak, model


def _sse(points: Sequence[Tuple[float, float, float]],
         predict) -> float:
    return sum((predict(u, q) - t) ** 2 for u, q, t in points)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted achievable α–β parameters + the evidence behind them."""

    name: str
    base: HardwareSpec
    peak_flops: float
    hbm_bw: float
    net_bw: float
    sources: Dict[str, str]          # resource/link -> 'measured' | 'datasheet'
    iterations: int
    fit_measurements: Tuple[Measurement, ...]
    validation_measurements: Tuple[Measurement, ...] = ()
    estimator: str = "best"          # see ESTIMATORS
    alpha_compute: float = 0.0       # s per execution
    alpha_memory: float = 0.0        # s per execution
    alpha_network: float = 0.0       # s per serialized hop (primary link)
    link_bws: Dict[str, float] = dataclasses.field(default_factory=dict)
    link_alphas: Dict[str, float] = dataclasses.field(default_factory=dict)
    compute_eff: EfficiencyModel = EfficiencyModel()   # eff(F) ceiling curve

    @property
    def peaks(self) -> Tuple[float, float, float]:
        return (self.peak_flops, self.hbm_bw, self.net_bw)

    @property
    def alphas(self) -> Tuple[float, float, float]:
        return (self.alpha_compute, self.alpha_memory, self.alpha_network)

    @functools.cached_property
    def _pricing_params(self) -> _Params:
        return self._params()

    def _params(self) -> _Params:
        # unmeasured links keep their datasheet bandwidths here too, so
        # model_seconds/rel_error agree with what spec() would predict
        link_bws = dict(self.base.extra_links)
        link_bws.update(self.link_bws)
        return _Params(peaks=list(self.peaks), alphas=list(self.alphas),
                       link_bws=link_bws,
                       link_alphas=dict(self.link_alphas),
                       compute_eff=self.compute_eff)

    def spec(self) -> HardwareSpec:
        """The calibrated HardwareSpec.

        Extra links carry their *own* fitted (α, bandwidth) where measured;
        unmeasured links keep the datasheet number rather than being scaled
        by the primary-NET ratio (the v1 behaviour this fit replaces).
        """
        extra = dict(self.base.extra_links)
        extra.update(self.link_bws)
        summary = self.error_summary("validation")
        return HardwareSpec(
            name=self.name,
            peak_flops=self.peak_flops,
            hbm_bw=self.hbm_bw,
            net_bw=self.net_bw,
            extra_links=extra,
            alpha_compute=self.alpha_compute,
            alpha_memory=self.alpha_memory,
            alpha_network=self.alpha_network,
            link_alphas=dict(self.link_alphas),
            model_rel_error=summary["median_abs_rel_error"],
            compute_eff=self.compute_eff,
            vmem_bytes=self.base.vmem_bytes,
            hbm_capacity_bytes=self.base.hbm_capacity_bytes,
            ckpt_bw=self.base.ckpt_bw,
        )

    # ---- model-vs-measured error --------------------------------------------
    def model_seconds(self, m: Measurement) -> float:
        return _model_seconds(m, self._pricing_params)

    def observed_seconds(self, m: Measurement) -> float:
        return _observed(m, self.estimator)

    def rel_error(self, m: Measurement) -> float:
        """(model − measured) / measured: negative = model under-predicts."""
        obs = self.observed_seconds(m)
        return (self.model_seconds(m) - obs) / obs

    def errors(self, which: str = "all") -> Dict[str, float]:
        ms = {"fit": self.fit_measurements,
              "validation": self.validation_measurements,
              "all": self.fit_measurements + self.validation_measurements,
              }[which]
        return {m.work.name: self.rel_error(m) for m in ms}

    def error_summary(self, which: str = "all") -> Dict[str, float]:
        errs = sorted(abs(e) for e in self.errors(which).values())
        if not errs:
            return {"n": 0, "median_abs_rel_error": 0.0,
                    "max_abs_rel_error": 0.0}
        mid = len(errs) // 2
        median = errs[mid] if len(errs) % 2 else \
            0.5 * (errs[mid - 1] + errs[mid])
        return {"n": len(errs), "median_abs_rel_error": median,
                "max_abs_rel_error": errs[-1]}

    # ---- persistence ---------------------------------------------------------
    def to_dict(self) -> Dict:
        params = self._params()

        def dump(ms: Sequence[Measurement]) -> List[Dict]:
            out = []
            for m in ms:
                d = m.to_dict()
                d["assigned"] = _RESOURCES[_assign(m, params)]
                model = _model_seconds(m, params)
                obs = self.observed_seconds(m)
                d["model_seconds"] = model
                d["rel_error"] = (model - obs) / obs
                out.append(d)
            return out

        return {
            "schema": CALIBRATION_SCHEMA,
            "name": self.name,
            "base": self.base.name,
            # who/what/when produced these numbers (git sha, library
            # versions, hostname, wall clock) — repro.obs.metrics
            "provenance": provenance(),
            "estimator": self.estimator,
            "peak_flops": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "net_bw": self.net_bw,
            "alpha_compute": self.alpha_compute,
            "alpha_memory": self.alpha_memory,
            "alpha_network": self.alpha_network,
            "compute_eff": self.compute_eff.to_dict(),
            "extra_links": dict(self.spec().extra_links),
            "link_alphas": dict(self.link_alphas),
            "vmem_bytes": self.base.vmem_bytes,
            "hbm_capacity_bytes": self.base.hbm_capacity_bytes,
            "ckpt_bw": self.base.ckpt_bw,
            "sources": dict(self.sources),
            "datasheet": {"peak_flops": self.base.peak_flops,
                          "hbm_bw": self.base.hbm_bw,
                          "net_bw": self.base.net_bw,
                          "extra_links": dict(self.base.extra_links)},
            "fit": {"iterations": self.iterations,
                    **self.error_summary("fit")},
            "validation": self.error_summary("validation"),
            "measurements": dump(self.fit_measurements),
            "validation_measurements": dump(self.validation_measurements),
        }

    def save(self, registry_dir: Optional[str] = None) -> str:
        from repro.core.hardware import PRESETS
        if self.name in PRESETS:
            raise ValueError(
                f"calibration name {self.name!r} shadows a datasheet preset "
                f"(get_hardware would never resolve it); pick another, e.g. "
                f"{self.name + CALIBRATED_SUFFIX!r}")
        cdir = calibration_dir(registry_dir)
        os.makedirs(cdir, exist_ok=True)
        path = os.path.join(cdir, f"{self.name}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    def summary(self) -> str:
        lines = [f"calibration {self.name} (base {self.base.name}, "
                 f"estimator {self.estimator}, "
                 f"{self.iterations} fit iterations)"]
        datasheet = (self.base.peak_flops, self.base.hbm_bw, self.base.net_bw)
        units = ("s/exec", "s/exec", "s/hop")
        for r, a, fitted, alpha, ds, unit in zip(
                _RESOURCES, _ALPHAS, self.peaks, self.alphas, datasheet,
                units):
            lines.append(
                f"  {r:>10}: {fitted:.4g} ({self.sources[r]}; datasheet "
                f"{ds:.4g}, x{fitted / ds:.3f}) "
                f"{a}={alpha:.3g} {unit}")
        if not self.compute_eff.is_identity:
            e = self.compute_eff
            lines.append(
                f"  compute_eff: eff(F) = "
                f"{e.eff_min:.2g} + {1 - e.eff_min:.2g}/"
                f"(1 + ({e.f_half:.3g}/F)^{e.p:.3g})   "
                f"[eff(1e6)={e.eff(1e6):.2f}, eff(1e9)={e.eff(1e9):.2f}]")
        for tag in sorted(self.base.extra_links):
            bw = self.link_bws.get(tag, self.base.extra_links[tag])
            src = self.sources.get(f"link:{tag}", "datasheet")
            lines.append(
                f"  link {tag:>6}: {bw:.4g} ({src}; datasheet "
                f"{self.base.extra_links[tag]:.4g}) "
                f"alpha={self.link_alphas.get(tag, self.alpha_network):.3g} "
                f"s/hop")
        for which in ("fit", "validation"):
            s = self.error_summary(which)
            if s["n"]:
                lines.append(
                    f"  {which}: n={s['n']} median |rel err| "
                    f"{100 * s['median_abs_rel_error']:.1f}% max "
                    f"{100 * s['max_abs_rel_error']:.1f}%")
        return "\n".join(lines)


def load_calibration_dict(name: str,
                          registry_dir: Optional[str] = None) -> Dict:
    """The raw registry JSON for ``name`` (spec loading lives in hardware)."""
    path = os.path.join(calibration_dir(registry_dir), f"{name}.json")
    with open(path) as f:
        return json.load(f)


def fit_ceilings(measurements: Sequence[Measurement],
                 base: HardwareSpec, *,
                 name: Optional[str] = None,
                 validation: Sequence[Measurement] = (),
                 estimator: str = "best",
                 max_iterations: int = 32) -> Calibration:
    """Per-resource α–β least-squares fit of the machine parameters.

    Fit measurements are grouped by ``category`` (the resource their bench
    saturates by construction) and network points further by link tag; each
    group solves ``t ≈ α·u + q/peak`` (module docstring has the rationale
    for dropping the v1 Lloyd re-assignment).  ``validation`` points (e.g.
    whole model steps) only contribute to the reported error.  Resources
    and links with no measurements keep the datasheet ``base`` numbers
    (α = 0).  ``estimator`` picks the wall-time statistic (see
    :data:`ESTIMATORS`).  ``max_iterations`` is accepted for API
    compatibility and ignored.
    """
    if not measurements:
        raise ValueError("need at least one measurement to fit")
    if estimator not in ESTIMATORS:
        raise ValueError(f"estimator {estimator!r} not in {ESTIMATORS}")
    del max_iterations  # category grouping needs no alternation (see above)
    groups = {"compute": 0, "memory": 1, "network": 2}
    # whole-step points can never constrain a per-resource fit; when the
    # caller hands a full suite (e.g. microbench.default_suite()) route
    # them to validation rather than silently counting them as fit evidence
    steps = [m for m in measurements if m.category not in groups]
    measurements = [m for m in measurements if m.category in groups]
    validation = tuple(validation) + tuple(steps)
    if not measurements:
        raise ValueError("need at least one compute/memory/network "
                         "measurement to fit (step points only validate)")
    params = _Params.from_spec(base)
    measured_links: set = set()
    fitted = [False, False, False]
    # compute / memory: one execution pays one α (u = 1)
    by_resource = {}
    for r in (0, 1):
        pts = [(1.0, _quantities(m)[r], _observed(m, estimator))
               for m in measurements if groups.get(m.category) == r]
        by_resource[r] = pts
        if pts:
            with trace.span(f"calibrate.fit.{('compute', 'memory')[r]}",
                            n_points=len(pts)):
                params.alphas[r], params.peaks[r] = \
                    _fit_alpha_beta(pts, params.peaks[r])
            fitted[r] = True
    # compute only: also try the size-dependent efficiency ceiling and keep
    # whichever model (constant intercept vs saturating curve) prices the
    # sized-GEMM points with less squared error; ties keep α–β, so exact
    # synthetic α–β suites — and any spec that is genuinely latency-plus-
    # constant-ceiling — are reproduced unchanged
    cpts = by_resource[0]
    if cpts:
        with trace.span("calibrate.fit.efficiency", n_points=len(cpts)):
            eff_fit = _fit_efficiency(cpts)
    else:
        eff_fit = None
    if eff_fit is not None:
        peak_eff, eff_model = eff_fit
        sse_ab = _sse(cpts, lambda u, q, a=params.alphas[0],
                      pk=params.peaks[0]: a * u + (q / pk if pk > 0 else 0.0))
        sse_eff = _sse(cpts, lambda u, q, pk=peak_eff, em=eff_model:
                       q / (pk * em.eff(q)) if q > 0 else 0.0)
        if sse_eff < sse_ab:
            params.alphas[0] = 0.0       # the curve subsumes the intercept
            params.peaks[0] = peak_eff
            params.compute_eff = eff_model
    # network: α multiplies serialized hops, fitted per link tag
    by_link: Dict[Optional[str], List[Tuple[float, float, float]]] = {}
    for m in measurements:
        if groups.get(m.category) != 2:
            continue
        tag = None if _is_primary(m.link) else m.link
        by_link.setdefault(tag, []).append(
            (m.work.net_steps, m.work.net_bytes, _observed(m, estimator)))
    for tag, pts in by_link.items():
        with trace.span("calibrate.fit.network",
                        link=tag or "primary", n_points=len(pts)):
            if tag is None:
                params.alphas[2], params.peaks[2] = \
                    _fit_alpha_beta(pts, params.peaks[2])
                fitted[2] = True
            else:
                prior = params.link_bws.get(tag, params.peaks[2])
                alpha, bw = _fit_alpha_beta(pts, prior)
                params.link_alphas[tag] = alpha
                params.link_bws[tag] = bw
                measured_links.add(tag)
    iterations = 1
    sources = {res: ("measured" if fitted[r] else "datasheet")
               for r, res in enumerate(_RESOURCES)}
    for tag in set(base.extra_links) | measured_links:
        sources[f"link:{tag}"] = ("measured" if tag in measured_links
                                  else "datasheet")
    # only persist per-link parameters that were actually fitted — the
    # spec() fallback keeps unmeasured links at their datasheet values
    link_bws = {t: params.link_bws[t] for t in measured_links}
    link_alphas = {t: params.link_alphas[t] for t in measured_links}
    return Calibration(
        name=name or base.name + CALIBRATED_SUFFIX,
        base=base,
        peak_flops=params.peaks[0], hbm_bw=params.peaks[1],
        net_bw=params.peaks[2],
        sources=sources, iterations=iterations,
        fit_measurements=tuple(measurements),
        validation_measurements=tuple(validation),
        estimator=estimator,
        alpha_compute=params.alphas[0],
        alpha_memory=params.alphas[1],
        alpha_network=params.alphas[2],
        link_bws=link_bws, link_alphas=link_alphas,
        compute_eff=params.compute_eff,
    )


# --- CLI ----------------------------------------------------------------------


def _configure_backend(backend: Optional[str], devices: int) -> None:
    """Set backend env *before* jax is imported anywhere in this process."""
    if "jax" in sys.modules:
        return   # too late to steer; run with the backend already chosen
    if backend and backend != "default":
        os.environ.setdefault("JAX_PLATFORMS", backend)
    # host-device forcing applies to any CPU-backed run, including
    # backend='default' on a box where jax resolves to CPU anyway
    if devices > 1 and backend != "tpu":
        flag = f"--xla_force_host_platform_device_count={devices}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.measure.calibrate",
        description="Measure this machine and fit achievable Ridgeline "
                    "ceilings (PEAK/HBM/NET).")
    ap.add_argument("--hardware", default="clx",
                    help="datasheet preset to calibrate against "
                         "(initialization + fallback for unmeasured "
                         "resources)")
    ap.add_argument("--backend", default="default",
                    choices=("default", "cpu", "tpu"),
                    help="jax platform (set before jax import)")
    ap.add_argument("--devices", type=int, default=1,
                    help="CPU host devices to fake for collective benches "
                         "(>1 enables NET calibration accelerator-free)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few repeats; finishes in <60s on CPU")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per bench and pass "
                         "(default 9 smoke / 11 full, x3 merged passes)")
    ap.add_argument("--estimator", default="best", choices=ESTIMATORS,
                    help="wall-time statistic to fit on: 'best' sample "
                         "(robust on shared boxes) or 'median'")
    ap.add_argument("--no-steps", action="store_true",
                    help="skip the whole-model-step validation benches")
    ap.add_argument("--name", default=None,
                    help="registry entry name (default <hardware>_cal)")
    ap.add_argument("--out", default=None,
                    help="registry directory (default artifacts/calibration)")
    ap.add_argument("--figures", default=None,
                    help="also write overlay figures to this directory")
    args = ap.parse_args(argv)

    try:
        base = get_hardware(args.hardware)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    from repro.core.hardware import PRESETS
    if args.name in PRESETS:
        print(f"error: --name {args.name!r} shadows a datasheet preset; "
              f"pick another (default: {args.hardware}_cal)", file=sys.stderr)
        return 2
    _configure_backend(args.backend, args.devices)

    from repro.measure import microbench
    with trace.span("calibrate.suite", smoke=args.smoke,
                    devices=args.devices):
        suite = microbench.default_suite(
            smoke=args.smoke, repeats=args.repeats, steps=not args.no_steps)
    fit = [m for m in suite if m.category != "step"]
    steps = [m for m in suite if m.category == "step"]
    if not any(m.category == "network" for m in fit):
        print("note: single device -> no collective benches; NET ceiling "
              "stays datasheet (re-run with --devices N)", file=sys.stderr)

    with trace.span("calibrate.fit", n_fit=len(fit),
                    n_validation=len(steps)):
        calib = fit_ceilings(fit, base, name=args.name, validation=steps,
                             estimator=args.estimator)
    path = calib.save(args.out)
    print(calib.summary())
    print(f"wrote {path}")

    from repro.measure import overlay
    cell_paths = overlay.write_measured_cells(calib, registry_dir=args.out)
    for p in cell_paths:
        print(f"wrote {p}")
    if args.figures or not args.out:
        figdir = args.figures or os.path.join(
            os.path.dirname(calibration_dir(args.out)), "figures")
        for p in overlay.write_calibration_figs(figdir, calib):
            print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
