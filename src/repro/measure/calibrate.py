"""Fit achievable PEAK/HBM/NET ceilings from measured (WorkUnit, seconds).

The Ridgeline's projection is ``t = max(F/PEAK, B_M/HBM, B_N/NET)``; the
datasheet presets in ``core/hardware`` put vendor peaks on the right-hand
side, which makes every projection a *lower* bound — often a loose one.
Following the time-based-roofline line of work (Wang et al.), this module
replaces the vendor peaks with the ceilings the machine actually achieves:

  1. assign each measurement to its bottleneck resource under the current
     ceilings (the argmax in the time model),
  2. per resource, solve the 1-D least-squares ``t ≈ q · (1/peak)`` over the
     assigned points (closed form: ``1/peak = Σ q·t / Σ q²``),
  3. repeat until the assignment is a fixed point (a Lloyd-style alternation
     that converges in a handful of rounds).

A resource with no assigned points keeps its prior ceiling and is reported
as ``datasheet`` rather than ``measured`` — e.g. NET on a single-device
host where there is no wire to time.

The result persists as one JSON file per spec under
``artifacts/calibration/`` (schema ``repro.calibration/v1``); the loader
side lives in ``core/hardware`` so any consumer can
``get_hardware(name, calibrated=True)`` without importing jax.

CLI::

    python -m repro.measure.calibrate --backend cpu --smoke
    python -m repro.measure.calibrate --backend cpu --devices 4 --hardware clx
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import (CALIBRATED_SUFFIX, CALIBRATION_SCHEMA,
                                 HardwareSpec, calibration_dir, get_hardware)
from repro.measure.microbench import Measurement

_RESOURCES = ("peak_flops", "hbm_bw", "net_bw")

#: which wall-time statistic a calibration trusts per bench:
#: 'best' (fastest sample — robust to contention on shared boxes, the
#: classic bandwidth-benchmark convention) or 'median' (typical operating
#: point, right for dedicated nodes)
ESTIMATORS = ("best", "median")


def _quantities(m: Measurement) -> Tuple[float, float, float]:
    return (m.work.flops, m.work.mem_bytes, m.work.net_bytes)


def _observed(m: Measurement, estimator: str) -> float:
    return m.best if estimator == "best" else m.seconds


def _model_seconds(m: Measurement, peaks: Sequence[float]) -> float:
    return max((q / p if p > 0 else 0.0)
               for q, p in zip(_quantities(m), peaks))


def _assign(m: Measurement, peaks: Sequence[float]) -> int:
    times = [(q / p if p > 0 else 0.0)
             for q, p in zip(_quantities(m), peaks)]
    return max(range(3), key=lambda r: (times[r], -r))


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted achievable ceilings + the evidence behind them."""

    name: str
    base: HardwareSpec
    peak_flops: float
    hbm_bw: float
    net_bw: float
    sources: Dict[str, str]          # resource -> 'measured' | 'datasheet'
    iterations: int
    fit_measurements: Tuple[Measurement, ...]
    validation_measurements: Tuple[Measurement, ...] = ()
    estimator: str = "best"          # see ESTIMATORS

    @property
    def peaks(self) -> Tuple[float, float, float]:
        return (self.peak_flops, self.hbm_bw, self.net_bw)

    def spec(self) -> HardwareSpec:
        """The calibrated HardwareSpec (extra links scale with NET)."""
        scale = self.net_bw / self.base.net_bw if self.base.net_bw else 1.0
        return HardwareSpec(
            name=self.name,
            peak_flops=self.peak_flops,
            hbm_bw=self.hbm_bw,
            net_bw=self.net_bw,
            extra_links={k: v * scale
                         for k, v in self.base.extra_links.items()},
            vmem_bytes=self.base.vmem_bytes,
        )

    # ---- model-vs-measured error --------------------------------------------
    def model_seconds(self, m: Measurement) -> float:
        return _model_seconds(m, self.peaks)

    def observed_seconds(self, m: Measurement) -> float:
        return _observed(m, self.estimator)

    def rel_error(self, m: Measurement) -> float:
        """(model − measured) / measured: negative = model under-predicts."""
        obs = self.observed_seconds(m)
        return (self.model_seconds(m) - obs) / obs

    def errors(self, which: str = "all") -> Dict[str, float]:
        ms = {"fit": self.fit_measurements,
              "validation": self.validation_measurements,
              "all": self.fit_measurements + self.validation_measurements,
              }[which]
        return {m.work.name: self.rel_error(m) for m in ms}

    def error_summary(self, which: str = "all") -> Dict[str, float]:
        errs = sorted(abs(e) for e in self.errors(which).values())
        if not errs:
            return {"n": 0, "median_abs_rel_error": 0.0,
                    "max_abs_rel_error": 0.0}
        mid = len(errs) // 2
        median = errs[mid] if len(errs) % 2 else \
            0.5 * (errs[mid - 1] + errs[mid])
        return {"n": len(errs), "median_abs_rel_error": median,
                "max_abs_rel_error": errs[-1]}

    # ---- persistence ---------------------------------------------------------
    def to_dict(self) -> Dict:
        def dump(ms: Sequence[Measurement]) -> List[Dict]:
            out = []
            for m in ms:
                d = m.to_dict()
                d["assigned"] = _RESOURCES[_assign(m, self.peaks)]
                d["model_seconds"] = self.model_seconds(m)
                d["rel_error"] = self.rel_error(m)
                out.append(d)
            return out

        return {
            "schema": CALIBRATION_SCHEMA,
            "name": self.name,
            "base": self.base.name,
            "estimator": self.estimator,
            "peak_flops": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "net_bw": self.net_bw,
            "extra_links": dict(self.spec().extra_links),
            "vmem_bytes": self.base.vmem_bytes,
            "sources": dict(self.sources),
            "datasheet": {"peak_flops": self.base.peak_flops,
                          "hbm_bw": self.base.hbm_bw,
                          "net_bw": self.base.net_bw},
            "fit": {"iterations": self.iterations,
                    **self.error_summary("fit")},
            "validation": self.error_summary("validation"),
            "measurements": dump(self.fit_measurements),
            "validation_measurements": dump(self.validation_measurements),
        }

    def save(self, registry_dir: Optional[str] = None) -> str:
        from repro.core.hardware import PRESETS
        if self.name in PRESETS:
            raise ValueError(
                f"calibration name {self.name!r} shadows a datasheet preset "
                f"(get_hardware would never resolve it); pick another, e.g. "
                f"{self.name + CALIBRATED_SUFFIX!r}")
        cdir = calibration_dir(registry_dir)
        os.makedirs(cdir, exist_ok=True)
        path = os.path.join(cdir, f"{self.name}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    def summary(self) -> str:
        lines = [f"calibration {self.name} (base {self.base.name}, "
                 f"estimator {self.estimator}, "
                 f"{self.iterations} fit iterations)"]
        datasheet = (self.base.peak_flops, self.base.hbm_bw, self.base.net_bw)
        for r, fitted, ds in zip(_RESOURCES, self.peaks, datasheet):
            lines.append(
                f"  {r:>10}: {fitted:.4g} ({self.sources[r]}; datasheet "
                f"{ds:.4g}, x{fitted / ds:.3f})")
        for which in ("fit", "validation"):
            s = self.error_summary(which)
            if s["n"]:
                lines.append(
                    f"  {which}: n={s['n']} median |rel err| "
                    f"{100 * s['median_abs_rel_error']:.1f}% max "
                    f"{100 * s['max_abs_rel_error']:.1f}%")
        return "\n".join(lines)


def load_calibration_dict(name: str,
                          registry_dir: Optional[str] = None) -> Dict:
    """The raw registry JSON for ``name`` (spec loading lives in hardware)."""
    path = os.path.join(calibration_dir(registry_dir), f"{name}.json")
    with open(path) as f:
        return json.load(f)


def fit_ceilings(measurements: Sequence[Measurement],
                 base: HardwareSpec, *,
                 name: Optional[str] = None,
                 validation: Sequence[Measurement] = (),
                 estimator: str = "best",
                 max_iterations: int = 32) -> Calibration:
    """Alternating assign/least-squares fit of the three ceilings.

    ``measurements`` drive the fit; ``validation`` points (e.g. whole model
    steps) only contribute to the reported error.  Initialization is the
    datasheet ``base``, so resources with no informative measurements keep
    their vendor numbers.  ``estimator`` picks the wall-time statistic
    (see :data:`ESTIMATORS`).
    """
    if not measurements:
        raise ValueError("need at least one measurement to fit")
    if estimator not in ESTIMATORS:
        raise ValueError(f"estimator {estimator!r} not in {ESTIMATORS}")
    peaks = [base.peak_flops, base.hbm_bw, base.net_bw]
    assignment: Optional[List[int]] = None
    iterations = 0
    for _ in range(max_iterations):
        iterations += 1
        new_assignment = [_assign(m, peaks) for m in measurements]
        if new_assignment == assignment:
            break
        assignment = new_assignment
        for r in range(3):
            num = 0.0
            den = 0.0
            for m, a in zip(measurements, assignment):
                if a != r:
                    continue
                q = _quantities(m)[r]
                num += q * _observed(m, estimator)
                den += q * q
            if den > 0 and num > 0:
                peaks[r] = den / num      # 1/peak = Σqt/Σq² -> peak = Σq²/Σqt
    assignment = [_assign(m, peaks) for m in measurements]
    sources = {res: ("measured" if any(a == r for a in assignment)
                     else "datasheet")
               for r, res in enumerate(_RESOURCES)}
    return Calibration(
        name=name or base.name + CALIBRATED_SUFFIX,
        base=base,
        peak_flops=peaks[0], hbm_bw=peaks[1], net_bw=peaks[2],
        sources=sources, iterations=iterations,
        fit_measurements=tuple(measurements),
        validation_measurements=tuple(validation),
        estimator=estimator,
    )


# --- CLI ----------------------------------------------------------------------


def _configure_backend(backend: Optional[str], devices: int) -> None:
    """Set backend env *before* jax is imported anywhere in this process."""
    if "jax" in sys.modules:
        return   # too late to steer; run with the backend already chosen
    if backend and backend != "default":
        os.environ.setdefault("JAX_PLATFORMS", backend)
    # host-device forcing applies to any CPU-backed run, including
    # backend='default' on a box where jax resolves to CPU anyway
    if devices > 1 and backend != "tpu":
        flag = f"--xla_force_host_platform_device_count={devices}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.measure.calibrate",
        description="Measure this machine and fit achievable Ridgeline "
                    "ceilings (PEAK/HBM/NET).")
    ap.add_argument("--hardware", default="clx",
                    help="datasheet preset to calibrate against "
                         "(initialization + fallback for unmeasured "
                         "resources)")
    ap.add_argument("--backend", default="default",
                    choices=("default", "cpu", "tpu"),
                    help="jax platform (set before jax import)")
    ap.add_argument("--devices", type=int, default=1,
                    help="CPU host devices to fake for collective benches "
                         "(>1 enables NET calibration accelerator-free)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few repeats; finishes in <60s on CPU")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per bench (default 3 smoke / 7 full)")
    ap.add_argument("--estimator", default="best", choices=ESTIMATORS,
                    help="wall-time statistic to fit on: 'best' sample "
                         "(robust on shared boxes) or 'median'")
    ap.add_argument("--no-steps", action="store_true",
                    help="skip the whole-model-step validation benches")
    ap.add_argument("--name", default=None,
                    help="registry entry name (default <hardware>_cal)")
    ap.add_argument("--out", default=None,
                    help="registry directory (default artifacts/calibration)")
    ap.add_argument("--figures", default=None,
                    help="also write overlay figures to this directory")
    args = ap.parse_args(argv)

    try:
        base = get_hardware(args.hardware)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    from repro.core.hardware import PRESETS
    if args.name in PRESETS:
        print(f"error: --name {args.name!r} shadows a datasheet preset; "
              f"pick another (default: {args.hardware}_cal)", file=sys.stderr)
        return 2
    _configure_backend(args.backend, args.devices)

    from repro.measure import microbench
    suite = microbench.default_suite(
        smoke=args.smoke, repeats=args.repeats, steps=not args.no_steps)
    fit = [m for m in suite if m.category != "step"]
    steps = [m for m in suite if m.category == "step"]
    if not any(m.category == "network" for m in fit):
        print("note: single device -> no collective benches; NET ceiling "
              "stays datasheet (re-run with --devices N)", file=sys.stderr)

    calib = fit_ceilings(fit, base, name=args.name, validation=steps,
                         estimator=args.estimator)
    path = calib.save(args.out)
    print(calib.summary())
    print(f"wrote {path}")

    from repro.measure import overlay
    cell_paths = overlay.write_measured_cells(calib, registry_dir=args.out)
    for p in cell_paths:
        print(f"wrote {p}")
    if args.figures or not args.out:
        figdir = args.figures or os.path.join(
            os.path.dirname(calibration_dir(args.out)), "figures")
        for p in overlay.write_calibration_figs(figdir, calib):
            print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
