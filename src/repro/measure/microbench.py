"""Sized microbenchmarks: real kernels under a clock, as (WorkUnit, seconds).

Every bench in this module returns a :class:`Measurement` — the analytic
Ridgeline characteristics (F, B_M, B_N) of what actually ran, paired with a
robustly-measured wall time from :mod:`repro.measure.timers`.  The
calibration fit (``measure/calibrate``) turns a suite of these into
achievable PEAK/HBM/NET ceilings; the overlay (``measure/overlay``) plots
them next to the analytic curves.

Bench families and which resource they are built to saturate:

  * ``matmul_benches`` — square GEMMs through the kernel dispatch layer
    (``kernels/ops.matmul``: reference path on CPU, Pallas on TPU).
    Compute-dominant at the larger sizes.
  * ``memory_benches`` — elementwise streams (saxpy) over arrays far larger
    than LLC.  Memory-dominant by construction: ~0.25 FLOP per byte.
  * ``collective_benches`` — ``psum`` all-reduces over every local device
    (needs >1 device: real chips, or CPU host devices via
    ``--devices N`` on the calibrate CLI).  Network-dominant; wire bytes
    priced by ``distributed/collectives`` under the ring model.
  * ``step_benches`` — whole jitted model steps on tiny configs: the
    dlrm-mlp train step (``train/loop``) and a reduced dense-LM decode step
    (``serve/engine``), with F/B_M read off the compiled HLO via
    ``core/hlo_analysis.cost_analysis_dict``.  These are *validation*
    points: the calibrate CLI fits ceilings on the micro suites and reports
    model-vs-measured error on the steps.

All benches run accelerator-free on the CPU backend (shapes are sized so the
smoke suite finishes in well under a minute).
"""
from __future__ import annotations

import dataclasses
import math
import time
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ridgeline import WorkUnit
from repro.measure.timers import (TimingStats, block_until_ready,
                                  time_callable)
from repro.obs import trace

#: bench categories, also used by calibrate.py to split fit vs validation
CATEGORIES = ("compute", "memory", "network", "step")

#: large sizes saturate the β (bandwidth) term; the *small* entries exist to
#: expose the α intercept (t = α + q/peak) — and, since the efficiency-curve
#: fit (calibrate v3), to trace out the sub-peak small-GEMM tail of
#: ``eff(F)``: the 64³/128³ GEMMs run at a few percent of what 1024³
#: sustains, which is exactly the curvature the Hill fit needs to see
SMOKE_MATMUL_SIZES = (64, 128, 256, 512, 768, 1024)
FULL_MATMUL_SIZES = (64, 128, 256, 512, 1024, 1536, 2048)
#: streams stay well above LLC size — a sub-cache stream measures cache,
#: not HBM, and would silently poison the fitted ceiling
SMOKE_STREAM_MB = (32, 64)
FULL_STREAM_MB = (32, 64, 128, 256)
#: ...except the KB-scale entries: their bandwidth term is negligible at
#: *any* plausible rate (64 KB is <100 µs even at 1 GB/s), so they are
#: pure per-execution dispatch overhead — the α_M intercept the 2-param
#: fit needs, unidentifiable from same-decade saturating sizes alone
SMOKE_STREAM_KB = (64,)
FULL_STREAM_KB = (64, 256)
SMOKE_COLLECTIVE_MB = (4, 16)
FULL_COLLECTIVE_MB = (4, 16, 64)
#: small-payload collectives: the per-hop α dominates these, which is what
#: lets the network fit see latency at all (ISSUE 3 / ROADMAP α item); the
#: 16 KB point is nearly pure latency, anchoring α against bandwidth noise
SMOKE_COLLECTIVE_KB = (16, 64, 256)
FULL_COLLECTIVE_KB = (16, 64, 256, 1024)


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One (WorkUnit, measured seconds) pair plus provenance.

    ``seconds`` is the median wall time (the typical operating point under
    whatever contention the box has); ``best_seconds`` is the fastest sample
    — the noise-robust estimator of what the hardware can do, which is what
    ceiling *fitting* uses (``calibrate.fit_ceilings(estimator=...)``).
    """

    work: WorkUnit
    seconds: float                   # median wall time of one execution
    category: str                    # one of CATEGORIES
    best_seconds: float = 0.0        # fastest sample; 0 -> falls back to median
    rel_spread: float = 0.0          # IQR / median from the timing harness
    backend: str = ""
    meta: Tuple[Tuple[str, str], ...] = ()   # extra key/value provenance

    def __post_init__(self):
        if self.category not in CATEGORIES:
            raise ValueError(
                f"category {self.category!r} not in {CATEGORIES}")
        if self.seconds <= 0:
            raise ValueError(f"non-positive measurement for {self.work.name}")

    @property
    def best(self) -> float:
        return self.best_seconds or self.seconds

    @property
    def link(self) -> Optional[str]:
        """Network link tag this measurement exercised (None = primary)."""
        return dict(self.meta).get("link")

    def to_dict(self) -> Dict:
        return {
            "name": self.work.name,
            "flops": self.work.flops,
            "mem_bytes": self.work.mem_bytes,
            "net_bytes": self.work.net_bytes,
            "net_steps": self.work.net_steps,
            "seconds": self.seconds,
            "best_seconds": self.best,
            "category": self.category,
            # a NaN spread (n<3: not measurable — timers.rel_spread) is
            # not representable in strict JSON; serialize it as null
            "rel_spread": None if math.isnan(self.rel_spread)
            else self.rel_spread,
            "backend": self.backend,
            "meta": dict(self.meta),
        }

    @staticmethod
    def from_dict(d: Dict) -> "Measurement":
        spread = d.get("rel_spread", 0.0)
        return Measurement(
            work=WorkUnit(d["name"], d["flops"], d["mem_bytes"],
                          d["net_bytes"],
                          net_steps=d.get("net_steps", 0.0)),
            seconds=d["seconds"], category=d["category"],
            best_seconds=d.get("best_seconds", 0.0),
            rel_spread=math.nan if spread is None else spread,
            backend=d.get("backend", ""),
            meta=tuple(sorted(d.get("meta", {}).items())))


#: transient bench failures (allocator pressure bursts, backend runtime
#: hiccups) get this many retries before the suite gives up on the bench
_BENCH_RETRIES = 2
#: backoff base between bench retries: base · 2^(k−1), deterministically
#: jittered per bench name so parallel suites desynchronize
_BENCH_BACKOFF_S = 0.05
#: cooperative per-bench wall budget: a cold probe call projects the full
#: median-of-k run, and repeats are clamped to fit the budget (floor 1) —
#: one mispriced bench can no longer eat the whole CI timing budget
_BENCH_TIMEOUT_S = 30.0

#: the retryable class — runtime/backend errors, not programming errors
#: (a ValueError from bad shapes would fail identically on every retry)
_TRANSIENT = (RuntimeError, OSError, MemoryError)


def _guarded_stats(name: str, fn, *, repeats: int, warmup: int,
                   retries: int = _BENCH_RETRIES,
                   timeout_s: float = _BENCH_TIMEOUT_S,
                   span=None) -> TimingStats:
    """``time_callable`` with bounded retry and a per-bench budget guard.

    The guard is cooperative (it cannot interrupt a hung kernel): a timed
    probe call — which doubles as extra warmup — projects the cost of the
    full ``warmup + repeats`` run, and the repeat count is clamped so the
    bench fits ``timeout_s``.  The probe is a *cold* call (it may carry
    compilation), so clamping is conservative: a bench is only cut when
    even optimistic accounting cannot fit it.
    """
    for attempt in range(retries + 1):
        try:
            t0 = time.monotonic()
            block_until_ready(fn())
            probe_s = time.monotonic() - t0
            r = repeats
            if timeout_s > 0 and probe_s * (warmup + repeats) > timeout_s:
                r = max(1, int(timeout_s / probe_s) - warmup)
                trace.count("bench.repeats_clamped", 1)
                if span is not None:
                    span.set(repeats_clamped=r, probe_s=probe_s)
            return time_callable(fn, repeats=r, warmup=warmup)
        except _TRANSIENT:  # noqa: PERF203
            if attempt >= retries:
                raise
            trace.count("bench.retries", 1)
            # deterministic per-bench jitter: crc32 of the name spreads
            # concurrent suites without any mutable RNG state
            jitter = 1.0 + 0.1 * ((zlib.crc32(name.encode()) % 256) / 255.0
                                  - 0.5)
            time.sleep(_BENCH_BACKOFF_S * 2.0 ** attempt * jitter)
    raise AssertionError("unreachable")  # pragma: no cover


def _measure(name: str, fn, work: WorkUnit, category: str, *,
             repeats: int, warmup: int = 2,
             meta: Tuple[Tuple[str, str], ...] = ()) -> Measurement:
    import jax
    # link-tagged span per bench: meta keys ("link", "via", ...) become
    # span args, so a calibration trace shows where the suite spent time
    with trace.span(f"bench.{work.name}", category=category,
                    repeats=repeats, **dict(meta)) as sp:
        stats: TimingStats = _guarded_stats(work.name, fn, repeats=repeats,
                                            warmup=warmup, span=sp)
        sp.set(median_s=stats.median, best_s=stats.best)
    return Measurement(
        work=work, seconds=stats.median, best_seconds=stats.best,
        category=category, rel_spread=stats.rel_spread,
        backend=jax.default_backend(), meta=meta)


# --- compute: GEMMs through the kernel dispatch layer -------------------------


def matmul_benches(sizes: Sequence[int] = SMOKE_MATMUL_SIZES, *,
                   repeats: int = 5,
                   via: Optional[str] = None) -> List[Measurement]:
    """Square f32 GEMMs through the kernel layer (``kernels/ops`` + ``ref``).

    ``via='ops'`` times the production dispatch wrapper — the Pallas blocked
    kernel, compiled natively on TPU.  On CPU that wrapper runs Pallas in
    interpret mode, whose per-block emulation overhead would be *measured
    as* compute; so the default there is ``via='ref'``, the jitted reference
    kernel (plain XLA dot — what this backend can actually do).

    WorkUnit accounting is the compulsory-traffic model the planner uses:
    F = 2·M·N·K MACs-as-flops, B_M = one read of each operand + one write of
    the output.  B_N = 0 (single-device kernels).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    if via is None:
        via = "ops" if jax.default_backend() == "tpu" else "ref"
    if via not in ("ops", "ref"):
        raise ValueError(f"via must be 'ops' or 'ref', got {via!r}")
    matmul = ops.matmul if via == "ops" else jax.jit(ref.ref_matmul)
    out = []
    for s in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (s, s), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (s, s), jnp.float32)
        itemsize = a.dtype.itemsize
        work = WorkUnit(f"matmul_{s}x{s}x{s}",
                        flops=2.0 * s * s * s,
                        mem_bytes=3.0 * s * s * itemsize,
                        net_bytes=0.0)
        out.append(_measure(work.name, lambda a=a, b=b: matmul(a, b),
                            work, "compute", repeats=repeats,
                            meta=(("via", via),)))
    return out


# --- memory: elementwise streams ----------------------------------------------


def memory_benches(sizes_mb: Sequence[int] = SMOKE_STREAM_MB, *,
                   sizes_kb: Sequence[int] = SMOKE_STREAM_KB,
                   repeats: int = 5) -> List[Measurement]:
    """saxpy streams ``y = 2x + y``: 2 FLOP and 12 bytes per element (f32).

    The MiB entries are sized in *total traffic* well beyond cache, so the
    measured rate is main-memory bandwidth, not LLC — they anchor the
    fitted ceiling.  The KiB entries are latency probes: at that size the
    transfer term vanishes and the wall time *is* the per-execution
    dispatch overhead, which is what identifies α_M (and what a
    whole-model step pays at least once, however small its traffic).
    """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def saxpy(x, y):
        return 2.0 * x + y

    out = []
    sizes = [(kb * 1024, f"saxpy_{kb}kb") for kb in sizes_kb]
    sizes += [(mb * 1024 * 1024, f"saxpy_{mb}mb") for mb in sizes_mb]
    for nbytes, name in sizes:
        n = max(1, nbytes // 4)            # f32 elements per operand
        x = jnp.ones((n,), jnp.float32)
        y = jnp.full((n,), 0.5, jnp.float32)
        work = WorkUnit(name,
                        flops=2.0 * n,
                        mem_bytes=3.0 * n * 4,   # read x, read y, write out
                        net_bytes=0.0)
        out.append(_measure(work.name, lambda x=x, y=y: saxpy(x, y),
                            work, "memory", repeats=repeats))
    return out


# --- network: all-reduce over the local device mesh ---------------------------


def collective_benches(sizes_mb: Sequence[int] = SMOKE_COLLECTIVE_MB, *,
                       sizes_kb: Sequence[int] = SMOKE_COLLECTIVE_KB,
                       repeats: int = 5,
                       link: str = "net") -> List[Measurement]:
    """Ring-priced ``psum`` all-reduces across all local devices.

    Returns ``[]`` on a single-device process — there is no wire to measure;
    the calibrate CLI then keeps the datasheet NET ceiling and says so.
    Payload is the per-chip logical tensor; wire bytes *and hop counts*
    follow the ``distributed/collectives`` ring model, so the calibrated
    per-link (α, bandwidth) pair is directly comparable with the analytic
    planner's α–β accounting.  The KB-scale payloads are latency-dominated
    by construction — without them the fit cannot see α.  ``link`` tags
    which mesh axis these collectives rode (meta key the per-axis fit
    groups by); the default is the primary link.
    """
    import jax
    import jax.numpy as jnp

    from repro.distributed import collectives

    n_dev = jax.local_device_count()
    if n_dev < 2:
        return []
    psum = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    out = []
    sizes = [(kb * 1024, f"allreduce_{kb}kb_x{n_dev}") for kb in sizes_kb]
    sizes += [(mb * 1024 * 1024, f"allreduce_{mb}mb_x{n_dev}")
              for mb in sizes_mb]
    for nbytes, name in sizes:
        n = max(1, nbytes // 4)
        x = jnp.ones((n_dev, n), jnp.float32)
        payload = float(n) * 4.0
        cost = collectives.all_reduce(payload, n_dev, "ring")
        # per-chip reduction flops (~(n−1)/n adds per element) and the
        # staging traffic of touching the payload twice
        work = WorkUnit(name,
                        flops=float(n),
                        mem_bytes=2.0 * payload,
                        net_bytes=float(cost.wire_bytes),
                        net_steps=float(cost.steps))
        out.append(_measure(work.name, lambda x=x: psum(x),
                            work, "network", repeats=repeats,
                            meta=(("link", link),)))
    return out


# --- whole model steps (validation points) ------------------------------------


def _hlo_work_unit(name: str, compiled, net_bytes: float = 0.0) -> WorkUnit:
    from repro.core.hlo_analysis import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    return WorkUnit(name,
                    flops=float(cost.get("flops", 0.0)),
                    mem_bytes=float(cost.get("bytes accessed", 0.0)),
                    net_bytes=net_bytes)


def train_step_bench(batch: int = 64, width: int = 256, layers: int = 3, *,
                     repeats: int = 3) -> Measurement:
    """Tiny dlrm-mlp train step (loss+grad+SGD), F/B_M from compiled HLO."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.optim.optimizer import SGD
    from repro.train.loop import (TrainStepConfig, build_train_step,
                                  init_train_state)

    cfg = get_config("dlrm-mlp").replace(
        n_layers=layers, mlp_widths=(width,) * layers, d_model=width,
        compute_dtype=jnp.float32)
    opt = SGD(learning_rate=1e-2)
    step = build_train_step(cfg, opt, TrainStepConfig())
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    batch_arrs = {
        "features": jax.random.normal(jax.random.PRNGKey(1), (batch, width)),
        "click": jnp.zeros((batch,), jnp.float32),
    }
    jitted = jax.jit(step)
    compiled = jitted.lower(state, batch_arrs).compile()
    work = _hlo_work_unit(f"train_step_mlp_b{batch}_w{width}x{layers}",
                          compiled)
    with trace.span(f"bench.{work.name}", category="step",
                    kind="train_step", repeats=repeats) as sp:
        stats = _guarded_stats(work.name, lambda: jitted(state, batch_arrs),
                               repeats=repeats, warmup=2, span=sp)
    return Measurement(work=work, seconds=stats.median, category="step",
                       rel_spread=stats.rel_spread,
                       backend=jax.default_backend(),
                       meta=(("kind", "train_step"), ("arch", "dlrm-mlp")))


def serve_step_bench(batch: int = 8, max_len: int = 64, *,
                     repeats: int = 3) -> Measurement:
    """One-token decode on the reduced smollm config, F/B_M from HLO."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import transformer as lm_mod
    from repro.serve.engine import build_serve_step, init_cache

    cfg = get_reduced("smollm-135m")
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    cache = init_cache(params, cfg, batch, max_len)
    tok = jnp.zeros((batch, 1), jnp.int32)
    pos = jnp.int32(1)
    jitted = jax.jit(build_serve_step(cfg))
    compiled = jitted.lower(params, tok, cache, pos).compile()
    work = _hlo_work_unit(f"serve_step_smollm_b{batch}", compiled)
    with trace.span(f"bench.{work.name}", category="step",
                    kind="serve_step", repeats=repeats) as sp:
        stats = _guarded_stats(work.name,
                               lambda: jitted(params, tok, cache, pos),
                               repeats=repeats, warmup=2, span=sp)
    return Measurement(work=work, seconds=stats.median, category="step",
                       rel_spread=stats.rel_spread,
                       backend=jax.default_backend(),
                       meta=(("kind", "serve_step"), ("arch", "smollm-135m")))


def step_benches(*, smoke: bool = True, repeats: int = 3,
                 passes: int = 2) -> List[Measurement]:
    """Whole-step validation points spanning scales.

    Three points even in smoke mode: a median over two validation steps is
    just their mean, so one structurally-hard point (the tiny decode step,
    whose sub-peak GEMMs no max-of-ceilings model captures) used to define
    the reported error by itself.

    Each bench runs ``passes`` times spread across the suite and keeps the
    pass with the fastest best-sample (see :func:`merge_passes`).
    """
    def one_pass() -> List[Measurement]:
        out = [train_step_bench(repeats=repeats),
               train_step_bench(batch=256, width=512, layers=4,
                                repeats=repeats),
               serve_step_bench(repeats=repeats)]
        if not smoke:
            out.append(serve_step_bench(batch=16, max_len=128,
                                        repeats=repeats))
        return out

    return merge_passes([one_pass() for _ in range(max(passes, 1))])


#: a pass best this far below the median-of-passes is treated as a fluke
_FLUKE_RATIO = 0.4


def merge_passes(passes: Sequence[List[Measurement]]) -> List[Measurement]:
    """Per bench, keep the fastest pass — unless it looks like a fluke.

    Contention on small shared boxes comes in seconds-long bursts, so
    back-to-back repeats of one bench are correlated — keeping the fastest
    of several *separated* passes is how the ``best`` estimator reaches
    the uncontended time.  But a single pass can also be anomalously
    *fast* (page-cache/allocator flukes on streams), and a plain min
    selects exactly those flukes into the fit; a best more than
    ``_FLUKE_RATIO`` below the median-of-passes falls back to the median
    pass instead.
    """
    merged = []
    for group in zip(*passes):
        ranked = sorted(group, key=lambda m: m.best)
        fastest = ranked[0]
        median = ranked[(len(ranked) - 1) // 2]
        merged.append(fastest if fastest.best >= _FLUKE_RATIO * median.best
                      else median)
    return merged


# --- the suite ----------------------------------------------------------------


def _global_warmup() -> None:
    """One discarded kernel round to absorb runtime/threadpool cold start.

    Per-bench warmup handles tracing+compilation; this handles the first
    touch of the jax runtime itself, which otherwise lands entirely on
    whichever bench happens to run first.
    """
    import jax
    import jax.numpy as jnp
    x = jnp.ones((1024, 1024), jnp.float32)
    jax.block_until_ready(jax.jit(lambda a: a @ a)(x))


def default_suite(*, smoke: bool = True, repeats: Optional[int] = None,
                  steps: bool = True, passes: int = 3) -> List[Measurement]:
    """The standard calibration suite: micro fits + step validation points.

    Default repeats are deliberately generous, and the whole suite runs
    ``passes`` times with the fastest best-sample kept per bench
    (:func:`merge_passes`): the ``best`` estimator the fit uses converges
    to the uncontended time only with enough *decorrelated* draws, and on
    small shared boxes contention noise — not bench cost — is what limits
    calibration quality.
    """
    r = repeats if repeats is not None else (9 if smoke else 11)
    _global_warmup()

    def one_pass() -> List[Measurement]:
        # steps lead the pass: they are the validation criterion, and on
        # burst-throttled boxes whatever runs last in a sustained load
        # window measures systematically slow — putting the whole-step
        # clocks next to the micro clocks they are compared against keeps
        # the fit and its validation in the same contention regime
        out: List[Measurement] = []
        if steps:
            out += step_benches(smoke=smoke, repeats=r, passes=1)
        out += matmul_benches(
            SMOKE_MATMUL_SIZES if smoke else FULL_MATMUL_SIZES, repeats=r)
        out += memory_benches(SMOKE_STREAM_MB if smoke else FULL_STREAM_MB,
                              sizes_kb=(SMOKE_STREAM_KB if smoke
                                        else FULL_STREAM_KB),
                              repeats=r)
        out += collective_benches(
            SMOKE_COLLECTIVE_MB if smoke else FULL_COLLECTIVE_MB,
            sizes_kb=SMOKE_COLLECTIVE_KB if smoke else FULL_COLLECTIVE_KB,
            repeats=r)
        return out

    results = []
    for p in range(max(passes, 1)):
        with trace.span("bench.suite_pass", index=p, smoke=smoke):
            results.append(one_pass())
    return merge_passes(results)
