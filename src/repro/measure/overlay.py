"""Measured overlays: empirical dots + model error on reports and figures.

The analytic pipeline ends in two artifact kinds — per-cell ``CellReport``
JSONs (``core/report``) and Ridgeline plane figures (``core/ridgeline``
ascii/svg).  This module closes the loop by stamping measured wall times and
model-vs-measured relative error onto both:

  * :func:`attach_measurement` fills the ``measured_*`` fields of a
    CellReport (the schema carries them as zeros until a clock has run);
  * :func:`write_measured_cells` emits one measured CellReport per
    whole-model-step validation bench of a :class:`~.calibrate.Calibration`,
    under ``artifacts/calibration/cells/``;
  * :func:`write_calibration_figs` renders the calibration's measurements on
    the *calibrated* spec's Ridgeline plane, with each point annotated
    ``meas <wall> vs model <projection> (±err%)`` — empirical dots next to
    analytic curves, per the time-based-roofline methodology.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

from repro.core.report import CellReport
from repro.core.ridgeline import analyze, ascii_plot, svg_plot
from repro.measure.calibrate import Calibration
from repro.measure.microbench import Measurement


def rel_error(model_seconds: float, measured_seconds: float) -> float:
    """(model − measured) / measured; negative = model under-predicts."""
    if measured_seconds <= 0:
        raise ValueError(f"non-positive measurement {measured_seconds}")
    return (model_seconds - measured_seconds) / measured_seconds


def attach_measurement(report: CellReport, measured_seconds: float,
                       source: str = "measured") -> CellReport:
    """Stamp a wall-clock measurement (and model error) onto a CellReport."""
    report.measured_runtime = float(measured_seconds)
    report.measured_rel_error = rel_error(report.runtime, measured_seconds)
    report.measured_source = source
    return report


def _fmt(seconds: float) -> str:
    return f"{seconds * 1e6:.0f}us" if seconds < 1e-3 else \
        f"{seconds * 1e3:.2f}ms"


def point_notes(calib: Calibration,
                measurements: Optional[Sequence[Measurement]] = None
                ) -> Dict[str, str]:
    """name -> 'meas … vs model … (±err%)' annotations for the plotters.

    When the calibration fitted a size-dependent efficiency curve, each
    point also carries its achievable-PEAK fraction ``eff(F)`` — the
    figure then shows *why* the small points sit under the ceiling.
    """
    ms = measurements if measurements is not None else (
        calib.fit_measurements + calib.validation_measurements)
    eff = calib.compute_eff

    def note(m: Measurement) -> str:
        s = (f"meas {_fmt(calib.observed_seconds(m))} vs model "
             f"{_fmt(calib.model_seconds(m))} ({calib.rel_error(m):+.0%})")
        if not eff.is_identity and m.work.flops > 0:
            s += f" eff {eff.eff(m.work.flops):.0%}"
        return s

    return {m.work.name: note(m) for m in ms}


def measured_table(reports: Sequence[CellReport]) -> str:
    """Markdown table of model-vs-measured runtimes for measured cells."""
    head = ("| arch | shape | mesh | model runtime | measured | rel err | "
            "source |\n|---|---|---|---|---|---|---|")
    rows = [head]
    for r in reports:
        if not r.measured_runtime:
            continue
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {_fmt(r.runtime)} | "
            f"{_fmt(r.measured_runtime)} | {r.measured_rel_error:+.1%} | "
            f"{r.measured_source} |")
    return "\n".join(rows)


def measured_cell_reports(calib: Calibration) -> List[CellReport]:
    """One measured CellReport per whole-model-step validation bench."""
    hw = calib.spec()
    out = []
    for m in calib.validation_measurements:
        meta = dict(m.meta)
        rep = CellReport(
            arch=meta.get("arch", m.work.name), shape=m.work.name,
            mesh="1", step_kind=meta.get("kind", "step"),
            num_devices=1, hardware=hw.name,
            flops=m.work.flops, mem_bytes=m.work.mem_bytes,
            wire_bytes=m.work.net_bytes, wire_bytes_by_kind={},
            peak_memory_per_device=0.0,
            model_flops=m.work.flops, params_total=0.0, params_active=0.0,
            tokens_per_step=0.0, variant="measured",
            notes=f"microbench validation ({m.backend})")
        rep.finalize(hw)
        # same wall-time statistic as the registry/figures, so every
        # artifact of one calibration reports one consistent rel error
        attach_measurement(
            rep, calib.observed_seconds(m),
            source=f"calibrate:{calib.name}@{m.backend}/{calib.estimator}")
        out.append(rep)
    return out


def write_measured_cells(calib: Calibration,
                         registry_dir: Optional[str] = None) -> List[str]:
    """Persist measured CellReports under <calibration dir>/cells/."""
    from repro.core.hardware import calibration_dir
    cdir = os.path.join(calibration_dir(registry_dir), "cells")
    return [rep.save(cdir) for rep in measured_cell_reports(calib)]


def write_calibration_figs(outdir: str, calib: Calibration) -> List[str]:
    """Ridgeline plane of the measured points on the calibrated spec.

    Every measured point draws as a hollow marker with its wall time and
    model error; the analytic regions/ridges behind them come from the
    *calibrated* ceilings, so the figure is the measured machine, not the
    datasheet cartoon.
    """
    os.makedirs(outdir, exist_ok=True)
    hw = calib.spec()
    ms = list(calib.fit_measurements) + list(calib.validation_measurements)
    analyses = [analyze(m.work, hw) for m in ms]
    notes = point_notes(calib, ms)
    paths = []
    p = os.path.join(outdir, f"calibration_{calib.name}.svg")
    with open(p, "w") as f:
        f.write(svg_plot(analyses, hw, width=880, height=560,
                         point_notes=notes))
    paths.append(p)
    p = os.path.join(outdir, f"calibration_{calib.name}.txt")
    with open(p, "w") as f:
        f.write(ascii_plot(analyses, hw, point_notes=notes))
        f.write("\n\n" + calib.summary() + "\n")
    paths.append(p)
    return paths
