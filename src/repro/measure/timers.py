"""Robust wall-clock timing for jitted callables (and plain Python ones).

The measurement discipline every microbenchmark in this subsystem shares:

  * **dispatch-blind**: jax dispatch is async, so the raw return of a jitted
    call measures almost nothing.  Every sample walks the output pytree and
    calls ``block_until_ready`` on any leaf that has it (duck-typed — this
    module never imports jax, so the statistics are unit-testable and the
    harness times plain Python functions unchanged).
  * **jit-discard**: the first ``warmup`` calls are timed but excluded from
    the statistics; the first of them absorbs tracing + compilation.
  * **median-of-k with IQR**: wall clocks on shared CPU boxes are noisy and
    right-skewed (GC, scheduler).  We report the median as the estimate and
    the inter-quartile range as the spread; mean/min/max ride along.

``time_callable`` is the one entry point; ``robust_stats`` is the pure
statistics core.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Sequence, Tuple

#: fewer kept samples than this and an IQR is structurally ~0 — the spread
#: statistic is undefined, not "perfectly stable"
MIN_SAMPLES_FOR_SPREAD = 3


def _leaves(out: Any):
    """Minimal pytree walk (list/tuple/dict) — enough to reach jax arrays."""
    if isinstance(out, (list, tuple)):
        for x in out:
            yield from _leaves(x)
    elif isinstance(out, dict):
        for x in out.values():
            yield from _leaves(x)
    else:
        yield out


def block_until_ready(out: Any) -> Any:
    """Duck-typed ``jax.block_until_ready``: blocks every leaf that can."""
    for leaf in _leaves(out):
        blocker = getattr(leaf, "block_until_ready", None)
        if callable(blocker):
            blocker()
    return out


def _quantile(sorted_xs: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile of an ascending sequence (numpy's
    default method), without requiring numpy."""
    n = len(sorted_xs)
    if n == 0:
        raise ValueError("quantile of empty sample")
    if n == 1:
        return float(sorted_xs[0])
    pos = q * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac)


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Median-of-k summary of one timed callable."""

    samples: Tuple[float, ...]       # kept samples, seconds, call order
    warmup_samples: Tuple[float, ...]  # discarded jit/warmup calls
    median: float
    iqr: float                       # q75 − q25 of the kept samples
    mean: float
    best: float
    worst: float

    @property
    def rel_spread(self) -> float:
        """IQR as a fraction of the median — the noise figure of merit.

        NaN when fewer than :data:`MIN_SAMPLES_FOR_SPREAD` samples were
        kept: a 1–2 sample run has an IQR of (near) 0 by construction,
        and reporting ``0.0`` there would read as "perfectly stable"
        when the spread was simply never measured.  NaN propagates
        honestly through downstream noise gates (any ``spread < tol``
        acceptance check fails rather than silently passing).
        """
        if len(self.samples) < MIN_SAMPLES_FOR_SPREAD:
            return math.nan
        return self.iqr / self.median if self.median > 0 else 0.0

    @property
    def seconds(self) -> float:
        """The headline estimate (median)."""
        return self.median

    def summary(self) -> str:
        s = (f"{self.median * 1e3:.3f}ms ±{self.iqr * 1e3:.3f}ms IQR "
             f"(n={len(self.samples)}, best {self.best * 1e3:.3f}ms)")
        if len(self.samples) < MIN_SAMPLES_FOR_SPREAD:
            s += " [n<3: spread not measurable]"
        return s


def robust_stats(samples: Sequence[float],
                 warmup: int = 0) -> TimingStats:
    """Median/IQR statistics over ``samples``, discarding the first ``warmup``."""
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    kept = [float(s) for s in samples[warmup:]]
    if not kept:
        raise ValueError(
            f"no samples left after discarding {warmup} warmup calls "
            f"(got {len(samples)} total)")
    srt = sorted(kept)
    return TimingStats(
        samples=tuple(kept),
        warmup_samples=tuple(float(s) for s in samples[:warmup]),
        median=_quantile(srt, 0.5),
        iqr=_quantile(srt, 0.75) - _quantile(srt, 0.25),
        mean=sum(kept) / len(kept),
        best=srt[0],
        worst=srt[-1],
    )


def time_callable(fn: Callable, *args,
                  repeats: int = 7,
                  warmup: int = 2,
                  calls_per_sample: int = 1,
                  clock: Callable[[], float] = time.perf_counter,
                  **kwargs) -> TimingStats:
    """Time ``fn(*args, **kwargs)`` with warmup discard and median-of-k.

    Each of the ``warmup + repeats`` samples times ``calls_per_sample``
    back-to-back calls (bump it for sub-microsecond callables so the clock
    granularity stops dominating) and divides the elapsed wall time through.
    Outputs are blocked on (``block_until_ready``) *inside* the timed region,
    so async-dispatch runtimes are charged for the work, not the dispatch.
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    if calls_per_sample < 1:
        raise ValueError(f"calls_per_sample must be >= 1, got {calls_per_sample}")
    samples = []
    for _ in range(warmup + repeats):
        t0 = clock()
        for _ in range(calls_per_sample):
            block_until_ready(fn(*args, **kwargs))
        samples.append((clock() - t0) / calls_per_sample)
    return robust_stats(samples, warmup=warmup)
