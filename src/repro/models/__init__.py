"""Model zoo: 10 assigned architectures + the paper's DLRM MLP case study."""
from repro.models.common import ModelConfig, count_params, softmax_cross_entropy
