"""Grouped-query attention: init, full-sequence apply, single-token decode.

Supports: GQA (kv heads < q heads), optional QKV bias (Qwen2), per-head QK
RMS-norm (Qwen3), RoPE / learned / no positions, causal or bidirectional,
sliding-window masks (Hymba local layers), cross-attention (Whisper decoder),
and a Pallas flash-attention fast path (``cfg.use_flash``).

Shapes: activations (B, S, D); per-head tensors (B, S, H, dh).
KV cache for decode: dict(k=(B, S_max, Hkv, dh), v=..., pos scalar index).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, Specs, apply_rope,
                                 dense_init, ones, rms_norm_head, zeros)

NEG_INF = -0.7 * jnp.finfo(jnp.float32).max


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((cfg.q_dim,))
        p["bk"] = zeros((cfg.kv_dim,))
        p["bv"] = zeros((cfg.kv_dim,))
    if cfg.qk_norm:
        p["q_norm"] = ones((cfg.dh,))
        p["k_norm"] = ones((cfg.dh,))
    return p


def attention_specs(cfg: ModelConfig) -> Specs:
    p = {
        "wq": ("embed", "q_proj"),
        "wk": ("embed", "kv_proj"),
        "wv": ("embed", "kv_proj"),
        "wo": ("q_proj", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("q_proj",)
        p["bk"] = ("kv_proj",)
        p["bv"] = ("kv_proj",)
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, kv_src: jnp.ndarray,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    dt = cfg.compute_dtype
    B, S = x.shape[0], x.shape[1]
    Skv = kv_src.shape[1]
    q = x @ p["wq"].astype(dt)
    k = kv_src @ p["wk"].astype(dt)
    v = kv_src @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, cfg.n_heads, cfg.dh)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.dh)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.dh)
    if cfg.qk_norm:
        q = rms_norm_head(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_head(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _mask_bias(sq: int, skv: int, causal: bool, window: int,
               offset: int = 0) -> Optional[jnp.ndarray]:
    """(sq, skv) additive fp32 mask; None if fully visible.

    ``offset`` = absolute position of query 0 minus position of key 0
    (decode: q_pos - 0).
    """
    if not causal and window <= 0:
        return None
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window > 0:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          bias: Optional[jnp.ndarray], cfg: ModelConfig) -> jnp.ndarray:
    """Reference dot-product attention with GQA head grouping.

    q: (B, Sq, H, dh), k/v: (B, Skv, K, dh) -> (B, Sq, H, dh).
    GQA is realized by repeating K/V up to H heads rather than splitting q
    into (K, G): the repeat keeps the head axis intact, which is what lets
    GSPMD shard the O(S^2) score tensor over the mesh ``model`` axis (a
    (K,G) reshape of a sharded head axis defeats propagation and replicates
    the scores — measured 54 GiB/device on smollm train_4k before this).
    Softmax in fp32 for numerics; contractions stay in compute dtype so the
    MXU path (and cost analysis) reflect bf16 math.
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    if K != H:
        reps = H // K
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    if bias is not None:
        scores = scores + bias
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def apply_attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    kv_src: Optional[jnp.ndarray] = None,     # cross-attention source
    causal: Optional[bool] = None,
    window: int = 0,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    cross = kv_src is not None
    kv_src = x if kv_src is None else kv_src.astype(dt)
    causal = (cfg.causal and not cross) if causal is None else causal
    B, S = x.shape[0], x.shape[1]
    q, k, v = _project_qkv(p, x, kv_src, cfg)
    if cfg.pos_emb == "rope" and not cross:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # "attn_seq" is the SP-fallback axis: mapped to the model axis only when
    # heads can't shard it (see dryrun._rules_for), so head-TP archs keep
    # collective-free attention and odd-head archs still shard the O(S^2)
    # scores over seq.
    from repro.distributed.sharding import shard_hint
    q = shard_hint(q, ("batch", "attn_seq", "heads", None))
    k = shard_hint(k, ("batch", "attn_seq", "kv_heads", None))
    v = shard_hint(v, ("batch", "attn_seq", "kv_heads", None))
    if cfg.use_flash and not cross and q.shape[1] == k.shape[1]:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif (cfg.attn_impl == "chunked" and not cross
          and q.shape[1] == k.shape[1] and q.shape[1] > cfg.attn_block_q):
        out = _blockwise_sdpa(q, k, v, cfg, causal, window)
    else:
        bias = _mask_bias(q.shape[1], k.shape[1], causal, window)
        out = _sdpa(q, k, v, bias, cfg)
    out = out.reshape(B, S, cfg.q_dim)
    return out @ p["wo"].astype(dt)


def _blockwise_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    cfg: ModelConfig, causal: bool, window: int) -> jnp.ndarray:
    """Blockwise (chunked) attention: scan over q blocks, O(S·bq) memory.

    The pure-XLA counterpart of the Pallas flash kernel: never materializes
    the (S × S) score tensor — each scan step computes one q-block's scores
    against all keys (bq × S), masks by absolute block position, softmaxes
    and contracts.  XLA reuses the step buffer across iterations, so the
    peak transient drops by S/bq (32× at prefill_32k with bq=1024).  Used
    when ``cfg.attn_impl == "chunked"``; the §Perf memory lever.
    """
    from repro.distributed.sharding import shard_hint
    B, S, H, dh = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    bq = cfg.attn_block_q
    Sp = ((S + bq - 1) // bq) * bq
    if Sp != S:
        # pad query rows (their outputs are sliced off; keys keep length S)
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    nb = Sp // bq
    qb = jnp.moveaxis(q.reshape(B, nb, bq, H, dh), 1, 0)   # (nb,B,bq,H,dh)
    scale = jnp.sqrt(dh).astype(q.dtype)
    kpos = jnp.arange(S)[None, :]

    def body(_, inp):
        qi, i = inp
        # re-assert the SP sharding inside the scan (slicing the leading
        # block axis would otherwise leave the block replicated)
        qi = shard_hint(qi, ("batch", "attn_seq", "heads", None))
        s = jnp.einsum("bqhd,bshd->bhqs", qi, k) / scale
        s = s.astype(jnp.float32)
        qpos = i * bq + jnp.arange(bq)[:, None]
        ok = jnp.ones((bq, S), bool)
        if causal:
            ok = ok & (kpos <= qpos)
        if window > 0:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhqs,bshd->bqhd", w, v)
        return None, o

    _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, Sp, H, dh)
    return out[:, :S]


# --- decode with KV cache -------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None,
                  dtype=None) -> Dict[str, jnp.ndarray]:
    L = cfg.n_layers if n_layers is None else n_layers
    dt = dtype or cfg.compute_dtype
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def kv_cache_specs() -> Specs:
    return {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None)}


def decode_attention(
    p: Params,
    x: jnp.ndarray,                 # (B, 1, D) current token activations
    layer_cache: Dict[str, jnp.ndarray],   # k/v (B, S_max, K, dh) this layer
    pos: jnp.ndarray,               # scalar int32: write/read position
    cfg: ModelConfig,
    *,
    window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode: update cache at ``pos``, attend over prefix."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    if cfg.pos_emb == "rope":
        pos_arr = jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)
    # cache write as an ELEMENTWISE select on the seq axis: unlike
    # dynamic-update-slice at a traced position, this partitions trivially
    # when kv_seq is sharded over the mesh (GSPMD was measured to all-gather
    # the whole cache for the d-u-s form: +7.5 GiB/dev on qwen2-7b decode).
    S = layer_cache["k"].shape[1]
    at_pos = (jnp.arange(S) == pos)[None, :, None, None]
    k = jnp.where(at_pos, k_new, layer_cache["k"])
    v = jnp.where(at_pos, v_new, layer_cache["v"])
    kpos = jnp.arange(S)
    ok = kpos <= pos
    if window > 0:
        ok = ok & (kpos > pos - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    out = _sdpa_grouped(q, k, v, bias, cfg)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(dt)
    return out, {"k": k, "v": v}


def _sdpa_grouped(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  bias: Optional[jnp.ndarray], cfg: ModelConfig
                  ) -> jnp.ndarray:
    """Decode-path attention in grouped (K, G) form — NO kv repetition.

    The repeat-based ``_sdpa`` is right for training (keeps the head axis
    intact for TP score sharding), but at decode the KV cache is SEQ-sharded
    and repeating K/V up to H heads makes GSPMD reconcile head-sharding vs
    seq-sharding by all-gathering the expanded cache (measured 64 GB/step on
    minitron-8b decode_32k).  Contracting against the grouped cache keeps
    every score/output computation local to the seq shards; only the tiny
    (B, 1, H, dh) query is replicated.
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    if bias is not None:
        scores = scores + bias
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, dh)
