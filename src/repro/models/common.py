"""Shared model substrate: config, initializers, norms, rope, embeddings.

Pure-JAX functional modules: ``init_*(key, cfg) -> params`` (nested dict
pytrees, fp32 master weights) and ``apply``-style functions taking params.
Compute runs in ``cfg.compute_dtype`` (bf16 by default — matches the TPU v5e
MXU the dry-run models); parameters stay fp32 and are cast at use.

Every ``init_*`` has a ``*_specs`` twin returning the same pytree structure
with *logical axis names* per dimension; ``repro.distributed.sharding`` maps
logical axes -> mesh axes to build NamedShardings.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, Params, Specs  # noqa: F401
# (re-exported: every model module imports ModelConfig from here)


# --- initializers -------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def zeros(shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones(shape, dtype)


# --- norms --------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": ones((d,))}
    if cfg.norm == "layernorm":
        p["bias"] = zeros((d,))
    return p


def norm_specs(cfg: ModelConfig) -> Specs:
    p = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        p["bias"] = ("embed",)
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(cfg.compute_dtype)


def rms_norm_head(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head QK-norm (Qwen3): normalize over the last (head) dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --- rotary position embeddings -----------------------------------------------

def rope_freqs(dh: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, n_heads, dh); positions: broadcastable to (..., seq)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                 # (..., seq, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal table (seq, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / (d // 2 - 1 if d > 2 else 1)))
    tab = jnp.zeros((seq, d), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab


# --- activations ----------------------------------------------------------------

def activation(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu2":  # squared ReLU (Primer / Nemotron family)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


# --- losses ---------------------------------------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE; logits (..., V) any dtype -> fp32 loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# --- param counting ---------------------------------------------------------------

def count_params(params: Params) -> int:
    return int(sum(p.size for p in jax.tree_util.tree_leaves(params)))
