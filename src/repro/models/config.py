"""ModelConfig — the jax-free architecture description.

Split out of ``models/common.py`` so that purely analytic consumers (the
Ridgeline sweep engine, the parallelism planner CLI, the collective cost
models) can load every config in ``repro.configs`` without importing jax.
``ml_dtypes.bfloat16`` *is* ``jnp.bfloat16`` as far as ``np.dtype`` equality
(and hence every ``astype``/array constructor) is concerned, so the dtype
defaults behave identically on the jax paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import ml_dtypes
import numpy as np

Params = Any  # nested dict pytree of jnp arrays
Specs = Any   # matching pytree of tuples of logical axis names (or None)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers the whole LM family (dense/MoE/SSM/hybrid/enc-dec)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_emb: str = "rope"            # rope | learned | none
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()   # full-attn layers when windowed
    causal: bool = True
    # ffn
    ffn_activation: str = "swiglu"   # swiglu | gelu
    ffn_bias: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    pad_experts_to: int = 0          # pad expert count for EP divisibility
                                     # (dead experts are never routed to)
    moe_group_tokens: int = 2048     # GShard dispatch-group size: dispatch
                                     # HBM traffic scales ~T·Tg·k·cf
    # ssm / hybrid
    ssm_state: int = 0               # per-head SSM state size
    ssm_conv: int = 4                # short conv width
    slstm_layers: Tuple[int, ...] = ()   # xLSTM: which blocks are sLSTM
    ssm_chunk: int = 256             # chunked-scan block length
    # enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed encoder context (audio frames)
    # vlm
    visual_tokens: int = 0
    visual_width: int = 0            # ViT stub embedding width
    # mlp (DLRM case study)
    mlp_widths: Tuple[int, ...] = ()
    # numerics / lowering
    compute_dtype: Any = ml_dtypes.bfloat16
    param_dtype: Any = np.float32
    scan_layers: bool = True
    remat: str = "none"              # none | dots | full
    use_flash: bool = False          # Pallas flash-attention path
    use_pallas_matmul: bool = False  # Pallas blocked-matmul path (MLP)
    attn_impl: str = "dense"         # dense | chunked (O(S·bq) XLA blockwise)
    attn_block_q: int = 1024         # q-block for chunked attention
    sp_outputs: bool = False         # Megatron-SP: constrain row-parallel
                                     # block outputs to seq-sharded, turning
                                     # their all-reduce into reduce-scatter
    max_seq_len: int = 8192          # learned-pos table size; rope is unbounded

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.dh

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
