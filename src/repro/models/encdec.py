"""Whisper-style encoder-decoder [arXiv:2212.04356].

Encoder: conv frontend is a STUB per the brief — ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D) directly (the two stride-2 convs
that produce them are not part of the assigned backbone).  Encoder blocks are
bidirectional MHA + GELU MLP with pre-LayerNorm; sinusoidal positions.

Decoder: causal self-attention + cross-attention over encoder output +
GELU MLP; learned positions; embedding tied with the LM head (as Whisper).

Whisper-tiny is MHA (6 heads == 6 kv heads), biases on (Whisper uses biased
projections), LayerNorm not RMSNorm — all driven by the config.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models.common import (ModelConfig, Params, Specs, apply_norm,
                                 embed_init, init_norm, norm_specs,
                                 sinusoidal_positions)


def init_encdec(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"attn_norm": init_norm(cfg),
                "attn": attn_mod.init_attention(k1, cfg),
                "ffn_norm": init_norm(cfg),
                "ffn": ffn_mod.init_ffn(k2, cfg)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self_norm": init_norm(cfg),
                "self_attn": attn_mod.init_attention(k1, cfg),
                "cross_norm": init_norm(cfg),
                "cross_attn": attn_mod.init_attention(k2, cfg),
                "ffn_norm": init_norm(cfg),
                "ffn": ffn_mod.init_ffn(k3, cfg)}

    enc_keys = jnp.stack(jax.random.split(ks[0], cfg.encoder_layers))
    dec_keys = jnp.stack(jax.random.split(ks[1], cfg.n_layers))
    return {
        "enc_blocks": jax.vmap(enc_block)(enc_keys),
        "enc_norm": init_norm(cfg),
        "dec_embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model),
        "dec_pos": embed_init(ks[3], cfg.max_seq_len, cfg.d_model),
        "dec_blocks": jax.vmap(dec_block)(dec_keys),
        "dec_norm": init_norm(cfg),
    }


def encdec_specs(cfg: ModelConfig) -> Specs:
    stack = lambda specs: jax.tree.map(
        lambda axes: ("layers",) + tuple(axes), specs,
        is_leaf=lambda x: isinstance(x, tuple))
    enc_blk = {"attn_norm": norm_specs(cfg),
               "attn": attn_mod.attention_specs(cfg),
               "ffn_norm": norm_specs(cfg), "ffn": ffn_mod.ffn_specs(cfg)}
    dec_blk = {"self_norm": norm_specs(cfg),
               "self_attn": attn_mod.attention_specs(cfg),
               "cross_norm": norm_specs(cfg),
               "cross_attn": attn_mod.attention_specs(cfg),
               "ffn_norm": norm_specs(cfg), "ffn": ffn_mod.ffn_specs(cfg)}
    return {
        "enc_blocks": stack(enc_blk), "enc_norm": norm_specs(cfg),
        "dec_embed": ("vocab", "embed"), "dec_pos": (None, "embed"),
        "dec_blocks": stack(dec_blk), "dec_norm": norm_specs(cfg),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames (B, T_enc, D) stub embeddings -> encoder states (B, T_enc, D)."""
    dt = cfg.compute_dtype
    T = frames.shape[1]
    x = frames.astype(dt) + sinusoidal_positions(T, cfg.d_model).astype(dt)
    x = shard_hint(x, ("batch", "seq", "embed"))

    def body(x, blk):
        h = apply_norm(blk["attn_norm"], x, cfg)
        x = x + attn_mod.apply_attention(blk["attn"], h, cfg, causal=False)
        h = apply_norm(blk["ffn_norm"], x, cfg)
        x = x + ffn_mod.apply_ffn(blk["ffn"], h, cfg)
        return shard_hint(x, ("batch", "seq", "embed")), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg)


def forward(params: Params, tokens: jnp.ndarray, frames: jnp.ndarray,
            cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(tokens (B,S), frames (B,T_enc,D)) -> (logits (B,S,V), aux=0)."""
    dt = cfg.compute_dtype
    enc = encode(params, frames, cfg)
    S = tokens.shape[1]
    x = jnp.take(params["dec_embed"].astype(dt), tokens, axis=0)
    x = x + params["dec_pos"][:S].astype(dt)
    x = shard_hint(x, ("batch", "seq", "embed"))

    def body(x, blk):
        h = apply_norm(blk["self_norm"], x, cfg)
        x = x + attn_mod.apply_attention(blk["self_attn"], h, cfg, causal=True)
        h = apply_norm(blk["cross_norm"], x, cfg)
        x = x + attn_mod.apply_attention(blk["cross_attn"], h, cfg,
                                         kv_src=enc, causal=False)
        h = apply_norm(blk["ffn_norm"], x, cfg)
        x = x + ffn_mod.apply_ffn(blk["ffn"], h, cfg)
        return shard_hint(x, ("batch", "seq", "embed")), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = x @ params["dec_embed"].T.astype(dt)
    return shard_hint(logits, ("batch", "seq", "vocab")), jnp.float32(0.0)


# --- decode -------------------------------------------------------------------------

def init_encdec_cache(params: Params, frames: jnp.ndarray, batch: int,
                      max_len: int, cfg: ModelConfig) -> Dict[str, Any]:
    """Prefill: run the encoder once, precompute per-layer cross K/V."""
    dt = cfg.compute_dtype
    enc = encode(params, frames, cfg)

    def cross_kv(blk):
        p = blk["cross_attn"]
        Tk = enc.shape[1]
        k = (enc @ p["wk"].astype(dt))
        v = (enc @ p["wv"].astype(dt))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        k = k.reshape(batch, Tk, cfg.n_kv_heads, cfg.dh)
        v = v.reshape(batch, Tk, cfg.n_kv_heads, cfg.dh)
        return k, v

    # vmap over the stacked layer axis of dec_blocks -> (L, B, Tk, K, dh)
    ck, cv = jax.vmap(cross_kv)(params["dec_blocks"])
    return {"self": attn_mod.init_kv_cache(cfg, batch, max_len),
            "cross_k": ck, "cross_v": cv}


def decode_step(params: Params, tokens: jnp.ndarray, cache: Dict[str, Any],
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    dt = cfg.compute_dtype
    B = tokens.shape[0]
    x = jnp.take(params["dec_embed"].astype(dt), tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(dt)

    def body(x, inp):
        blk, krow, vrow, ck, cv = inp
        h = apply_norm(blk["self_norm"], x, cfg)
        a, kv = attn_mod.decode_attention(blk["self_attn"], h,
                                          {"k": krow, "v": vrow}, pos, cfg)
        x = x + a
        h = apply_norm(blk["cross_norm"], x, cfg)
        q, _, _ = attn_mod._project_qkv(blk["cross_attn"], h, h, cfg)
        out = attn_mod._sdpa_grouped(q, ck, cv, None, cfg)
        x = x + out.reshape(B, 1, cfg.q_dim) @ blk["cross_attn"]["wo"].astype(dt)
        h = apply_norm(blk["ffn_norm"], x, cfg)
        x = x + ffn_mod.apply_ffn(blk["ffn"], h, cfg)
        return x, (kv["k"], kv["v"])

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"]["k"],
                  cache["self"]["v"], cache["cross_k"], cache["cross_v"]))
    new_cache = {"self": {"k": k, "v": v},
                 "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = x @ params["dec_embed"].T.astype(dt)
    return logits, new_cache
