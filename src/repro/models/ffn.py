"""Dense feed-forward blocks: SwiGLU (llama/qwen) and GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, Specs, activation,
                                 dense_init, zeros)


def init_ffn(key, cfg: ModelConfig, d_ff: int = 0) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_activation == "swiglu":
        p = {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model),
        }
    else:
        p = {
            "w_up": dense_init(ks[0], cfg.d_model, d_ff),
            "w_down": dense_init(ks[1], d_ff, cfg.d_model),
        }
    if cfg.ffn_bias:
        p["b_up"] = zeros((d_ff,))
        p["b_down"] = zeros((cfg.d_model,))
    return p


def ffn_specs(cfg: ModelConfig) -> Specs:
    if cfg.ffn_activation == "swiglu":
        p = {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
             "w_down": ("ffn", "embed")}
    else:
        p = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
    if cfg.ffn_bias:
        p["b_up"] = ("ffn",)
        p["b_down"] = ("embed",)
    return p


def apply_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.compute_dtype
    x = x.astype(dt)
    if cfg.use_pallas_matmul:
        from repro.kernels import ops as kops
        matmul = kops.matmul
    else:
        matmul = lambda a, b, bias=None, act=None: _mm(a, b, bias, act)
    if cfg.ffn_activation == "swiglu":
        g = matmul(x, p["w_gate"].astype(dt), act="silu")
        u = matmul(x, p["w_up"].astype(dt),
                   bias=p.get("b_up", None))
        h = g * u
    else:
        h = matmul(x, p["w_up"].astype(dt), bias=p.get("b_up"),
                   act=cfg.ffn_activation)
    return matmul(h, p["w_down"].astype(dt), bias=p.get("b_down"))


def _mm(a, b, bias=None, act=None):
    y = a @ b
    if bias is not None:
        y = y + bias.astype(y.dtype)
    if act is not None:
        y = activation(act, y)
    return y
