"""Hymba hybrid blocks [arXiv:2411.13676]: parallel attention ∥ Mamba heads.

Each block: x -> pre-norm -> {GQA attention, Mamba heads} on the same input,
outputs per-branch RMS-normalized, combined with learnable per-channel scales
β (the paper's fusion), then the usual SwiGLU FFN sub-block.

Attention is sliding-window (``cfg.sliding_window``) in every layer except
``cfg.global_attn_layers`` (paper: first/middle/last stay global) — this plus
the constant-size SSM state is what makes ``long_500k`` decode feasible.
The per-layer window is carried through the layer scan as data (a traced
scalar: S+1 ⇒ effectively global), so the stacked-params scan stays
homogeneous.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models.attention import (NEG_INF, _project_qkv, _sdpa,
                                    _sdpa_grouped)
from repro.models.common import (ModelConfig, Params, Specs, apply_norm,
                                 apply_rope, init_norm, norm_specs, ones)


def init_hymba_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "pre_norm": init_norm(cfg),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "mamba": mamba_mod.init_mamba(ks[1], cfg),
        "attn_out_norm": init_norm(cfg),
        "mamba_out_norm": init_norm(cfg),
        "beta_attn": ones((cfg.d_model,)),
        "beta_mamba": ones((cfg.d_model,)),
        "ffn_norm": init_norm(cfg),
        "ffn": ffn_mod.init_ffn(ks[2], cfg),
    }


def hymba_block_specs(cfg: ModelConfig) -> Specs:
    return {
        "pre_norm": norm_specs(cfg),
        "attn": attn_mod.attention_specs(cfg),
        "mamba": mamba_mod.mamba_specs(cfg),
        "attn_out_norm": norm_specs(cfg),
        "mamba_out_norm": norm_specs(cfg),
        "beta_attn": ("embed",),
        "beta_mamba": ("embed",),
        "ffn_norm": norm_specs(cfg),
        "ffn": ffn_mod.ffn_specs(cfg),
    }


def _windowed_attention(p, h, cfg: ModelConfig, window) -> jnp.ndarray:
    """Full-seq attention with a *traced* window size (for the layer scan)."""
    dt = cfg.compute_dtype
    B, S, _ = h.shape
    q, k, v = _project_qkv(p, h, h, cfg)
    if cfg.pos_emb == "rope":
        pos = jnp.arange(S)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    from repro.distributed.sharding import shard_hint
    q = shard_hint(q, ("batch", "attn_seq", "heads", None))
    k = shard_hint(k, ("batch", "attn_seq", "kv_heads", None))
    v = shard_hint(v, ("batch", "attn_seq", "kv_heads", None))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = (kpos <= qpos) & (kpos > qpos - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    out = _sdpa(q, k, v, bias, cfg)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(dt)


def apply_hymba_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                      window) -> jnp.ndarray:
    dt = cfg.compute_dtype
    h = apply_norm(p["pre_norm"], x, cfg)
    a = _windowed_attention(p["attn"], h, cfg, window)
    m = mamba_mod.apply_mamba(p["mamba"], h, cfg)
    fused = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg) * p["beta_attn"].astype(dt)
                   + apply_norm(p["mamba_out_norm"], m, cfg) * p["beta_mamba"].astype(dt))
    x = x + fused
    x = x + ffn_mod.apply_ffn(p["ffn"], apply_norm(p["ffn_norm"], x, cfg), cfg)
    return x


def layer_windows(cfg: ModelConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer attention window array (traced through the scan).

    Global layers get window = seq_len (sees everything); local layers get
    ``cfg.sliding_window``.
    """
    w = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    for i in cfg.global_attn_layers:
        w = w.at[i].set(seq_len)
    return w


# --- decode ----------------------------------------------------------------------
#
# Decode is *unrolled* over layers (training scans): the KV memory bound of
# Hymba comes from local layers holding only an O(window) ring buffer while
# just len(global_attn_layers) layers keep full-length KV.  A homogeneous
# layer scan would force the full buffer on every layer (O(L·S) — 21 GiB at
# 500k for hymba-1.5b); unrolling keeps it at O(n_global·S + L·W).

def init_hymba_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    W = min(cfg.sliding_window, max_len)
    dt = cfg.compute_dtype
    cache: Dict = {}
    M, n = mamba_mod.init_mamba_state(cfg, batch)
    for i in range(cfg.n_layers):
        S = max_len if i in cfg.global_attn_layers else W
        cache[f"layer{i}"] = {
            "k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.dh), dt),
            "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.dh), dt),
            "mM": M, "mn": n,
        }
    return cache


def decode_hymba_block(p: Params, x: jnp.ndarray, cache_row: Dict,
                       pos: jnp.ndarray, cfg: ModelConfig,
                       is_global: bool) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode for one layer (static local/global branch).

    Local layers write a ring buffer slot ``pos % W`` and mask by slot age;
    global layers write at ``pos`` into their full-length buffer.
    """
    dt = cfg.compute_dtype
    B = x.shape[0]
    h = apply_norm(p["pre_norm"], x, cfg)

    q, k_new, v_new = _project_qkv(p["attn"], h, h, cfg)
    if cfg.pos_emb == "rope":
        pos_arr = jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)

    # elementwise cache write (partitions under kv_seq sharding; see
    # attention.decode_attention)
    S = cache_row["k"].shape[1]
    if is_global:
        slot = pos
        ok = jnp.arange(S) <= pos
    else:
        slot = jnp.mod(pos, S)
        ages = jnp.mod(slot - jnp.arange(S), S)      # age of each ring slot
        ok = ages <= jnp.minimum(pos, S - 1)
    at_slot = (jnp.arange(S) == slot)[None, :, None, None]
    k = jnp.where(at_slot, k_new, cache_row["k"])
    v = jnp.where(at_slot, v_new, cache_row["v"])
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    a = _sdpa_grouped(q, k, v, bias, cfg)
    a = a.reshape(B, 1, cfg.q_dim) @ p["attn"]["wo"].astype(dt)

    m, mstate = mamba_mod.decode_mamba(
        p["mamba"], h, (cache_row["mM"], cache_row["mn"]), cfg)
    fused = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg) * p["beta_attn"].astype(dt)
                   + apply_norm(p["mamba_out_norm"], m, cfg) * p["beta_mamba"].astype(dt))
    x = x + fused
    x = x + ffn_mod.apply_ffn(p["ffn"], apply_norm(p["ffn_norm"], x, cfg), cfg)
    new_row = {"k": k, "v": v, "mM": mstate[0], "mn": mstate[1]}
    return x, new_row
