"""Mamba-style selective-SSM heads (SSD form) for the Hymba hybrid blocks.

Hymba [arXiv:2411.13676] pairs attention heads with Mamba heads in parallel
inside each block.  We realize the Mamba heads in the Mamba-2/SSD per-head
scalar-decay form (see DESIGN.md: MXU-friendly chunked GEMMs instead of the
per-channel selective scan, which is a serial VPU pattern on TPU):

    h_t = a_t h_{t-1} + Δ_t B_t x_t,   y_t = C_t h_t + D ⊙ x_t
    a_t = exp(-Δ_t · exp(A_log)),      Δ_t = softplus(w_dt · u_t + b_dt)

Head layout mirrors the attention side: ``n_heads`` heads of ``dh`` channels,
state size ``cfg.ssm_state`` per head.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Params, Specs, dense_init, zeros
from repro.models.ssd import (chunked_linear_recurrence, decode_linear_step,
                              init_linear_state)


def init_mamba(key, cfg: ModelConfig) -> Params:
    D, H, dh, N = cfg.d_model, cfg.n_heads, cfg.dh, cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_v": dense_init(ks[0], D, H * dh),
        "w_B": dense_init(ks[1], D, H * N),
        "w_C": dense_init(ks[2], D, H * N),
        "w_dt": dense_init(ks[3], D, H),
        "b_dt": zeros((H,)),
        "A_log": jnp.zeros((H,), jnp.float32),     # a = exp(-dt * exp(A_log))
        "D_skip": jnp.ones((H, dh), jnp.float32),
        "w_out": dense_init(ks[4], H * dh, D),
    }


def mamba_specs(cfg: ModelConfig) -> Specs:
    return {
        "w_v": ("embed", "q_proj"), "w_B": ("embed", "kv_proj"),
        "w_C": ("embed", "kv_proj"), "w_dt": ("embed", None),
        "b_dt": (None,), "A_log": (None,), "D_skip": ("heads", None),
        "w_out": ("q_proj", "embed"),
    }


def _mamba_proj(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    dt_ = cfg.compute_dtype
    B, S, D = x.shape
    H, dh, N = cfg.n_heads, cfg.dh, cfg.ssm_state
    from repro.distributed.sharding import shard_hint
    v = shard_hint((x @ p["w_v"].astype(dt_)).reshape(B, S, H, dh),
                   ("batch", "attn_seq", "heads", None))
    bk = shard_hint((x @ p["w_B"].astype(dt_)).reshape(B, S, H, N),
                    ("batch", "attn_seq", "heads", None))
    cq = shard_hint((x @ p["w_C"].astype(dt_)).reshape(B, S, H, N),
                    ("batch", "attn_seq", "heads", None))
    delta = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["b_dt"])  # (B,S,H)
    log_a = -delta * jnp.exp(p["A_log"])                 # (B,S,H) <= 0
    v_in = v * delta[..., None].astype(dt_)              # fold Δ into v
    return v, v_in, bk, cq, log_a


def apply_mamba(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt_ = cfg.compute_dtype
    x = x.astype(dt_)
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.dh
    v, v_in, bk, cq, log_a = _mamba_proj(p, x, cfg)
    chunk = min(cfg.ssm_chunk, S)
    y, _ = chunked_linear_recurrence(cq, bk, v_in, log_a, chunk=chunk)
    y = y + v * p["D_skip"].astype(dt_)
    return y.reshape(B, S, H * dh) @ p["w_out"].astype(dt_)


def init_mamba_state(cfg: ModelConfig, batch: int):
    return init_linear_state(batch, cfg.n_heads, cfg.ssm_state, cfg.dh)


def decode_mamba(p: Params, x: jnp.ndarray, state, cfg: ModelConfig):
    dt_ = cfg.compute_dtype
    x = x.astype(dt_)
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.dh
    v, v_in, bk, cq, log_a = _mamba_proj(p, x, cfg)
    y, state = decode_linear_step(state, cq[:, 0], bk[:, 0], v_in[:, 0],
                                  jnp.exp(log_a[:, 0]))
    y = y + v[:, 0] * p["D_skip"].astype(dt_)
    return y.reshape(B, 1, H * dh) @ p["w_out"].astype(dt_), state
