"""The paper's case study (§III): DLRM-style MLP tower, data-parallel.

A stack of fully-connected layers O_l = f(W_l I_l + b_l) with feature width
4096 (paper Fig. 4), trained data-parallel: each step's gradients are
synchronized with an all-reduce whose wire volume the Ridgeline's B_N term
captures.  The three GEMM phases the paper counts (forward, activation-grad,
weight-grad) all appear in the jitted train step's HLO and are what
``cost_analysis`` reports.

``use_pallas_matmul`` routes the layer GEMMs through the Pallas
fused-bias+ReLU blocked matmul kernel (the compute hotspot this paper's
analysis centers on).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models.common import ModelConfig, Params, Specs, dense_init, zeros


def init_mlp(key, cfg: ModelConfig) -> Params:
    widths = cfg.mlp_widths
    ks = jax.random.split(key, len(widths))
    layers = []
    for i, k in enumerate(ks):
        d_in = widths[i - 1] if i else widths[0]
        layers.append({"w": dense_init(k, d_in, widths[i]),
                       "b": zeros((widths[i],))})
    head_key = jax.random.fold_in(key, 7)
    return {"layers": layers,
            "head": {"w": dense_init(head_key, widths[-1], 1), "b": zeros((1,))}}


def mlp_specs(cfg: ModelConfig) -> Specs:
    # pure data-parallel (the paper's deployment): weights replicated
    layers = [{"w": (None, None), "b": (None,)} for _ in cfg.mlp_widths]
    return {"layers": layers, "head": {"w": (None, None), "b": (None,)}}


def forward(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x (B, d_in) -> logit (B,)."""
    dt = cfg.compute_dtype
    h = x.astype(dt)
    h = shard_hint(h, ("batch", None))
    if cfg.use_pallas_matmul:
        from repro.kernels import ops as kops
        for lyr in params["layers"]:
            h = kops.matmul(h, lyr["w"].astype(dt), bias=lyr["b"].astype(dt),
                            act="relu")
    else:
        for lyr in params["layers"]:
            h = jax.nn.relu(h @ lyr["w"].astype(dt) + lyr["b"].astype(dt))
    logit = h @ params["head"]["w"].astype(dt) + params["head"]["b"].astype(dt)
    return logit[..., 0]


def loss_fn(params: Params, x: jnp.ndarray, y: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Binary cross-entropy (click-through objective of DLRM)."""
    logit = forward(params, x, cfg).astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y
        + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# --- analytic Ridgeline terms (paper §III accounting) ---------------------------

def analytic_work_unit(batch: int, width: int, n_layers: int,
                       dtype_bytes: int = 4) -> Tuple[float, float, float]:
    """(F, B_M, B_N) per step for the paper's MLP accounting.

    F   = 6 * B * W^2 * L      (fwd + act-grad + wgt-grad GEMMs, 2BW^2 each)
    B_M = L * W^2 * dtype_bytes (weights read once per step — the paper's
          Fig. 4a convention that puts the CLX ridge crossing at batch 32)
    B_N = 2 * L * W^2 * dtype_bytes (ring all-reduce wire bytes of the grads)
    """
    F = 6.0 * batch * width * width * n_layers
    B_M = float(n_layers) * width * width * dtype_bytes
    B_N = 2.0 * float(n_layers) * width * width * dtype_bytes
    return F, B_M, B_N
