"""Mixture-of-Experts FFN: top-k routing with capacity-based dense dispatch.

TPU-idiomatic GShard/Switch formulation: token->expert assignment becomes a
dense one-hot dispatch tensor contracted with einsum, so expert compute is a
batched GEMM (E, C, D) x (E, D, F) that shards cleanly over the ``expert``
logical axis (EP over the mesh ``model`` axis).  No torch-style NCCL
emulation: the all-to-all pattern emerges from GSPMD propagation on the
sharded einsum.

Supports the two assigned MoE archs:
  * qwen2-moe-a2.7b  — 60 routed top-4 + 4 shared experts (d_ff 1408)
  * qwen3-moe-30b-a3b — 128 routed top-8, no shared (d_ff 768)
Routing = softmax-then-topk with renormalized gates (Qwen convention), plus
the standard load-balancing auxiliary loss (Switch §4) exposed for training.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Params, Specs, dense_init
from repro.models import ffn as ffn_mod


def _padded_e(cfg: ModelConfig) -> int:
    return max(cfg.n_experts, cfg.pad_experts_to)


def init_moe(key, cfg: ModelConfig) -> Params:
    # optional expert padding: allocating E_pad >= E experts (the extra ones
    # are never routed to) buys EP divisibility on the mesh model axis —
    # e.g. qwen2-moe's 60 experts pad to 64 for a 16-wide axis.  FLOP cost:
    # zero (dispatch one-hots never select them); memory: E_pad/E.
    E, D, F = _padded_e(cfg), cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(D)
    p = {
        # router logits stay at the TRUE expert count (padding experts must
        # never receive routing mass)
        "router": dense_init(ks[0], D, cfg.n_experts),
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * scale,
        "w_up": jax.random.normal(ks[2], (E, D, F)) * scale,
        "w_down": jax.random.normal(ks[3], (E, F, D)) / jnp.sqrt(F),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = ffn_mod.init_ffn(ks[4], cfg, d_ff=shared_ff)
    return p


def moe_specs(cfg: ModelConfig) -> Specs:
    # "experts" is EP (mesh model axis) when the count divides it; otherwise
    # the launcher maps "expert_ffn" to the model axis instead (per-expert
    # hidden TP — 60-expert qwen2-moe vs a 16-wide axis).
    p = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ffn"),
        "w_up": ("experts", "embed", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_mod.ffn_specs(cfg)
    return p


def _capacity(group_tokens: int, cfg: ModelConfig) -> int:
    cap = int(group_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.moe_top_k)


def route(router_logits: jnp.ndarray, cfg: ModelConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (gates (T,k), expert_idx (T,k), aux_loss scalar)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e, with the
    # assignment fraction f_e counting ALL k routed choices (normalized by
    # k so f sums to 1) — the top-1 Switch convention undercounts load for
    # the k=4/8 Qwen routers, leaving k-1 of every token's assignments
    # invisible to the loss
    E, k = cfg.n_experts, cfg.moe_top_k
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1),
                  axis=0) / k                                  # (E,)
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Tokens are flattened and re-grouped into fixed ``cfg.moe_group_tokens``
    windows (GShard §3.2 groups: capacity buffers are sized per group,
    keeping the dispatch tensor linear in total tokens); each group
    dispatches into per-expert capacity buffers
    via one-hot einsum.  Capacity-dropped tokens pass through the residual
    (their expert contribution is zero) — the standard GShard behaviour.
    The group axis carries the ``batch`` logical sharding (DP), the expert
    axis carries ``experts`` (EP over mesh ``model``).
    """
    dt = cfg.compute_dtype
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.moe_top_k
    Tg = min(cfg.moe_group_tokens, T)
    if T % Tg:
        # fall back to one group per sequence for odd smoke-test sizes
        Tg = S if T % S == 0 else T
    G = T // Tg
    C = _capacity(Tg, cfg)
    xt = x.reshape(G, Tg, D).astype(dt)

    E_pad = p["w_gate"].shape[0]
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(dt))
    gates, idx, aux = route(logits.reshape(T, E), cfg)         # (T,k) fp32
    gates = gates.reshape(G, Tg, k)
    idx = idx.reshape(G, Tg, k)

    # position of each (token, choice) inside its expert's capacity buffer,
    # computed per group via masked cumulative sum over the flattened choices
    onehot = jax.nn.one_hot(idx, E_pad, dtype=jnp.float32)     # (G, Tg, k, E_pad)
    flat = onehot.reshape(G, Tg * k, E_pad)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat            # (G, Tg*k, E_pad)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(G, Tg, k)
    keep = (pos < C).astype(jnp.float32)
    gates = gates * keep

    # dispatch/combine tensors (G, Tg, E, C)
    pos_oh = jax.nn.one_hot(pos, C, dtype=dt) * keep[..., None].astype(dt)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot.astype(dt), pos_oh)
    combine = jnp.einsum("gtec,gtk->gtec", dispatch, gates.astype(dt))

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xt)           # (G, E, C, D)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"].astype(dt)))
    u = jnp.einsum("gecd,edf->gecf", xin, p["w_up"].astype(dt))
    h = jnp.einsum("gecf,efd->gecd", g * u, p["w_down"].astype(dt))
    out = jnp.einsum("gtec,gecd->gtd", combine, h)             # (G, Tg, D)

    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + ffn_mod.apply_ffn(p["shared"], x.astype(dt), cfg)
    return out, aux.astype(jnp.float32)
