"""Chunked scalar-decay linear recurrence (SSD form) — shared SSM substrate.

One primitive serves both assigned recurrent families:

  * xLSTM mLSTM blocks  — matrix memory C_t = f_t C_{t-1} + i_t k_t v_t^T,
    y_t = (q_t C_t) / max(|q_t n_t|, 1) with normalizer n_t = f_t n_{t-1} + i_t k_t
  * Hymba mamba heads   — h_t = a_t h_{t-1} + B_t x_t, y_t = C_t h_t
    (Mamba-2/SSD per-head scalar decay; see DESIGN.md §hardware-adaptation for
    why we use the SSD form rather than Mamba-1 per-channel diagonal A: the
    chunked formulation is MXU-friendly — intra-chunk work is dense GEMMs —
    where Mamba-1's per-element selective scan is a VPU-serial pattern.)

Training/prefill use the *chunked* algorithm: O(T·L) memory, intra-chunk
quadratic attention-like GEMMs + an inter-chunk ``lax.scan`` carrying the
(dk × dv) state.  Decode is the exact sequential update on a constant-size
state — this is what makes ``long_500k`` feasible for these families.

Sequence-axis convention: inputs (B, T, H, d); decay is given as
``log_decay`` (B, T, H) with values ≤ 0 (log of a forget factor in (0, 1]).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_linear_recurrence(
    q: jnp.ndarray,           # (B, T, H, dk)
    k: jnp.ndarray,           # (B, T, H, dk)
    v: jnp.ndarray,           # (B, T, H, dv)
    log_decay: jnp.ndarray,   # (B, T, H)
    chunk: int = 256,
    normalize: bool = False,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Return (y (B,T,H,dv), final (M (B,H,dk,dv), n (B,H,dk)))."""
    B, T, H, dk = k.shape
    dv = v.shape[-1]
    L = min(chunk, T)
    while T % L:          # fall back to the largest divisor <= chunk
        L -= 1
    NC = T // L
    f32 = jnp.float32

    def split(x):  # (B, T, H, d) -> (NC, B, L, H, d)
        return jnp.moveaxis(x.reshape(B, NC, L, *x.shape[2:]), 1, 0)

    qc, kc, vc = split(q), split(k), split(v)
    la = jnp.moveaxis(log_decay.reshape(B, NC, L, H), 1, 0).astype(f32)
    cum = jnp.cumsum(la, axis=2)                     # (NC, B, L, H) inclusive
    total = cum[:, :, -1:, :]                        # (NC, B, 1, H)

    # intra-chunk: D_ij = exp(cum_i - cum_j) for j <= i else 0
    idx = jnp.arange(L)
    tri = (idx[:, None] >= idx[None, :])             # (L, L) j <= i
    # scores in compute dtype on the MXU; decay applied in fp32
    scores = jnp.einsum("nbihd,nbjhd->nbhij", qc, kc)      # (NC,B,H,L,L)
    diff = (cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
            - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3))
    # diff: (NC, B, H, L_i, L_j); mask BEFORE exp (future diffs are positive
    # and would overflow)
    diff = jnp.where(tri[None, None, None], diff, -jnp.inf)
    dmat = jnp.exp(diff)
    w = scores.astype(f32) * dmat
    y_intra = jnp.einsum("nbhij,nbjhd->nbihd", w.astype(v.dtype), vc)
    d_intra = None
    if normalize:
        d_intra = jnp.sum(w, axis=-1).transpose(0, 1, 3, 2)  # (NC,B,L,H)

    # per-chunk summaries: M_c = sum_j exp(total - cum_j) k_j v_j^T
    kdecay = jnp.exp(total - cum)                     # (NC, B, L, H)
    kd = kc.astype(f32) * kdecay[..., None]
    M_c = jnp.einsum("nblhd,nblhe->nbhde", kd, vc.astype(f32))  # (NC,B,H,dk,dv)
    n_c = jnp.sum(kd, axis=2) if normalize else None  # (NC, B, H, dk)

    if state is None:
        M0 = jnp.zeros((B, H, dk, dv), f32)
        n0 = jnp.zeros((B, H, dk), f32)
    else:
        M0, n0 = state

    chunk_decay = jnp.exp(total[:, :, 0, :])          # (NC, B, H)

    # Inter-chunk state composition via an ASSOCIATIVE scan over chunk
    # summaries — not a sequential lax.scan: scanning would slice the big
    # per-chunk tensors through scan xs, which defeats GSPMD sharding of the
    # chunk axis (measured 2 GiB/layer -> ~0.3 GiB on xlstm-125m train_4k).
    # Combine law for (a, M) with M_t = a_t M_{t-1} + Mc_t:
    #   (a2, M2) ∘ (a1, M1) = (a1·a2, a2·M1 + M2)
    def combine(left, right):
        a1, m1, n1 = left
        a2, m2, n2 = right
        return (a1 * a2,
                a2[:, :, :, None, None] * m1 + m2,
                a2[:, :, :, None] * n1 + n2)

    n_c_eff = n_c if normalize else jnp.zeros((NC, B, H, 1), f32)
    a_in = jnp.concatenate([jnp.ones((1, B, H), f32), chunk_decay], axis=0)
    M_in = jnp.concatenate([M0[None], M_c], axis=0)
    n_in = jnp.concatenate(
        [(n0 if normalize else jnp.zeros((B, H, 1), f32))[None], n_c_eff],
        axis=0)
    _, M_pref, n_pref = jax.lax.associative_scan(
        combine, (a_in, M_in, n_in), axis=0)
    Mf, nf = M_pref[-1], n_pref[-1]
    M_prev, n_prev = M_pref[:-1], n_pref[:-1]          # exclusive prefixes

    # inter-chunk contribution, fully batched over chunks
    qdec = qc.astype(f32) * jnp.exp(cum)[..., None]    # (NC,B,L,H,dk)
    y = y_intra.astype(f32) + jnp.einsum("nblhd,nbhde->nblhe", qdec, M_prev)
    if normalize:
        denom = d_intra + jnp.einsum("nblhd,nbhd->nblh", qdec, n_prev)
        y = y / jnp.maximum(jnp.abs(denom), 1.0)[..., None]
    y = jnp.moveaxis(y, 0, 1).reshape(B, T, H, dv)
    return y.astype(v.dtype), (Mf, nf if normalize else n0)


def decode_linear_step(
    state: Tuple[jnp.ndarray, jnp.ndarray],   # M (B,H,dk,dv), n (B,H,dk)
    q: jnp.ndarray,                           # (B, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,                           # (B, H, dv)
    decay: jnp.ndarray,                       # (B, H) forget factor in (0,1]
    normalize: bool = False,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Exact sequential update — O(1) per token, constant-size state."""
    M, n = state
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    M = decay[..., None, None] * M + kf[..., :, None] * vf[..., None, :]
    n = decay[..., None] * n + kf
    y = jnp.einsum("bhd,bhde->bhe", qf, M)
    if normalize:
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
        y = y / den[..., None]
    return y.astype(v.dtype), (M, n)


def init_linear_state(batch: int, heads: int, dk: int, dv: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return (jnp.zeros((batch, heads, dk, dv), jnp.float32),
            jnp.zeros((batch, heads, dk), jnp.float32))
