"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory) + sLSTM (scalar).

mLSTM: per-head matrix memory C_t = f_t C_{t-1} + i_t k_t v_t^T with
normalizer n_t and output y_t = (q_t C_t) / max(|q_t n_t|, 1).  Training and
prefill run the chunked SSD form (``repro.models.ssd``); decode runs the exact
sequential update on constant-size state.

Numerics deviation (recorded in DESIGN.md): the paper's exponential input
gate with running-max stabilizer is replaced by bounded sigmoid gates
(i_t = σ(ĩ), f_t = σ(f̃)).  The state equations and normalizer are otherwise
the paper's; this is the standard stabilized variant used when the chunked
parallel form must stay GEMM-shaped (the running-max recursion serializes).

sLSTM: scalar state per head-channel with exponential gating and the paper's
stabilizer state (m_t), run as an exact ``lax.scan`` over time — it has no
parallel form (the paper motivates it exactly so: state mixing forbids it).

Block layout follows the xLSTM paper: pre-LN residual blocks; mLSTM block
has up-projection factor 2 with conv + gated output; sLSTM block is
post-projected with a GeGLU-style FFN factor 4/3.  We keep the projections
but omit the depthwise conv (stub'd as identity) — noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, Specs, dense_init,
                                 ones, zeros)
from repro.models.ssd import (chunked_linear_recurrence, decode_linear_step,
                              init_linear_state)

PROJ_FACTOR = 2  # mLSTM up-projection (paper's p_f = 2)


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = PROJ_FACTOR * cfg.d_model
    H = cfg.n_heads
    return H, d_inner // H


# --- mLSTM ---------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> Params:
    H, dh = _heads(cfg)
    d_inner = H * dh
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], cfg.d_model, d_inner),
        "w_gate": dense_init(ks[1], cfg.d_model, d_inner),
        "wq": dense_init(ks[2], d_inner, d_inner),
        "wk": dense_init(ks[3], d_inner, d_inner),
        "wv": dense_init(ks[4], d_inner, d_inner),
        "w_if": dense_init(ks[5], d_inner, 2 * H),   # input+forget gate logits
        "b_if": zeros((2 * H,)),
        "skip_scale": ones((d_inner,)),
        "w_down": dense_init(ks[6], d_inner, cfg.d_model),
    }


def mlstm_specs(cfg: ModelConfig) -> Specs:
    return {
        "w_up": ("embed", "ffn"), "w_gate": ("embed", "ffn"),
        "wq": ("ffn", "ffn"), "wk": ("ffn", "ffn"), "wv": ("ffn", "ffn"),
        "w_if": ("ffn", None), "b_if": (None,),
        "skip_scale": ("ffn",), "w_down": ("ffn", "embed"),
    }


def _mlstm_qkvg(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    from repro.distributed.sharding import shard_hint
    dt = cfg.compute_dtype
    H, dh = _heads(cfg)
    B, S, _ = x.shape
    u = shard_hint(x @ p["w_up"].astype(dt), ("batch", "seq", "ffn"))
    z = jax.nn.silu(
        shard_hint(x @ p["w_gate"].astype(dt), ("batch", "seq", "ffn")))
    q = (u @ p["wq"].astype(dt)).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(dt)
    k = (u @ p["wk"].astype(dt)).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(dt)
    v = (u @ p["wv"].astype(dt)).reshape(B, S, H, dh)
    gif = (u @ p["w_if"].astype(dt) + p["b_if"].astype(dt)).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gif[..., :H])              # (B,S,H)
    log_f = jax.nn.log_sigmoid(gif[..., H:])           # (B,S,H), <= 0
    return u, z, q, k * i_gate[..., None].astype(dt), v, log_f


def apply_mlstm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.compute_dtype
    x = x.astype(dt)
    B, S, _ = x.shape
    H, dh = _heads(cfg)
    u, z, q, k, v, log_f = _mlstm_qkvg(p, x, cfg)
    chunk = min(cfg.ssm_chunk, S)
    y, _ = chunked_linear_recurrence(q, k, v, log_f, chunk=chunk,
                                     normalize=True)
    y = y.reshape(B, S, H * dh) + u * p["skip_scale"].astype(dt)
    return (y * z) @ p["w_down"].astype(dt)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H, dh = _heads(cfg)
    return init_linear_state(batch, H, dh, dh)


def decode_mlstm(p: Params, x: jnp.ndarray, state, cfg: ModelConfig):
    """x: (B, 1, D) -> (y (B,1,D), new state)."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    B = x.shape[0]
    H, dh = _heads(cfg)
    u, z, q, k, v, log_f = _mlstm_qkvg(p, x, cfg)
    y, state = decode_linear_step(
        state, q[:, 0], k[:, 0], v[:, 0], jnp.exp(log_f[:, 0]),
        normalize=True)
    y = y.reshape(B, 1, H * dh) + u * p["skip_scale"].astype(dt)
    return (y * z) @ p["w_down"].astype(dt), state


# --- sLSTM ---------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        # recurrent weights are per-head block-diagonal in the paper; we use
        # per-channel (diagonal) recurrence — the head-mixing happens in the
        # post-FFN.  4 gates: i, f, z (cell input), o.
        "w_x": dense_init(ks[0], D, 4 * D),
        "r_diag": zeros((4, D)),          # diagonal recurrent weights
        "b": zeros((4 * D,)),
        "w_ffn_up": dense_init(ks[1], D, (4 * D) // 3 * 2),
        "w_ffn_down": dense_init(ks[2], (4 * D) // 3, D),
    }


def slstm_specs(cfg: ModelConfig) -> Specs:
    return {"w_x": ("embed", None), "r_diag": (None, "embed"), "b": (None,),
            "w_ffn_up": ("embed", "ffn"), "w_ffn_down": ("ffn", "embed")}


def init_slstm_state(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 10.0}


def _slstm_cell(p, state, xw, cfg: ModelConfig):
    """One exact sLSTM step with exponential gating + stabilizer (paper eq. 9)."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    D = cfg.d_model
    r = p["r_diag"].astype(jnp.float32)
    gates = xw.astype(jnp.float32) + jnp.concatenate(
        [h * r[0], h * r[1], h * r[2], h * r[3]], axis=-1) + p["b"].astype(jnp.float32)
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)                  # stabilizer state
    i = jnp.exp(gi - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence sLSTM: exact scan over time + GeGLU post-FFN.

    The recurrence is inherently sequential (the paper motivates sLSTM so);
    memory is bounded by a two-level scan: an outer scan over time chunks
    whose body is rematerialized — backward saves only one carry per chunk
    and recomputes the ≤``ssm_chunk`` inner steps on the fly.
    """
    dt = cfg.compute_dtype
    B, S, D = x.shape
    L = min(cfg.ssm_chunk, S)
    NC = S // L if S % L == 0 else 1
    L = S // NC
    xc = jnp.moveaxis(x.astype(dt).reshape(B, NC, L, D), 1, 0)  # (NC,B,L,D)
    state0 = init_slstm_state(cfg, B)

    def chunk_body(st, x_chunk):                       # x_chunk (B,L,D)
        xw = x_chunk @ p["w_x"].astype(dt)             # (B,L,4D)

        def step(st, xw_t):
            st = _slstm_cell(p, st, xw_t, cfg)
            return st, st["h"]

        st, hs = jax.lax.scan(step, st, jnp.moveaxis(xw, 1, 0))
        return st, jnp.moveaxis(hs, 0, 1)              # (B,L,D)

    _, hs = jax.lax.scan(jax.checkpoint(chunk_body), state0, xc)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(dt)
    up = h @ p["w_ffn_up"].astype(dt)
    a, b = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * b) @ p["w_ffn_down"].astype(dt)


def decode_slstm(p: Params, x: jnp.ndarray, state, cfg: ModelConfig):
    dt = cfg.compute_dtype
    xw = x[:, 0].astype(dt) @ p["w_x"].astype(dt)
    state = _slstm_cell(p, state, xw, cfg)
    h = state["h"][:, None, :].astype(dt)
    up = h @ p["w_ffn_up"].astype(dt)
    a, b = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * b) @ p["w_ffn_down"].astype(dt), state
