"""Decoder-only LM assembling the block families, with scan-over-layers.

Families:
  dense  — GQA attention + SwiGLU FFN (llama/qwen style)
  moe    — GQA attention + top-k MoE FFN (shared experts optional)
  hybrid — Hymba parallel attention ∥ mamba blocks
  ssm    — xLSTM (mLSTM blocks + sLSTM at cfg.slstm_layers), unrolled

Deep homogeneous stacks scan over stacked per-layer params (O(1) HLO size —
this is what keeps 512-device dry-run compiles tractable and is also the
production layout).  xLSTM is shallow and heterogeneous -> unrolled.

``forward`` returns (logits, aux) where aux is the MoE load-balance loss
(0 for non-MoE).  ``decode_step`` performs one-token decode against the
cache pytree built by ``init_cache``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import hybrid as hybrid_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ModelConfig, Params, Specs, apply_norm,
                                 embed_init, init_norm, norm_specs,
                                 dense_init)


# --- block init/specs -------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    if cfg.family == "hybrid":
        return hybrid_mod.init_hymba_block(key, cfg)
    p = {
        "attn_norm": init_norm(cfg),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "ffn_norm": init_norm(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"] = ffn_mod.init_ffn(ks[1], cfg)
    return p


def block_specs(cfg: ModelConfig) -> Specs:
    if cfg.family == "hybrid":
        return hybrid_mod.hymba_block_specs(cfg)
    p = {
        "attn_norm": norm_specs(cfg),
        "attn": attn_mod.attention_specs(cfg),
        "ffn_norm": norm_specs(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_specs(cfg)
    else:
        p["ffn"] = ffn_mod.ffn_specs(cfg)
    return p


def init_xlstm_block(key, cfg: ModelConfig, layer: int) -> Params:
    if layer in cfg.slstm_layers:
        return {"norm": init_norm(cfg),
                "slstm": ssm_mod.init_slstm(key, cfg)}
    return {"norm": init_norm(cfg), "mlstm": ssm_mod.init_mlstm(key, cfg)}


def xlstm_block_specs(cfg: ModelConfig, layer: int) -> Specs:
    if layer in cfg.slstm_layers:
        return {"norm": norm_specs(cfg), "slstm": ssm_mod.slstm_specs(cfg)}
    return {"norm": norm_specs(cfg), "mlstm": ssm_mod.mlstm_specs(cfg)}


# --- model init/specs ----------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    p: Dict[str, Any] = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model)}
    if cfg.family == "ssm":
        p["blocks"] = [init_xlstm_block(ks[1 + i], cfg, i)
                       for i in range(cfg.n_layers)]
    elif cfg.scan_layers:
        blk_keys = jnp.stack(ks[1:1 + cfg.n_layers])
        p["blocks"] = jax.vmap(lambda k: init_block(k, cfg))(blk_keys)
    else:
        p["blocks"] = [init_block(ks[1 + i], cfg) for i in range(cfg.n_layers)]
    p["final_norm"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[-1], cfg.d_model, cfg.vocab_size)
    if cfg.pos_emb == "learned":
        p["pos_embed"] = embed_init(ks[-2], cfg.max_seq_len, cfg.d_model)
    return p


def lm_specs(cfg: ModelConfig) -> Specs:
    p: Dict[str, Any] = {"embed": ("vocab", "embed")}
    if cfg.family == "ssm":
        p["blocks"] = [xlstm_block_specs(cfg, i) for i in range(cfg.n_layers)]
    else:
        blk = block_specs(cfg)
        if cfg.scan_layers:
            blk = jax.tree.map(lambda axes: ("layers",) + tuple(axes), blk,
                               is_leaf=lambda x: isinstance(x, tuple))
        p["blocks"] = blk if cfg.scan_layers else [blk] * cfg.n_layers
    p["final_norm"] = norm_specs(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    if cfg.pos_emb == "learned":
        p["pos_embed"] = (None, "embed")
    return p


# --- forward (train / prefill) ----------------------------------------------------------

def _embed(params: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if cfg.pos_emb == "learned":
        S = tokens.shape[1]
        x = x + params["pos_embed"][:S].astype(dt)
    return x


def _apply_dense_block(blk: Params, x: jnp.ndarray, cfg: ModelConfig
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = apply_norm(blk["attn_norm"], x, cfg)
    a = attn_mod.apply_attention(blk["attn"], h, cfg,
                                 window=cfg.sliding_window)
    if cfg.sp_outputs:
        # Megatron-SP: constrain the row-parallel sublayer OUTPUT (a partial
        # sum over the model axis) to seq-sharded before the residual add —
        # GSPMD then lowers the sync as reduce-scatter (wire /2 vs the
        # all-reduce it otherwise inserts to make the output replicated).
        a = shard_hint(a, ("batch", "seq", "embed"))
    x = x + a
    x = shard_hint(x, ("batch", "seq", "embed"))
    h = apply_norm(blk["ffn_norm"], x, cfg)
    if "moe" in blk:
        out, aux = moe_mod.apply_moe(blk["moe"], h, cfg)
    else:
        out, aux = ffn_mod.apply_ffn(blk["ffn"], h, cfg), jnp.float32(0.0)
    if cfg.sp_outputs:
        out = shard_hint(out, ("batch", "seq", "embed"))
    x = shard_hint(x + out, ("batch", "seq", "embed"))
    return x, aux


def _apply_xlstm_block(blk: Params, x: jnp.ndarray, cfg: ModelConfig
                       ) -> jnp.ndarray:
    h = apply_norm(blk["norm"], x, cfg)
    if "slstm" in blk:
        return x + ssm_mod.apply_slstm(blk["slstm"], h, cfg)
    return x + ssm_mod.apply_mlstm(blk["mlstm"], h, cfg)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return fn


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) int32 -> (logits (B, S, V), aux scalar)."""
    x = _embed(params, tokens, cfg)
    x = shard_hint(x, ("batch", "seq", "embed"))
    aux = jnp.float32(0.0)

    if cfg.family == "ssm":
        for blk in params["blocks"]:
            x = _maybe_remat(
                lambda c, b: _apply_xlstm_block(b, c, cfg), cfg)(x, blk)
            x = shard_hint(x, ("batch", "seq", "embed"))
    elif cfg.family == "hybrid":
        S = tokens.shape[1]
        windows = hybrid_mod.layer_windows(cfg, S)

        def hybrid_body(carry, inp):
            blk, w = inp
            return _maybe_remat(
                lambda c, b: hybrid_mod.apply_hymba_block(b, c, cfg, w),
                cfg)(carry, blk), None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(hybrid_body, x, (params["blocks"], windows))
        else:
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"]) \
                    if not isinstance(params["blocks"], list) else params["blocks"][i]
                x = hybrid_mod.apply_hymba_block(blk, x, cfg, windows[i])
    else:
        def body(carry, blk):
            x, aux = carry
            fn = _maybe_remat(
                lambda c, b: _apply_dense_block(b, c, cfg), cfg)
            x, a = fn(x, blk)
            return (x, aux + a), None

        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
        else:
            for blk in params["blocks"]:
                x, a = _maybe_remat(
                    lambda c, b: _apply_dense_block(b, c, cfg), cfg)(x, blk)
                aux = aux + a

    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(cfg.compute_dtype)
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    return logits, aux


# --- decode ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    if cfg.family == "ssm":
        cache: Dict[str, Any] = {}
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                cache[f"layer{i}"] = ssm_mod.init_slstm_state(cfg, batch)
            else:
                M, n = ssm_mod.init_mlstm_state(cfg, batch)
                cache[f"layer{i}"] = {"M": M, "n": n}
        return cache
    if cfg.family == "hybrid":
        return hybrid_mod.init_hymba_cache(cfg, batch, max_len)
    return attn_mod.init_kv_cache(cfg, batch, max_len)


def decode_step(params: Params, tokens: jnp.ndarray, cache: Dict[str, Any],
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tokens (B, 1) + cache + scalar pos -> (logits (B, 1, V), new cache)."""
    x = _embed_decode(params, tokens, pos, cfg)
    x = shard_hint(x, ("batch", None, "embed"))

    if cfg.family == "ssm":
        new_cache: Dict[str, Any] = {}
        for i, blk in enumerate(params["blocks"]):
            h = apply_norm(blk["norm"], x, cfg)
            st = cache[f"layer{i}"]
            if "slstm" in blk:
                y, st = ssm_mod.decode_slstm(blk["slstm"], h, st, cfg)
            else:
                y, (M, n) = ssm_mod.decode_mlstm(blk["mlstm"], h,
                                                 (st["M"], st["n"]), cfg)
                st = {"M": M, "n": n}
            x = x + y
            new_cache[f"layer{i}"] = st
    elif cfg.family == "hybrid":
        new_cache = {}
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda a: a[i], params["blocks"]) \
                if not isinstance(params["blocks"], list) else params["blocks"][i]
            x, row = hybrid_mod.decode_hymba_block(
                blk, x, cache[f"layer{i}"], pos, cfg,
                is_global=i in cfg.global_attn_layers)
            new_cache[f"layer{i}"] = row
    else:
        def body(x, inp):
            blk, krow, vrow = inp
            h = apply_norm(blk["attn_norm"], x, cfg)
            a, kv = attn_mod.decode_attention(
                blk["attn"], h, {"k": krow, "v": vrow}, pos, cfg,
                window=cfg.sliding_window)
            x = x + a
            h = apply_norm(blk["ffn_norm"], x, cfg)
            if "moe" in blk:
                out, _ = moe_mod.apply_moe(blk["moe"], h, cfg)
            else:
                out = ffn_mod.apply_ffn(blk["ffn"], h, cfg)
            return x + out, (kv["k"], kv["v"])

        if cfg.scan_layers:
            x, (k, v) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"], cache["v"]))
            new_cache = {"k": k, "v": v}
        else:
            ks, vs = [], []
            for i, blk in enumerate(params["blocks"]):
                x, (k, v) = body(x, (blk, cache["k"][i], cache["v"][i]))
                ks.append(k)
                vs.append(v)
            new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}

    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(cfg.compute_dtype)
    return logits, new_cache


def _embed_decode(params: Params, tokens: jnp.ndarray, pos: jnp.ndarray,
                  cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if cfg.pos_emb == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0).astype(dt)
    return x
