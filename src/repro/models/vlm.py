"""InternVL2-style VLM backbone [arXiv:2404.16821].

Per the brief, the InternViT frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, N_vis, visual_width).  The backbone we
build and shard is the InternLM2-20B-class LM (48L, d=6144, 48H GQA kv=8)
plus the 2-layer MLP connector that projects ViT features into the LM width.
Visual tokens are prepended to the text sequence; loss is computed on text
positions only (the launcher's loss mask handles it).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import transformer as lm
from repro.models.common import (ModelConfig, Params, Specs, dense_init,
                                 zeros)


def init_vlm(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "lm": lm.init_lm(k1, cfg),
        "connector": {
            "w1": dense_init(k2, cfg.visual_width, cfg.d_model),
            "b1": zeros((cfg.d_model,)),
            "w2": dense_init(k3, cfg.d_model, cfg.d_model),
            "b2": zeros((cfg.d_model,)),
        },
    }


def vlm_specs(cfg: ModelConfig) -> Specs:
    return {
        "lm": lm.lm_specs(cfg),
        "connector": {"w1": (None, "embed"), "b1": ("embed",),
                      "w2": ("embed", "embed"), "b2": ("embed",)},
    }


def _project_visual(p: Params, patches: jnp.ndarray, cfg: ModelConfig
                    ) -> jnp.ndarray:
    dt = cfg.compute_dtype
    c = p["connector"]
    h = jax.nn.gelu(patches.astype(dt) @ c["w1"].astype(dt) + c["b1"].astype(dt))
    return h @ c["w2"].astype(dt) + c["b2"].astype(dt)


def forward(params: Params, tokens: jnp.ndarray, patches: jnp.ndarray,
            cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(tokens (B,S_text), patches (B,N_vis,Dv)) -> logits over full seq.

    Combined sequence = [visual tokens ; text tokens]; causal over the whole
    thing (InternVL inserts image context ahead of the prompt).
    """
    dt = cfg.compute_dtype
    vis = _project_visual(params, patches, cfg)               # (B, Nv, D)
    txt = lm._embed(params["lm"], tokens, cfg)                # (B, S, D)
    x = jnp.concatenate([vis, txt], axis=1)
    x = shard_hint(x, ("batch", "seq", "embed"))

    # run the LM stack on pre-built embeddings: reuse the dense-block scan
    aux = jnp.float32(0.0)

    if cfg.scan_layers:
        def body(carry, blk):
            x, aux = carry
            x, a = lm._maybe_remat(
                lambda c, b: lm._apply_dense_block(b, c, cfg), cfg)(x, blk)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), params["lm"]["blocks"])
    else:
        for blk in params["lm"]["blocks"]:
            x, a = lm._maybe_remat(
                lambda c, b: lm._apply_dense_block(b, c, cfg), cfg)(x, blk)
            aux = aux + a
    from repro.models.common import apply_norm
    x = apply_norm(params["lm"]["final_norm"], x, cfg)
    head = (params["lm"]["embed"].T if cfg.tie_embeddings
            else params["lm"]["lm_head"])
    logits = x @ head.astype(dt)
    return shard_hint(logits, ("batch", "seq", "vocab")), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return lm.init_cache(cfg, batch, max_len)


def decode_step(params: Params, tokens: jnp.ndarray, cache: Dict[str, Any],
                pos: jnp.ndarray, cfg: ModelConfig):
    """Decode rides the plain LM path (visual prefix already in the cache)."""
    return lm.decode_step(params["lm"], tokens, cache, pos, cfg)
