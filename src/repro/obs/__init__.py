"""Observability: tracing, metrics, and plan cost-attribution.

Three zero-dependency layers (stdlib only — importable everywhere the
planner is, including jax-free CLI paths):

  * :mod:`repro.obs.trace` — nested context-manager span tracer with
    thread-safe counters, exporting Chrome-trace-event JSON that loads
    directly into Perfetto (``ui.perfetto.dev``) or ``chrome://tracing``.
    Off by default and engineered to stay near-free when off; enabled via
    env ``REPRO_TRACE=/path.json`` or CLI ``--trace PATH``.
  * :mod:`repro.obs.metrics` — a process-wide registry of counters,
    gauges and histograms with JSON snapshot export, plus run-provenance
    capture (git sha, library versions, hostname, wall clock) stamped
    into ``BENCH_ridgeline.json`` and calibration registries.
  * :mod:`repro.obs.explain` — the attribution layer:
    ``plan_grid(..., explain=True)`` / CLI ``--explain`` decompose each
    surviving candidate's projected step time into additive terms
    (compute, memory, per-axis α·steps vs bytes/bw network, pipeline
    bubble, ZeRO sync) and report structured prune reasons.
"""
from repro.obs import metrics, trace  # noqa: F401  (stable import surface)
from repro.obs.metrics import REGISTRY, provenance  # noqa: F401
from repro.obs.trace import count, enabled, span  # noqa: F401

__all__ = ["trace", "metrics", "span", "count", "enabled", "REGISTRY",
           "provenance"]
