"""``python -m repro.obs --validate PATH`` — the trace-schema CLI.

Delegates to :func:`repro.obs.trace.main`; running the package (rather
than ``python -m repro.obs.trace``) avoids runpy's double-import warning
for a module the package ``__init__`` already re-exports.
"""
import sys

from repro.obs.trace import main

if __name__ == "__main__":
    sys.exit(main())
