"""Cost attribution: decompose every planner candidate's projected step time.

The planner ranks meshes by ``t_step = max(t_C, t_M, t_N)`` — a number
with no account of *why*.  This layer turns a ``plan_grid(...,
explain=True)`` result into an explanation:

  * per candidate, the full term decomposition — compute α + FLOP time,
    memory α + byte time, and the network side split per mesh axis into
    its α·steps (latency) and bytes/bw (bandwidth) parts, with the dp
    terms relabeled ``zero_sync`` when ZeRO's structural reduce-scatter +
    all-gather replaces the plain gradient all-reduce, and an
    ``ep_dispatch`` entry for the expert-parallel dispatch + combine
    all-to-all (zero on every ep = 1 candidate) — plus the 1F1B
    pipeline-bubble share of the step (interleaving shrinks the ramp by
    the candidate's virtual-stage count);
  * per candidate, a ``breakdown`` dict whose values **sum to the priced
    t_step** (property-tested): the additive parts of whichever resource
    bound the candidate.  The bubble is *not* one of those addends — it
    is an overlapping decomposition along the schedule axis
    (``runtime · (pp−1)/(m+pp−1)``), reported alongside;
  * per grid point, structured prune reasons: how many raw mesh tuples
    the enumeration rejected (batch/head divisibility, pp ∤ n_layers,
    the m ≥ pp 1F1B clamp) and how many enumerated candidates the
    HBM-capacity mask cut, with the ``min_zero_to_fit`` counterfactual
    ("this point is infeasible without ZeRO-k").

Everything here is a pure function of the grid — deterministic, so the
qwen2-7b explain JSON is golden-pinned (``tests/golden/explain_*.json``).
CLI surface: ``python -m repro.launch.plan ... --explain [--json]``.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

if TYPE_CHECKING:
    from repro.launch.plan_grid import PlanGrid

__all__ = ["EXPLAIN_SCHEMA", "explain_candidates", "explain_point",
           "explain_dict", "format_explain_table"]

EXPLAIN_SCHEMA = "repro.explain/v2"


def _require_terms(grid: "PlanGrid") -> None:
    if grid.explain_terms is None:
        raise ValueError(
            "grid carries no attribution terms; re-run plan_grid(..., "
            "explain=True) (CLI: --explain)")


def _ranked_indices(grid: "PlanGrid", chips: Optional[int],
                    batch: Optional[int]) -> List[int]:
    """Candidate indices of one grid point in ``PlanGrid.plans`` order."""
    idx = grid.point_indices(chips, batch)
    return sorted(idx.tolist(),
                  key=lambda i: (grid.runtime[i], grid.tp[i], grid.zero[i]))


def explain_candidates(grid: "PlanGrid", chips: Optional[int] = None,
                       batch: Optional[int] = None) -> List[Dict]:
    """Ranked per-candidate term decompositions for one grid point.

    Row order matches ``grid.plans(chips, batch)``.  Each record's
    ``breakdown`` values sum to ``runtime`` (within float tolerance —
    the addition order differs from the engine's fused broadcast pass);
    ``terms`` carries the full attribution regardless of the bound.
    """
    _require_terms(grid)
    t = grid.explain_terms
    labels = grid.labels()
    from repro.distributed import collectives
    algs = collectives.ALGORITHMS
    out = []
    for i in _ranked_indices(grid, chips, batch):
        dp, tp, pp = int(grid.dp[i]), int(grid.tp[i]), int(grid.pp[i])
        ep, vs = int(grid.ep[i]), int(grid.vstages[i])
        m, zero = int(grid.microbatches[i]), int(grid.zero[i])
        bound = str(labels[i])
        runtime = float(grid.runtime[i])
        # interleaving divides the ramp by vstages; the vs = 1 branch keeps
        # the classic integer expression (and its exact JSON rendering)
        ramp = (pp - 1) / vs if vs > 1 else pp - 1
        fill = m + ramp
        dp_kind = "zero_sync" if zero >= 1 else "all_reduce"
        dp_algo = ("-" if dp <= 1 else
                   ("rs+ag" if zero >= 1 else algs[int(grid.dp_algo_idx[i])]))
        tp_algo = "-" if tp <= 1 else algs[int(grid.tp_algo_idx[i])]
        net = {
            "dp": {"kind": dp_kind, "algo": dp_algo,
                   "link": "pod" if grid.dp_pod[i] else "ici",
                   "alpha_steps": float(t.net_dp_alpha_s[i]),
                   "bytes_over_bw": float(t.net_dp_bytes_s[i]),
                   "total": float(t.net_dp_alpha_s[i] + t.net_dp_bytes_s[i])},
            "tp": {"kind": "all_reduce", "algo": tp_algo,
                   "link": "pod" if grid.tp_pod[i] else "ici",
                   "alpha_steps": float(t.net_tp_alpha_s[i]),
                   "bytes_over_bw": float(t.net_tp_bytes_s[i]),
                   "total": float(t.net_tp_alpha_s[i] + t.net_tp_bytes_s[i])},
            "pp": {"kind": "p2p", "algo": "-" if pp <= 1 else "send",
                   "link": "pod" if grid.pp_pod[i] else "ici",
                   "alpha_steps": float(t.net_pp_alpha_s[i]),
                   "bytes_over_bw": float(t.net_pp_bytes_s[i]),
                   "total": float(t.net_pp_alpha_s[i] + t.net_pp_bytes_s[i])},
            "ep": {"kind": "ep_dispatch", "algo": "-" if ep <= 1 else "a2a",
                   "link": "pod" if grid.ep_pod[i] else "ici",
                   "alpha_steps": float(t.net_ep_alpha_s[i]),
                   "bytes_over_bw": float(t.net_ep_bytes_s[i]),
                   "total": float(t.net_ep_alpha_s[i] + t.net_ep_bytes_s[i])},
        }
        bubble_s = runtime * ramp / fill
        if bound == "compute":
            breakdown = {"compute_alpha": float(t.comp_alpha_s[i]),
                         "compute_flops": float(t.comp_flops_s[i])}
        elif bound == "memory":
            breakdown = {"memory_alpha": float(t.mem_alpha_s[i]),
                         "memory_bytes": float(t.mem_bytes_s[i])}
        else:
            dp_tag = "zero_sync" if zero >= 1 else "dp_sync"
            breakdown = {
                f"{dp_tag}_alpha": net["dp"]["alpha_steps"],
                f"{dp_tag}_bytes": net["dp"]["bytes_over_bw"],
                "tp_sync_alpha": net["tp"]["alpha_steps"],
                "tp_sync_bytes": net["tp"]["bytes_over_bw"],
                "pp_p2p_alpha": net["pp"]["alpha_steps"],
                "pp_p2p_bytes": net["pp"]["bytes_over_bw"],
                "ep_dispatch_alpha": net["ep"]["alpha_steps"],
                "ep_dispatch_bytes": net["ep"]["bytes_over_bw"],
            }
        goodput_rec = {}
        if grid.goodput is not None:
            # goodput pricing folded the failure bill into runtime, so the
            # breakdown gains the three amortized terms to keep summing to
            # the (effective) step time the ranking used
            breakdown["ckpt_overhead_s"] = float(grid.ckpt_overhead_s[i])
            breakdown["rework_s"] = float(grid.rework_s[i])
            breakdown["restart_s"] = float(grid.restart_s[i])
            goodput_rec = {"goodput": {
                "fraction": float(grid.goodput[i]),
                "ckpt_interval_s": float(grid.ckpt_interval_s[i]),
                "ckpt_overhead_s": float(grid.ckpt_overhead_s[i]),
                "rework_s": float(grid.rework_s[i]),
                "restart_s": float(grid.restart_s[i]),
            }}
        out.append({
            "mesh": (f"dp{dp}xtp{tp}" + (f"xpp{pp}" if pp > 1 else "")
                     + (f"xep{ep}" if ep > 1 else "")),
            "dp": dp, "tp": tp, "pp": pp, "ep": ep, "microbatches": m,
            "vstages": vs,
            "zero_stage": zero, "remat": bool(grid.remat),
            "algorithm": grid.algorithms[int(grid.req_idx[i])],
            "dp_algo": dp_algo, "tp_algo": tp_algo,
            "bottleneck": bound, "runtime": runtime,
            "t_compute": float(grid.t_compute[i]),
            "t_memory": float(grid.t_memory[i]),
            "t_network": float(grid.t_network[i]),
            "hbm_bytes": float(grid.hbm_bytes[i]),
            "terms": {
                "compute": {"alpha": float(t.comp_alpha_s[i]),
                            "flops": float(t.comp_flops_s[i])},
                "memory": {"alpha": float(t.mem_alpha_s[i]),
                           "bytes": float(t.mem_bytes_s[i])},
                "network": net,
            },
            "pipeline_bubble": {"fill": fill,
                                "fraction": ramp / fill,
                                "seconds": bubble_s},
            **goodput_rec,
            "breakdown": breakdown,
        })
    return out


def explain_point(grid: "PlanGrid", chips: Optional[int] = None,
                  batch: Optional[int] = None) -> Dict:
    """One grid point: prune reasons + ranked candidate decompositions."""
    _require_terms(grid)
    ci, bi = grid._point(chips, batch)
    reasons = dict(grid.prune_reasons[(ci, bi)])
    reasons["capacity"] = int(grid.n_pruned[ci, bi])
    k = int(grid.min_zero_to_fit[ci, bi])
    return {
        "chips": int(grid.chips_list[ci]),
        "batch": int(grid.batch_list[bi]),
        "prune_reasons": reasons,
        "min_zero_to_fit": k if 0 <= k <= 3 else None,
        "candidates": explain_candidates(grid, chips, batch),
    }


def explain_dict(grid: "PlanGrid") -> Dict:
    """The full machine-readable explanation of one ``plan_grid`` pass.

    Pure function of the grid (no clocks, no provenance) so the output is
    deterministic and golden-pinnable.
    """
    _require_terms(grid)
    return {
        "schema": EXPLAIN_SCHEMA,
        "arch": grid.cfg_name,
        "hardware": grid.hardware,
        "seq": grid.seq,
        "pod_size": grid.pod_size,
        "max_pp": grid.max_pp,
        "max_ep": grid.max_ep,
        "interleave": grid.interleave,
        "algorithms": list(grid.algorithms),
        "zero_stages": list(grid.zero_stages),
        "remat": bool(grid.remat),
        "capacity": {
            "hbm_capacity_bytes": float(grid.hbm_capacity_bytes),
            "checked": bool(grid.check_capacity),
            "n_enumerated": int(grid.n_enumerated),
            "n_pruned": int(grid.n_pruned.sum()),
            "pruned_fraction": float(grid.pruned_fraction),
        },
        # only a goodput-priced grid carries a failure model; the healthy
        # path keeps the committed explain goldens key-for-key identical
        **({"failure": {
            "mtbf_chip_s": (float(grid.failure.mtbf_chip_s)
                            if math.isfinite(grid.failure.mtbf_chip_s)
                            else None),
            "restart_s": float(grid.failure.restart_s),
            "reshard_s": float(grid.failure.reshard_s),
        }} if grid.goodput is not None and grid.failure is not None else {}),
        "points": [explain_point(grid, c, b)
                   for c in grid.chips_list for b in grid.batch_list],
    }


def _ms(s: float) -> str:
    return f"{s * 1e3:8.3f}"


def format_explain_table(records: Sequence[Dict]) -> str:
    """Per-candidate attribution as a table section (one grid point).

    The ep dispatch columns appear only when some candidate actually
    carries an ep axis, keeping the three-axis table unchanged."""
    eped = any(r.get("ep", 1) > 1 for r in records)
    head = (f"{'rank':>4} {'mesh':>12} {'mb':>4} {'z':>2} "
            f"{'comp ms':>8} {'mem ms':>8} "
            f"{'dpα ms':>8} {'dpB ms':>8} {'tpα ms':>8} {'tpB ms':>8} "
            f"{'ppα ms':>8} {'ppB ms':>8} "
            + (f"{'epα ms':>8} {'epB ms':>8} " if eped else "")
            + f"{'bubble':>7} "
            f"{'step ms':>8} {'bound':>7}")
    lines = [head, "-" * len(head)]
    for r, rec in enumerate(records):
        t = rec["terms"]
        net = t["network"]
        ep_cols = (
            f"{_ms(net['ep']['alpha_steps'])} "
            f"{_ms(net['ep']['bytes_over_bw'])} " if eped else "")
        lines.append(
            f"{r + 1:>4} {rec['mesh']:>12} {rec['microbatches']:>4} "
            f"{rec['zero_stage']:>2} "
            f"{_ms(rec['t_compute'])} {_ms(rec['t_memory'])} "
            f"{_ms(net['dp']['alpha_steps'])} {_ms(net['dp']['bytes_over_bw'])} "
            f"{_ms(net['tp']['alpha_steps'])} {_ms(net['tp']['bytes_over_bw'])} "
            f"{_ms(net['pp']['alpha_steps'])} {_ms(net['pp']['bytes_over_bw'])} "
            + ep_cols
            + f"{100 * rec['pipeline_bubble']['fraction']:6.1f}% "
            f"{_ms(rec['runtime'])} {rec['bottleneck']:>7}")
    return "\n".join(lines)


def format_prune_reasons(point: Dict) -> str:
    """One-line prune account for a grid point's explain record."""
    r = point["prune_reasons"]
    parts = [f"{k}={v}" for k, v in sorted(r.items()) if v]
    line = (f"# pruned @ chips={point['chips']} batch={point['batch']}: "
            + (", ".join(parts) if parts else "nothing"))
    if point["min_zero_to_fit"]:
        line += f" (infeasible without ZeRO-{point['min_zero_to_fit']})"
    return line


def to_json(grid: "PlanGrid", indent: int = 1) -> str:
    return json.dumps(explain_dict(grid), indent=indent, sort_keys=True)
