"""Process-wide metrics registry: counters, gauges, histograms, provenance.

One module-level :data:`REGISTRY` serves the whole process (every consumer
sees the same instruments, which is what makes cross-layer attribution
possible), with per-registry instances available for tests.  All three
instrument kinds are thread-safe and stdlib-only:

  * :class:`Counter` — monotone event count (``inc``),
  * :class:`Gauge` — last-write-wins value (``set``) — section wall-clocks,
  * :class:`Histogram` — streaming count/sum/min/max plus quantiles over a
    bounded window of the most recent observations (``observe``); the
    ``time()`` context manager observes elapsed seconds, which is how the
    serve/train step loops feed per-step latency distributions.

``snapshot()`` exports everything as one JSON-clean dict, and
:func:`provenance` captures what produced the numbers — git sha,
numpy/jax versions, hostname, wall clock — stamped into
``BENCH_ridgeline.json`` and every calibration registry entry so a
measurement can always be traced back to the code and box that made it.
"""
from __future__ import annotations

import contextlib
import math
import os
import platform
import socket
import subprocess
import threading
import time
from datetime import datetime, timezone
from typing import Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "provenance"]

Number = Union[int, float]


class Counter:
    """Monotone thread-safe event counter."""

    __slots__ = ("name", "_lock", "_n")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._n = 0.0

    def inc(self, n: Number = 1) -> float:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc must be >= 0, got {n}")
        with self._lock:
            self._n += n
            return self._n

    @property
    def value(self) -> float:
        return self._n

    def snapshot(self) -> float:
        return self._n


class Gauge:
    """Last-write-wins value (None until first ``set``)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value: Optional[float] = None

    def set(self, v: Number) -> float:
        self._value = float(v)
        return self._value

    @property
    def value(self) -> Optional[float]:
        return self._value

    def snapshot(self) -> Optional[float]:
        return self._value


#: quantile window: snapshots compute p50/p90/p99 over the most recent
#: this-many observations (count/sum/min/max stay exact over everything)
_HIST_WINDOW = 4096


class Histogram:
    """Streaming distribution: exact count/sum/min/max, windowed quantiles."""

    __slots__ = ("name", "_lock", "_count", "_sum", "_min", "_max",
                 "_window")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._window: List[float] = []

    def observe(self, v: Number) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            self._window.append(v)
            if len(self._window) > _HIST_WINDOW:
                del self._window[: len(self._window) - _HIST_WINDOW]

    @contextlib.contextmanager
    def time(self):
        """Observe the elapsed wall-clock seconds of the ``with`` body."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.observe(time.perf_counter() - t0)

    @property
    def count(self) -> int:
        return self._count

    def snapshot(self) -> Dict[str, float]:
        from repro.measure.timers import _quantile
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0}
            srt = sorted(self._window)
            return {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "mean": self._sum / self._count,
                    "p50": _quantile(srt, 0.50),
                    "p90": _quantile(srt, 0.90),
                    "p99": _quantile(srt, 0.99)}


class MetricsRegistry:
    """Create-or-get instrument registry with one-call JSON export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table: Dict, name: str, cls):
        with self._lock:
            inst = table.get(name)
            if inst is None:
                inst = table[name] = cls(name)
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    @contextlib.contextmanager
    def section(self, name: str):
        """Record the body's wall-clock seconds into gauge ``name`` —
        the per-section timing BENCH regressions localize with."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.gauge(name).set(time.perf_counter() - t0)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.snapshot() for n, c in sorted(counters.items())},
            "gauges": {n: g.snapshot() for n, g in sorted(gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(histograms.items())},
        }

    def reset(self) -> None:
        """Drop every instrument (tests; the process registry is additive)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: the process-wide registry every instrumented layer records into
REGISTRY = MetricsRegistry()


# --- run provenance -----------------------------------------------------------


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def _dist_version(name: str) -> Optional[str]:
    try:
        from importlib import metadata
        return metadata.version(name)
    except Exception:  # noqa: BLE001 — absent/broken dist metadata
        return None


def provenance() -> Dict[str, Optional[str]]:
    """Who/what/when produced a run — stamped into persisted artifacts.

    Deliberately cheap and side-effect free: library versions come from
    dist metadata (no jax import), the git sha from one short subprocess
    (None outside a checkout).
    """
    return {
        "git_sha": _git_sha(),
        "hostname": socket.gethostname(),
        "wall_clock_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "numpy": _dist_version("numpy"),
        "jax": _dist_version("jax"),
    }
