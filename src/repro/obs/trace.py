"""Span tracer: nested context-manager timing that exports Chrome trace JSON.

Every instrumented layer of the repo opens named spans through the one
module-level :func:`span` entry point::

    from repro.obs import trace

    with trace.span("plan_grid", arch=cfg.name) as sp:
        ...
        sp.set(n_candidates=n)        # attach args discovered mid-span

The resulting file is the Chrome trace event format (``"X"`` complete
events with microsecond ``ts``/``dur``), which loads unmodified into
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``; counters bumped
via :func:`count` export as ``"C"`` counter tracks.  Span nesting is purely
positional — same-thread spans nest by their (ts, dur) containment, which
is how the trace viewers render flame graphs — so the tracer keeps no
explicit parent pointers and stays a flat, lock-guarded event list
(thread-safe by construction; each event carries its thread id).

**Disabled is the default, and disabled is near-free.**  ``span()`` with no
active tracer is one module-global load plus returning a shared no-op
context manager — no clock reads, no allocation beyond the kwargs dict —
so instrumentation stays compiled into every hot path permanently
(``tests/test_obs.py`` pins the disabled-path overhead, and the committed
``planner_grid_candidates_per_s`` BENCH pin runs with these spans in
place).  Enable with env ``REPRO_TRACE=/path/trace.json`` (written at
process exit) or programmatically ``trace.enable(path)`` + ``write()``
(what CLI ``--trace PATH`` does).

:func:`validate_chrome_trace` is the schema gate CI runs on emitted
artifacts: top-level shape, per-event required fields, non-negative
durations, and proper same-thread span nesting (no partial overlap).
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Union

__all__ = ["Tracer", "enable", "disable", "enabled", "active", "span",
           "count", "counters", "write", "validate_chrome_trace", "main"]

#: env var: set to a path to trace the whole process into that file
TRACE_ENV = "REPRO_TRACE"

#: the ts/dur unit of the Chrome trace format is microseconds
_NS_PER_US = 1e3


class _NullSpan:
    """Shared do-nothing span — what :func:`span` returns when disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live ``"X"`` (complete) event; records on ``__exit__``."""

    __slots__ = ("_tracer", "name", "args", "_start_ns")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        end_ns = time.perf_counter_ns()
        self._tracer._record(self.name, self._start_ns, end_ns, self.args)
        return False

    def set(self, **args) -> "_Span":
        """Attach args discovered while the span is open (counts, sizes)."""
        self.args.update(args)
        return self


class Tracer:
    """Thread-safe span/counter collector exporting Chrome trace JSON."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._counters: Dict[str, float] = {}
        self._pid = os.getpid()
        self._t0_ns = time.perf_counter_ns()

    # -- recording -------------------------------------------------------------
    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def _record(self, name: str, start_ns: int, end_ns: int,
                args: Dict[str, Any]) -> None:
        ev = {"name": name, "ph": "X", "pid": self._pid,
              "tid": threading.get_ident(),
              "ts": (start_ns - self._t0_ns) / _NS_PER_US,
              "dur": (end_ns - start_ns) / _NS_PER_US}
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def count(self, name: str, n: Union[int, float] = 1) -> float:
        """Bump a named counter; also emits a ``"C"`` counter-track event."""
        ts = (time.perf_counter_ns() - self._t0_ns) / _NS_PER_US
        with self._lock:
            value = self._counters.get(name, 0) + n
            self._counters[name] = value
            self._events.append({"name": name, "ph": "C", "pid": self._pid,
                                 "tid": threading.get_ident(), "ts": ts,
                                 "args": {name: _jsonable(value)}})
        return value

    def counters(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counters)

    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    # -- export ----------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        from repro.obs.metrics import provenance
        with self._lock:
            events = list(self._events)
            counters = dict(self._counters)
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"provenance": provenance(),
                              "counters": counters}}

    def write(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no trace path: pass one or construct "
                             "Tracer(path=...)")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f)
        os.replace(tmp, path)
        return path


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars / odd types into JSON-clean values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    item = getattr(v, "item", None)          # numpy scalar
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return str(v)


# --- the module-level tracer (what instrumented code talks to) ----------------

_TRACER: Optional[Tracer] = None
_ATEXIT_REGISTERED = False
#: guards installs/removals of the process tracer (reads stay lock-free:
#: span()/count() deliberately snapshot _TRACER once, and a stale snapshot
#: during a racing disable() just records into the outgoing tracer)
_STATE_LOCK = threading.Lock()


def enable(path: Optional[str] = None) -> Tracer:
    """Install a process-wide tracer (idempotent; updates path if given)."""
    global _TRACER
    with _STATE_LOCK:
        if _TRACER is None:
            _TRACER = Tracer(path)
        elif path:
            _TRACER.path = path
        return _TRACER


def disable() -> Optional[Tracer]:
    """Remove the process-wide tracer; returns it (unwritten) if there was one."""
    global _TRACER
    with _STATE_LOCK:
        t, _TRACER = _TRACER, None
        return t


def enabled() -> bool:
    return _TRACER is not None


def active() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **args):
    """A context-manager span under the process tracer (no-op when disabled)."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, args)


def count(name: str, n: Union[int, float] = 1) -> Optional[float]:
    """Bump a process-wide trace counter (no-op → None when disabled)."""
    t = _TRACER
    if t is None:
        return None
    return t.count(name, n)


def counters() -> Dict[str, float]:
    t = _TRACER
    return {} if t is None else t.counters()


def write(path: Optional[str] = None) -> Optional[str]:
    """Flush the process tracer to disk (no-op → None when disabled)."""
    t = _TRACER
    if t is None:
        return None
    return t.write(path)


def _atexit_write() -> None:
    t = _TRACER
    if t is not None and t.path:
        try:
            t.write()
        except OSError:
            pass


def _init_from_env() -> None:
    global _ATEXIT_REGISTERED
    path = os.environ.get(TRACE_ENV, "").strip()
    if path:
        enable(path)
        with _STATE_LOCK:
            if not _ATEXIT_REGISTERED:
                atexit.register(_atexit_write)
                _ATEXIT_REGISTERED = True


_init_from_env()


# --- schema validation (the CI gate on emitted artifacts) ---------------------

_REQUIRED_X = ("name", "ph", "ts", "dur", "pid", "tid")

#: clock-read granularity slack when checking same-thread span containment
_NEST_EPS_US = 0.5


def validate_chrome_trace(trace: Union[str, Dict[str, Any]]
                          ) -> Dict[str, Any]:
    """Validate a Chrome-trace-event JSON file (or loaded dict).

    Checks the contract the viewers rely on: a ``traceEvents`` list; every
    ``"X"`` event carries name/ph/ts/dur/pid/tid with numeric non-negative
    duration; and same-thread complete events form a proper nesting (each
    pair is either disjoint or contained — partial overlap means a span
    leaked across another's boundary and the flame graph would lie).
    Returns a summary dict; raises ``ValueError`` with the first violation.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            doc = json.load(f)
    else:
        doc = trace
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("not a Chrome trace: want a dict with a "
                         "'traceEvents' list")
    spans: Dict[Any, List] = {}
    n_x = n_c = 0
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: not a dict with 'ph'")
        if ev["ph"] == "X":
            for k in _REQUIRED_X:
                if k not in ev:
                    raise ValueError(f"event {i}: 'X' event missing {k!r}")
            if not isinstance(ev["ts"], (int, float)) or \
                    not isinstance(ev["dur"], (int, float)):
                raise ValueError(f"event {i}: ts/dur must be numeric")
            if ev["dur"] < 0:
                raise ValueError(f"event {i}: negative dur {ev['dur']}")
            n_x += 1
            spans.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]),
                 ev["name"]))
        elif ev["ph"] == "C":
            if "name" not in ev or "ts" not in ev:
                raise ValueError(f"event {i}: 'C' event missing name/ts")
            n_c += 1
    max_depth = 0
    for tid, ivs in spans.items():
        # sort by start, longest first on ties -> parents precede children
        ivs.sort(key=lambda s: (s[0], -(s[1] - s[0])))
        stack: List = []
        for start, end, name in ivs:
            while stack and stack[-1][1] <= start + _NEST_EPS_US:
                stack.pop()
            if stack and end > stack[-1][1] + _NEST_EPS_US:
                raise ValueError(
                    f"thread {tid}: span {name!r} [{start}, {end}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]}, {stack[-1][1]}] — spans must nest")
            stack.append((start, end, name))
            max_depth = max(max_depth, len(stack))
    return {"n_events": len(doc["traceEvents"]), "n_spans": n_x,
            "n_counter_events": n_c, "n_threads": len(spans),
            "max_depth": max_depth,
            "counters": dict(doc.get("otherData", {}).get("counters", {}))}


def main(argv=None) -> int:
    """``python -m repro.obs.trace --validate PATH`` — the CI schema gate."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Validate a Chrome-trace-event JSON artifact.")
    ap.add_argument("--validate", metavar="PATH", required=True,
                    help="trace file to schema-check (exit 1 on violation)")
    args = ap.parse_args(argv)
    try:
        summary = validate_chrome_trace(args.validate)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"INVALID trace {args.validate}: {e}")
        return 1
    print(f"valid Chrome trace: {args.validate} "
          f"({summary['n_spans']} spans, "
          f"{summary['n_counter_events']} counter events, "
          f"depth {summary['max_depth']}, "
          f"{summary['n_threads']} thread(s))")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
