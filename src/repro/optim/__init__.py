"""Optimizers (``optimizer``) and gradient compression (``compression``)."""
