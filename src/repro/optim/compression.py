"""Gradient compression for the DP sync — the paper's network-term lever.

The Ridgeline case study's conclusion is that data-parallel training below a
batch threshold is NETWORK bound: t_N = B_N / net_bw dominates.  These
compressors shrink B_N (the all-reduce wire volume) at fixed model size:

  * Int8Compressor — per-tensor-chunk scale + int8 quantization with ERROR
    FEEDBACK (residual carried to the next step, Seide et al. / 1-bit SGD
    lineage): 4x wire reduction vs fp32, provably convergent for smooth
    objectives.
  * TopKCompressor — keep the largest |g| fraction per tensor with error
    feedback: wire ~ 2 * k * (4B idx + 4B val).

``round_trip`` (compress -> decompress) is what the train step applies: in
the SPMD formulation the all-reduce happens on the *decompressed* values, so
round-tripping before the optimizer models the numerics exactly; on a real
deployment the compressed payload is what crosses the wire (the int8 tensor
all-reduces in int32/bf16 accumulation).  ``wire_fraction`` reports the B_N
scale factor for the Ridgeline projection.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class CompressorState(NamedTuple):
    residual: Params      # error-feedback memory, fp32


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Per-chunk symmetric int8 with error feedback."""

    chunk: int = 4096

    def init(self, params: Params) -> CompressorState:
        return CompressorState(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def compress(self, g: jnp.ndarray, r: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """g + r -> (q int8, scale, new residual)."""
        x = g.astype(jnp.float32) + r
        flat = x.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % self.chunk
        fp = jnp.pad(flat, (0, pad)).reshape(-1, self.chunk)
        scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(x.shape)
        return q, scale, x - deq

    def round_trip_tree(self, grads: Params, state: CompressorState
                        ) -> Tuple[Params, CompressorState]:
        def one(g, r):
            x = g.astype(jnp.float32) + r
            flat = x.reshape(-1)
            n = flat.shape[0]
            pad = (-n) % self.chunk
            fp = jnp.pad(flat, (0, pad)).reshape(-1, self.chunk)
            scale = jnp.maximum(
                jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0, 1e-12)
            q = jnp.clip(jnp.round(fp / scale), -127, 127)
            deq = (q * scale).reshape(-1)[:n].reshape(x.shape)
            return deq.astype(g.dtype), (x - deq)

        out = jax.tree.map(one, grads, state.residual)
        deq = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return deq, CompressorState(residual=res)

    @property
    def wire_fraction(self) -> float:
        """int8 payload + fp32 scale per chunk vs fp32 baseline."""
        return (1.0 + 4.0 / self.chunk) / 4.0


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Magnitude top-k with error feedback (k = keep fraction)."""

    keep: float = 0.01

    def init(self, params: Params) -> CompressorState:
        return CompressorState(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def round_trip_tree(self, grads: Params, state: CompressorState
                        ) -> Tuple[Params, CompressorState]:
        def one(g, r):
            x = g.astype(jnp.float32) + r
            flat = x.reshape(-1)
            k = max(1, int(flat.shape[0] * self.keep))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
            deq = kept.reshape(x.shape)
            return deq.astype(g.dtype), (x - deq)

        out = jax.tree.map(one, grads, state.residual)
        deq = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return deq, CompressorState(residual=res)

    @property
    def wire_fraction(self) -> float:
        return 2.0 * self.keep  # (idx + val) per kept entry vs dense fp32


class StatelessRoundTrip:
    """Adapter matching TrainStepConfig.compression (residual folded into a
    step-held buffer is the stateful path; this stateless variant quantizes
    without error feedback, for ablations)."""

    def __init__(self, comp: Int8Compressor):
        self.comp = comp

    def round_trip(self, grads: Params) -> Params:
        deq, _ = self.comp.round_trip_tree(
            grads, self.comp.init(grads))
        return deq
