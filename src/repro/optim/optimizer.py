"""Pure-JAX optimizers (no optax in the image): AdamW, SGD-momentum.

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; plus global-norm
clipping and warmup-cosine schedules.  Optimizer state is fp32 regardless of
param dtype (mixed-precision master copies live in the params themselves,
which we keep fp32 — see models/common.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Params
    nu: Params


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Params) -> AdamWState:
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                          nu=jax.tree.map(jnp.copy, z))

    def _lr(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.learning_rate):
            return jnp.asarray(self.learning_rate(step), jnp.float32)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: Params, state: AdamWState, params: Params
               ) -> Tuple[Params, AdamWState]:
        step = state.step + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(m, v, p):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Params


@dataclasses.dataclass(frozen=True)
class SGD:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-2
    momentum: float = 0.9
    clip_norm: float = 0.0

    def init(self, params: Params) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))

    def update(self, grads, state, params):
        step = state.step + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mom = jax.tree.map(lambda m, g: self.momentum * m + g,
                           state.momentum, grads)
        lr = (self.learning_rate(step) if callable(self.learning_rate)
              else self.learning_rate)
        updates = jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mom, params)
        return updates, SGDState(step=step, momentum=mom)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
