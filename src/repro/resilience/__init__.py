"""Failure-aware planning and fault-injection for the repro stack.

Three layers, mirroring the model↔measurement discipline everywhere else:

* :mod:`repro.resilience.failures` — the analytic side: mesh MTBF,
  checkpoint cost, Young/Daly cadence, and the amortized per-step goodput
  overheads ``plan_grid --goodput`` folds into the ranking.  NumPy-only.
* :mod:`repro.resilience.faults` — deterministic seeded fault plans
  (preemptions, link flaps, stragglers, checkpoint corruption).
* :mod:`repro.resilience.harness` — replays a fault plan through the
  resilient training runner and measures the goodput actually delivered,
  to be compared against the analytic prediction.
* :mod:`repro.resilience.degraded` — the restart path after a hardware
  loss: re-plan on the surviving chips, restore the checkpoint onto the
  new mesh.

Importing the package pulls only the numpy-backed layers (analytic
kernels + fault plans); the jax-backed harness and degraded-restart glue
stay behind their own module imports.
"""
from repro.resilience.failures import (  # noqa: F401
    FailureModel,
    ckpt_time_s,
    failure_overhead_terms,
    goodput_fraction,
    goodput_terms,
    mesh_mtbf_s,
    young_daly_interval_s,
)
from repro.resilience.faults import (  # noqa: F401
    FaultEvent,
    FaultPlan,
)

__all__ = [
    "FailureModel",
    "FaultEvent",
    "FaultPlan",
    "ckpt_time_s",
    "failure_overhead_terms",
    "goodput_fraction",
    "goodput_terms",
    "mesh_mtbf_s",
    "young_daly_interval_s",
]
