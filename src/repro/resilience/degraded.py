"""Degraded restart: lose chips, re-plan on the survivors, restore, go.

The elastic pieces already exist in isolation — the planner can rank
meshes for any chip count, and ``checkpoint/elastic.restore_on_mesh``
reshards a checkpoint onto whatever mesh is up.  This module is the glue
a fleet controller calls after a hardware loss shrinks the pod:

1. ``replan_on_survivors`` re-runs the grid planner at the surviving chip
   count (same model, same global batch) and returns the best mesh.  When
   a :class:`FailureModel` is supplied the ranking is failure-aware: the
   smaller fleet has a *longer* mesh MTBF (fewer chips × same per-chip
   rate), so the winner can differ from a simple healthy re-rank.
2. ``degraded_restart`` builds the surviving mesh from that plan, restores
   the latest verified checkpoint onto it (corrupt steps quarantine and
   fall back, per ``checkpoint/checkpointer``), and remaps the data
   schedule for the surviving hosts.

Restart cost is what ``FailureModel.reshard_s`` prices in the planner's
goodput terms — this module is that constant made concrete.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Union

from jax.sharding import Mesh

from repro.checkpoint.elastic import remap_data_configs, restore_on_mesh
from repro.core.hardware import HardwareSpec
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_mesh
from repro.launch.plan_grid import MeshPlan, plan_grid
from repro.models.common import ModelConfig
from repro.resilience.failures import FailureModel


def replan_on_survivors(cfg: ModelConfig, hw: Union[HardwareSpec, str],
                        surviving_chips: int, global_batch: int, *,
                        seq: int = 1, max_pp: int = 1, max_ep: int = 1,
                        failure: Optional[FailureModel] = None,
                        **plan_kw) -> MeshPlan:
    """Best mesh for the surviving fleet (failure-aware when ``failure``
    is given — goodput terms are folded into the ranking)."""
    if surviving_chips < 1:
        raise ValueError(f"no survivors: {surviving_chips} chips")
    grid = plan_grid(cfg, hw, [surviving_chips], [global_batch], seq=seq,
                     max_pp=max_pp, max_ep=max_ep,
                     goodput=failure is not None, failure=failure,
                     **plan_kw)
    return grid.best(surviving_chips, global_batch)


@dataclasses.dataclass
class DegradedRestart:
    """Everything the controller needs to resume on the shrunken fleet."""

    plan: MeshPlan               # re-ranked mesh for the survivors
    mesh: Mesh                   # materialized (data, model) device mesh
    state: Any                   # checkpoint restored + resharded onto it
    step: int                    # step the restore landed on
    data_configs: Optional[List[DataConfig]] = None


def degraded_restart(checkpointer, like: Any, specs: Any, cfg: ModelConfig,
                     hw: Union[HardwareSpec, str], surviving_chips: int,
                     global_batch: int, *, seq: int = 1,
                     failure: Optional[FailureModel] = None,
                     data_cfg: Optional[DataConfig] = None,
                     surviving_hosts: int = 1, rules=None,
                     step: Optional[int] = None,
                     **plan_kw) -> DegradedRestart:
    """Re-plan on ``surviving_chips``, restore the checkpoint onto the new
    mesh, and remap the data schedule.

    The restore path inherits every integrity guarantee of the
    checkpointer: a corrupted latest step is quarantined and the restore
    falls back to the previous committed one, so a degraded restart never
    resumes from bytes that fail their checksum.
    """
    plan = replan_on_survivors(cfg, hw, surviving_chips, global_batch,
                               seq=seq, failure=failure, **plan_kw)
    # the runtime mesh materializes the (dp, tp) axes; pp/ep stay logical
    # (stage/expert placement), matching launch/mesh conventions
    mesh = make_mesh((plan.dp, plan.tp), ("data", "model"))
    state, got_step = restore_on_mesh(checkpointer, like, specs, mesh,
                                      rules=rules, step=step)
    data = (remap_data_configs(data_cfg, surviving_hosts)
            if data_cfg is not None else None)
    return DegradedRestart(plan=plan, mesh=mesh, state=state, step=got_step,
                           data_configs=data)
