"""Failure-aware goodput: the analytic side of ``repro.resilience``.

The Ridgeline prices a *healthy* step; at planner mesh sizes, failures are
a first-order cost.  This module prices the unhealthy remainder with three
classic results, all broadcast-vectorized so ``plan_grid`` applies them to
the whole candidate set in one pass:

* **mesh failure rate** — chips fail independently and exponentially with
  per-chip mean time between failures ``mtbf_chip_s``, so a ``chips``-wide
  mesh fails at rate ``λ = chips / mtbf_chip_s`` and its MTBF is
  ``mtbf_chip_s / chips`` (:func:`mesh_mtbf_s`);
* **checkpoint cost** — each chip persists its own shard of the training
  state (``launch/memory.WorkingSet.persisted``: params + optimizer states
  under the candidate's ZeRO/tp/pp/ep sharding) at ``HardwareSpec.ckpt_bw``
  bytes/s, so ``t_ckpt = persisted_bytes / ckpt_bw`` (:func:`ckpt_time_s`);
* **Young/Daly interval** — the overhead-minimizing checkpoint cadence is
  ``τ* = sqrt(2 · t_ckpt · MTBF)`` (:func:`young_daly_interval_s`).

:func:`failure_overhead_terms` amortizes those into three per-step seconds
terms — checkpoint overhead ``t_ckpt · t_step / τ``, expected rework
``(t_step / MTBF) · τ/2`` (on average half an interval of work replays
after a failure), and expected restart ``(t_step / MTBF) · restart_s``
(process respawn + elastic reshard) — and the goodput fraction

    goodput = t_step / (t_step + ckpt_overhead + E[rework] + E[restart])

is the delivered share of wall clock.  The MTBF = ∞ lane degenerates to
exact additive zeros (goodput ≡ 1), so a goodput-enabled plan with no
failure model stays bit-identical to the healthy ranking.

The empirical twin lives in ``repro.resilience.harness``: a seeded fault
plan replayed through ``ResilientRunner`` must land its *measured* goodput
within tolerance of these formulas (the same model↔measurement discipline
the calibration stack applies to the Ridgeline itself).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import numpy as np

from repro.analysis.contracts import shape_contract

ArrayLike = Union[int, float, np.ndarray]

#: hours → seconds (scale constant, not a unit-carrying name)
SECONDS_PER_HOUR = 3600.0


def _as_f64(x: ArrayLike) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Mesh-level failure statistics from per-chip constants.

    Attributes:
      mtbf_chip_s: per-chip mean time between failures, seconds
        (``inf`` = failure-free: every overhead term degenerates to 0.0).
      restart_s: time from failure to training again — process respawn,
        runtime re-init, checkpoint read-back.
      reshard_s: additional elastic-reshard time when the restart resumes
        on a degraded mesh (``checkpoint/elastic.restore_on_mesh``);
        charged on every restart — the pessimistic single constant.
    """

    mtbf_chip_s: float = float("inf")
    restart_s: float = 60.0
    reshard_s: float = 30.0

    @classmethod
    def from_mtbf_hours(cls, mtbf_hours: float, *, restart_s: float = 60.0,
                        reshard_s: float = 30.0) -> "FailureModel":
        """CLI convenience: ``--mtbf-hours H`` is per-chip MTBF in hours."""
        return cls(mtbf_chip_s=float(mtbf_hours) * SECONDS_PER_HOUR,
                   restart_s=restart_s, reshard_s=reshard_s)

    @property
    def downtime_s(self) -> float:
        """Seconds of lost wall clock per failure, beyond rework."""
        return self.restart_s + self.reshard_s


@shape_contract("chips:(*g) -> (*g)")
def mesh_mtbf_s(chips: ArrayLike, mtbf_chip_s: float) -> np.ndarray:
    """Mesh MTBF under independent exponential chip failures.

    The union of ``chips`` independent Poisson failure processes is a
    Poisson process at the summed rate, so the mesh fails every
    ``mtbf_chip_s / chips`` seconds.  ``mtbf_chip_s = inf`` propagates to
    an infinite mesh MTBF (failure-free lanes stay exact).
    """
    chips = _as_f64(chips)
    return mtbf_chip_s / np.maximum(chips, 1.0)


@shape_contract("persisted_bytes:(*g) -> (*g)")
def ckpt_time_s(persisted_bytes: ArrayLike, ckpt_bw: float) -> np.ndarray:
    """Seconds to write one checkpoint: per-chip shard bytes over the
    spec's per-chip checkpoint bandwidth (shards write concurrently, so
    the slowest — largest — shard bounds; with the symmetric sharding the
    working-set model assumes, every shard is the same size)."""
    if ckpt_bw <= 0.0:
        raise ValueError(
            "goodput planning needs HardwareSpec.ckpt_bw > 0 "
            "(the spec does not know its checkpoint bandwidth)")
    return _as_f64(persisted_bytes) / float(ckpt_bw)


@shape_contract("t_ckpt_s:(*g), mtbf_s:(*g) -> (*g)")
def young_daly_interval_s(t_ckpt_s: ArrayLike,
                          mtbf_s: ArrayLike) -> np.ndarray:
    """Young/Daly optimal checkpoint interval ``τ* = sqrt(2·t_ckpt·MTBF)``.

    Balances checkpoint overhead (∝ 1/τ) against expected rework after a
    failure (∝ τ/2).  An infinite MTBF yields an infinite interval —
    never checkpoint a machine that never fails — which the overhead
    terms downstream turn into exact zeros.
    """
    return np.sqrt(2.0 * _as_f64(t_ckpt_s) * _as_f64(mtbf_s))


@shape_contract("t_step_s:(*g), t_ckpt_s:(*g), interval_s:(*g), "
                "mtbf_s:(*g) -> (*g), (*g), (*g)")
def failure_overhead_terms(t_step_s: ArrayLike, t_ckpt_s: ArrayLike,
                           interval_s: ArrayLike, mtbf_s: ArrayLike,
                           downtime_s: float
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-step expected overhead seconds: (ckpt_overhead, rework, restart).

    * ``ckpt_overhead = t_ckpt · t_step / interval`` — one checkpoint of
      cost ``t_ckpt`` per ``interval`` seconds of useful work, amortized
      onto each step;
    * ``rework = (t_step / mtbf) · interval/2`` — failures arrive at rate
      ``1/mtbf`` and replay on average half an interval of work;
    * ``restart = (t_step / mtbf) · downtime_s`` — each failure also pays
      the restart + elastic-reshard downtime.

    The ``mtbf = inf`` lane is repaired to exact 0.0 on every term (the
    intermediate ``inf/inf`` is deliberately suppressed and overwritten),
    so adding these to a healthy step time is a bitwise identity there.
    """
    t_step_s = _as_f64(t_step_s)
    t_ckpt_s = _as_f64(t_ckpt_s)
    interval_s = _as_f64(interval_s)
    mtbf_s = _as_f64(mtbf_s)
    finite = np.isfinite(mtbf_s) & (mtbf_s > 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        ckpt_overhead_s = np.where(
            interval_s > 0.0,
            t_ckpt_s * t_step_s / np.where(interval_s > 0.0, interval_s,
                                           1.0),
            0.0)
        fail_per_step = np.where(
            finite, t_step_s / np.where(finite, mtbf_s, 1.0), 0.0)
    ckpt_overhead_s = np.where(finite, ckpt_overhead_s, 0.0)
    rework_s = fail_per_step * 0.5 * np.where(finite, interval_s, 0.0)
    restart_s = fail_per_step * float(downtime_s)
    return ckpt_overhead_s, rework_s, restart_s


@shape_contract("t_step_s:(*g), ckpt_overhead_s:(*g), rework_s:(*g), "
                "restart_s:(*g) -> (*g)")
def goodput_fraction(t_step_s: ArrayLike, ckpt_overhead_s: ArrayLike,
                     rework_s: ArrayLike,
                     restart_s: ArrayLike) -> np.ndarray:
    """Delivered share of wall clock:
    ``t_step / (t_step + ckpt_overhead + E[rework] + E[restart])``.
    Exactly 1.0 wherever every overhead term is zero."""
    t_step_s = _as_f64(t_step_s)
    total_s = (t_step_s + _as_f64(ckpt_overhead_s) + _as_f64(rework_s)
               + _as_f64(restart_s))
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(total_s > 0.0,
                       t_step_s / np.where(total_s > 0.0, total_s, 1.0),
                       1.0)
    return out


@shape_contract("t_step_s:(*g), persisted_bytes:(*g), chips:(*g) "
                "-> (*g), (*g), (*g), (*g), (*g)")
def goodput_terms(t_step_s: ArrayLike, persisted_bytes: ArrayLike,
                  chips: ArrayLike, *, ckpt_bw: float,
                  model: FailureModel
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray, np.ndarray]:
    """One-call composition for the planner: all goodput quantities.

    Returns ``(ckpt_overhead_s, rework_s, restart_s, interval_s, goodput)``
    elementwise over the broadcast candidate shape.  With an infinite
    ``model.mtbf_chip_s`` every overhead term is exactly 0.0 and goodput
    exactly 1.0 — the bit-identity lane the plan goldens pin.
    """
    mtbf_s = mesh_mtbf_s(chips, model.mtbf_chip_s)
    t_ckpt_s = ckpt_time_s(persisted_bytes, ckpt_bw)
    interval_s = young_daly_interval_s(t_ckpt_s, mtbf_s)
    ckpt_overhead_s, rework_s, restart_s = failure_overhead_terms(
        t_step_s, t_ckpt_s, interval_s, mtbf_s, model.downtime_s)
    good = goodput_fraction(t_step_s, ckpt_overhead_s, rework_s, restart_s)
    return ckpt_overhead_s, rework_s, restart_s, interval_s, good
