"""Fault-injection replay: measure the goodput a fault plan actually costs.

The analytic side (``resilience.failures``) *predicts* goodput from MTBF,
checkpoint cost, and cadence.  This harness *measures* it: a seeded
:class:`~repro.resilience.faults.FaultPlan` is driven through the real
``train.fault_tolerance.ResilientRunner`` — real jitted steps, real
checkpoint files, real restore-and-replay — and the replay's event
counters are priced in *virtual* time:

    wall  = executed·t_step + saves·t_ckpt + restarts·downtime
    goodput_measured = committed·t_step / wall

Virtual time (fixed seconds per step / checkpoint / restart) rather than
wall-clock keeps the measurement deterministic — the same plan replays to
the same goodput on any machine, which is what lets a test pin
``|measured − analytic| < tol`` without flaking on CI load.  The analytic
twin is evaluated at the replay's *actual* cadence (``ckpt_every · t_step``,
not the Young/Daly optimum) and its *empirical* failure rate, so the two
sides model the same job:

    mtbf = committed·t_step / n_restart_faults

The corrupt-checkpoint event exercises the integrity path end-to-end: it
flips bytes in the latest *committed* shard on disk, so the next restart's
restore must detect the bad crc32, quarantine the step, and fall back —
losing (and replaying) one extra checkpoint interval, which the accounting
attributes like any other rework.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional

from repro.checkpoint.checkpointer import COMMIT_MARKER, Checkpointer
from repro.resilience import failures
from repro.resilience.faults import (CORRUPT_CKPT, LINK_FLAP, PREEMPTION,
                                     STRAGGLER, FaultPlan)
from repro.train.fault_tolerance import (ResilientRunner, RunnerConfig,
                                         SimulatedFailure)


@dataclasses.dataclass(frozen=True)
class VirtualCosts:
    """Fixed virtual-seconds prices for the replay's accounting."""

    t_step_s: float = 1.0
    t_ckpt_s: float = 0.25
    downtime_s: float = 10.0


@dataclasses.dataclass
class ReplayResult:
    """Counters + priced goodput of one fault-plan replay."""

    n_steps: int                 # committed (useful) steps
    executed_steps: int          # every step that ran, incl. replays
    saves: int                   # checkpoints written
    restarts: int                # recoverable failures survived
    quarantined: int             # corrupt checkpoints detected + bypassed
    stragglers_flagged: int
    costs: VirtualCosts
    final_state: Any = None
    history: Optional[List[Dict]] = None

    @property
    def replayed_steps(self) -> int:
        return self.executed_steps - self.n_steps

    @property
    def wall_s(self) -> float:
        c = self.costs
        return (self.executed_steps * c.t_step_s + self.saves * c.t_ckpt_s
                + self.restarts * c.downtime_s)

    @property
    def useful_s(self) -> float:
        return self.n_steps * self.costs.t_step_s

    @property
    def goodput_measured(self) -> float:
        return self.useful_s / self.wall_s

    def goodput_analytic(self, ckpt_every: int,
                         n_restart_faults: int) -> float:
        """The ``resilience.failures`` prediction for this exact job:
        actual cadence (not Young/Daly), empirical failure rate."""
        c = self.costs
        interval_s = float(ckpt_every) * c.t_step_s
        mtbf_s = (self.useful_s / n_restart_faults
                  if n_restart_faults else float("inf"))
        ck, rw, rs = failures.failure_overhead_terms(
            c.t_step_s, c.t_ckpt_s, interval_s, mtbf_s, c.downtime_s)
        return float(failures.goodput_fraction(c.t_step_s, ck, rw, rs))


def _corrupt_latest(ckpt: Checkpointer) -> bool:
    """Flip bytes mid-file in the latest committed shard (silent
    corruption: size unchanged, commit marker intact — only the crc32
    knows).  Returns False when there is nothing committed yet."""
    step = ckpt.latest_step()
    if step is None:
        return False
    d = os.path.join(ckpt.root, f"step_{step:09d}")
    assert os.path.exists(os.path.join(d, COMMIT_MARKER))
    shards = sorted(n for n in os.listdir(d) if n.startswith("shard_"))
    path = os.path.join(d, shards[0])
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        buf = f.read(64)
        f.seek(size // 2)
        f.write(bytes(b ^ 0xFF for b in buf))
    return True


class _CountingCheckpointer(Checkpointer):
    """Checkpointer that counts saves and quarantines (the replay's
    observables) without changing any behavior."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.n_saves = 0
        self.n_quarantined = 0

    def save(self, step, tree, async_=False):
        self.n_saves += 1
        return super().save(step, tree, async_=async_)

    def _quarantine(self, step):
        self.n_quarantined += 1
        return super()._quarantine(step)


def replay(train_step, state, stream, plan: FaultPlan, ckpt_dir: str, *,
           ckpt_every: int = 10, costs: VirtualCosts = VirtualCosts(),
           max_retries: int = 10, keep: int = 5,
           straggler_sleep_s: float = 0.0,
           keep_history: bool = False) -> ReplayResult:
    """Drive ``plan`` through a real ResilientRunner; return the priced
    accounting.

    Each restart-class event (preemption, link flap) raises
    ``SimulatedFailure`` from inside the timed step window exactly once —
    the replayed pass over the same step must succeed, as it would on a
    fleet.  A ``corrupt_ckpt`` event corrupts the latest committed shard
    on disk at its step; the damage stays dormant until the next
    restart restores through it.  ``straggler`` events optionally sleep
    ``slowdown × straggler_sleep_s`` real seconds so the runner's EWMA
    detector has something to flag (0 disables — pure-accounting runs).

    ``costs`` is frozen (immutable), so the shared default instance is
    safe.
    """
    events = plan.by_step()
    fired: set = set()

    ckpt = _CountingCheckpointer(ckpt_dir, keep=keep)

    def failure_hook(step: int) -> None:
        ev = events.get(step)
        if ev is None or step in fired:
            return
        fired.add(step)
        if ev.kind in (PREEMPTION, LINK_FLAP):
            raise SimulatedFailure(f"{ev.kind} at step {step}")
        if ev.kind == CORRUPT_CKPT:
            _corrupt_latest(ckpt)
        elif ev.kind == STRAGGLER and straggler_sleep_s > 0.0:
            import time
            time.sleep(ev.slowdown * straggler_sleep_s)

    runner = ResilientRunner(
        train_step, ckpt,
        RunnerConfig(ckpt_every=ckpt_every, async_ckpt=False,
                     max_retries=max_retries, backoff_base_s=0.0),
        failure_hook=failure_hook)
    final, history = runner.run(state, stream, n_steps=plan.n_steps)

    return ReplayResult(
        n_steps=plan.n_steps,
        executed_steps=len(history),
        saves=ckpt.n_saves,
        restarts=len(fired & {e.step for e in plan.events
                              if e.kind in (PREEMPTION, LINK_FLAP)}),
        quarantined=ckpt.n_quarantined,
        stragglers_flagged=len(runner.stragglers),
        costs=costs,
        final_state=final,
        history=list(history) if keep_history else None)


def predicted_goodput(plan: FaultPlan, *, ckpt_every: int,
                      costs: VirtualCosts = VirtualCosts()) -> float:
    """Analytic goodput for a plan before running it (same formulas the
    planner folds into ``--goodput`` rankings, at the job's cadence)."""
    interval_s = float(ckpt_every) * costs.t_step_s
    useful_s = plan.n_steps * costs.t_step_s
    n = plan.n_restart_faults
    mtbf_s = useful_s / n if n else float("inf")
    ck, rw, rs = failures.failure_overhead_terms(
        costs.t_step_s, costs.t_ckpt_s, interval_s, mtbf_s,
        costs.downtime_s)
    return float(failures.goodput_fraction(costs.t_step_s, ck, rw, rs))
