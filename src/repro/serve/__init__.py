"""Serving layer: prefill + batched single-token decode (``engine``)."""
