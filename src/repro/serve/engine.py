"""Serving: prefill + batched single-token decode (``serve_step``).

``build_serve_step(cfg)`` returns the one-token decode function the
``decode_*`` / ``long_*`` dry-run cells lower: given the params, the KV
cache / recurrent state for a context of ``seq_len`` tokens, the current
token batch and position, produce logits + the updated cache.  Greedy
sampling helper included for the runnable demos.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod
from repro.models import vlm as vlm_mod
from repro.models.common import ModelConfig
from repro.obs import trace
from repro.obs.metrics import REGISTRY
from repro.measure.timers import block_until_ready


def build_serve_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        def serve_step(params, tokens, cache, pos):
            logits, cache = encdec_mod.decode_step(params, tokens, cache,
                                                   pos, cfg)
            return logits, cache
    elif cfg.family == "vlm":
        def serve_step(params, tokens, cache, pos):
            return vlm_mod.decode_step(params, tokens, cache, pos, cfg)
    else:
        def serve_step(params, tokens, cache, pos):
            return lm_mod.decode_step(params, tokens, cache, pos, cfg)
    return serve_step


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int,
               frames: jnp.ndarray | None = None):
    if cfg.family == "encdec":
        assert frames is not None
        return encdec_mod.init_encdec_cache(params, frames, batch, max_len, cfg)
    if cfg.family == "vlm":
        return vlm_mod.init_cache(cfg, batch, max_len)
    return lm_mod.init_cache(cfg, batch, max_len)


def greedy_generate(params, cfg: ModelConfig, prompt: jnp.ndarray,
                    steps: int, max_len: int,
                    frames: jnp.ndarray | None = None) -> jnp.ndarray:
    """Prefill token-by-token then greedy-decode ``steps`` tokens."""
    B, S = prompt.shape
    serve_step = jax.jit(build_serve_step(cfg))
    cache = init_cache(params, cfg, B, max_len, frames=frames)
    tok = prompt[:, :1]
    out = [tok]
    logits = None
    step_hist = REGISTRY.histogram("serve.step_seconds")
    with trace.span("serve.generate", arch=cfg.name, batch=B,
                    prompt_len=S, steps=steps):
        for t in range(S + steps - 1):
            # per-token decode latency: block inside the timed region so
            # async dispatch is charged for the work, not the dispatch
            with step_hist.time():
                logits, cache = serve_step(params, tok, cache, jnp.int32(t))
                block_until_ready(logits)
            if t + 1 < S:
                tok = prompt[:, t + 1:t + 2]
            else:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
            out.append(tok)
    return jnp.concatenate(out, axis=1)
