"""Training layer: step construction (``loop``) + fault tolerance."""
