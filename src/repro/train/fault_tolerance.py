"""Fault-tolerant training runner: restart, retry, straggler detection.

``ResilientRunner`` wraps a train-step callable with the operational layer a
1000-node job needs:

  * checkpoint/auto-resume — periodic (optionally async) saves through
    ``Checkpointer``; on (re)start it restores the latest committed step and
    fast-forwards the data pipeline (pure function of step — nothing else to
    replay);
  * bounded retry with re-init from checkpoint on step failure (the
    recoverable class: preemption, transient ICI timeout — simulated in
    tests with an injected failure hook);
  * straggler detection — per-step wall-time EWMA; a step slower than
    ``straggler_factor``× the EWMA raises a flag the orchestration layer
    consumes (on real fleets: re-schedule the slow host / exclude it at the
    next elastic restart).  Detection must live in the runner because only
    the runner sees wall time; mitigation is a callback.
  * exponential backoff with jitter between retries — a fleet restarting
    in lockstep after a shared-fate failure (power event, storage blip)
    would hammer the checkpoint store; each retry waits
    ``backoff_base_s · 2^(k−1)`` capped at ``backoff_max_s``, with a
    seeded ±``backoff_jitter`` spread so replicas desynchronize
    deterministically under test.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.obs import trace
from repro.obs.metrics import REGISTRY


@dataclasses.dataclass
class RunnerConfig:
    ckpt_every: int = 50
    async_ckpt: bool = True
    max_retries: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    # retry backoff: base · 2^(k−1) seconds before the k-th retry of a
    # step, capped at the max, jittered ±jitter fraction (0 base = none)
    backoff_base_s: float = 0.1
    backoff_max_s: float = 5.0
    backoff_jitter: float = 0.1


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float


class ResilientRunner:
    def __init__(self, train_step: Callable, checkpointer: Checkpointer,
                 cfg: Optional[RunnerConfig] = None,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.train_step = train_step
        self.ckpt = checkpointer
        # RunnerConfig is mutable, so a shared default instance would leak
        # one runner's tweaks into every later runner; build per-instance
        self.cfg = cfg if cfg is not None else RunnerConfig()
        self.on_straggler = on_straggler
        self.failure_hook = failure_hook   # tests inject failures here
        self.stragglers: List[StragglerEvent] = []
        self._ewma: Optional[float] = None
        self._warmup = True
        # fixed seed: backoff jitter must replay identically under test
        self._backoff_rng = random.Random(0x5EED)

    def resume_or_init(self, state):
        """Restore the latest committed checkpoint if one exists."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        restored, step = self.ckpt.restore(state)
        # the first step after a restore re-traces/compiles (new buffer
        # donation pattern) — re-arm the EWMA warm-up skip so that step is
        # not flagged as a straggler
        self._warmup = True
        return restored, step

    def _backoff(self, retries: int) -> float:
        """Seconds to wait before the ``retries``-th retry (jittered)."""
        base = self.cfg.backoff_base_s
        if base <= 0.0:
            return 0.0
        wait = min(base * 2.0 ** (retries - 1), self.cfg.backoff_max_s)
        return wait * (1.0 + self.cfg.backoff_jitter
                       * self._backoff_rng.uniform(-1.0, 1.0))

    def run(self, state, stream, n_steps: int,
            start_step: Optional[int] = None) -> Tuple[Any, List[Dict]]:
        """Run ``n_steps`` with retry-from-checkpoint on failure."""
        if start_step is None:
            state, start_step = self.resume_or_init(state)
        history: List[Dict] = []
        step = start_step
        retries = 0
        last_failed_step = -1
        step_hist = REGISTRY.histogram("train.step_seconds")
        with trace.span("train.run", n_steps=n_steps,
                        start_step=start_step) as run_sp:
            while step < n_steps:
                try:
                    t0 = time.monotonic()
                    if self.failure_hook is not None:
                        self.failure_hook(step)   # inside the timed window
                    batch = stream.batch(step)
                    state, metrics = self.train_step(state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.monotonic() - t0
                    step_hist.observe(dt)
                    self._track_time(step, dt)
                    history.append(
                        {k: float(v) for k, v in metrics.items()}
                        | {"step": step})
                    step += 1
                    if step % self.cfg.ckpt_every == 0:
                        self.ckpt.save(step, state,
                                       async_=self.cfg.async_ckpt)
                except _RECOVERABLE as e:  # noqa: PERF203
                    # retries are counted PER FAILING STEP: a replay that
                    # makes progress and then fails at the same step again
                    # is the deterministic-failure case and must eventually
                    # give up (counting globally and resetting on success
                    # would loop forever on a persistent fault).
                    trace.count("train.recoverable_failures", 1)
                    if step == last_failed_step:
                        retries += 1
                    else:
                        retries, last_failed_step = 1, step
                    if retries > self.cfg.max_retries:
                        raise
                    wait_s = self._backoff(retries)
                    if wait_s > 0.0:
                        time.sleep(wait_s)
                    self.ckpt.wait()
                    state, step = self.resume_or_init(state)
            if trace.enabled():
                run_sp.set(steps_run=len(history),
                           n_stragglers=len(self.stragglers))
        self.ckpt.wait()
        self.ckpt.save(n_steps, state, async_=False)
        return state, history

    def _track_time(self, step: int, dt: float) -> None:
        # the first measured step carries jit compilation — seeding the EWMA
        # with it would mask real stragglers for many steps; skip it
        if self._warmup:
            self._warmup = False
            return
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma and step > 2:
            ev = StragglerEvent(step=step, step_time=dt, ewma=self._ewma)
            self.stragglers.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        a = self.cfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt


class SimulatedFailure(RuntimeError):
    """Raised by test failure hooks to model preemption/node loss."""


_RECOVERABLE = (SimulatedFailure,)
