"""Train-step construction: loss, grad, optimizer update — family-aware.

``build_train_step(cfg, optimizer)`` returns a pure function
``train_step(state, batch) -> (state, metrics)`` suitable for ``jax.jit``
with in/out shardings.  Batches are dicts of arrays (see
``repro.launch.specs.input_specs`` for the exact keys per family).

Gradient sync is implicit in the SPMD formulation: the loss is a global mean
over the batch axis, so ∂loss/∂params materializes as reduce-scatter /
all-reduce over the DP mesh axes in the lowered HLO — exactly the traffic the
paper's B_N term accounts for.  Optional hooks:

  * microbatching (gradient accumulation over ``n_micro`` scan steps),
  * gradient compression (error-feedback int8, ``repro.optim.compression``)
    applied at the accumulation boundary,
  * MoE aux-loss folding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import mlp_dlrm as mlp_mod
from repro.models import transformer as lm_mod
from repro.models import vlm as vlm_mod
from repro.models.common import ModelConfig, softmax_cross_entropy
from repro.obs import trace


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    rng: jnp.ndarray


def make_loss_fn(cfg: ModelConfig) -> Callable:
    """Loss over one (micro)batch, returns (loss, metrics-dict)."""

    def lm_loss(params, batch):
        logits, aux = lm_mod.forward(params, batch["tokens"], cfg)
        ce = softmax_cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    def encdec_loss(params, batch):
        logits, aux = encdec_mod.forward(params, batch["tokens"],
                                         batch["frames"], cfg)
        ce = softmax_cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": aux}

    def vlm_loss(params, batch):
        logits, aux = vlm_mod.forward(params, batch["tokens"],
                                      batch["patches"], cfg)
        nv = cfg.visual_tokens
        ce = softmax_cross_entropy(logits[:, nv:], batch["labels"])
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    def mlp_loss(params, batch):
        loss = mlp_mod.loss_fn(params, batch["features"], batch["click"], cfg)
        return loss, {"ce": loss, "aux": jnp.float32(0.0)}

    return {"encdec": encdec_loss, "vlm": vlm_loss,
            "mlp": mlp_loss}.get(cfg.family, lm_loss)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    n_micro: int = 1                  # gradient-accumulation microbatches
    compression: Optional[Any] = None  # repro.optim.compression.Compressor


def build_train_step(cfg: ModelConfig, optimizer,
                     # shared default instance is safe: the dataclass is
                     # frozen, so no caller can mutate it for everyone
                     ts_cfg: TrainStepConfig = TrainStepConfig()):
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        params = state.params
        if ts_cfg.n_micro > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, _, g = grads_of(params, mb)
                acc = jax.tree.map(lambda a, b: a + b, acc, g)
                return (acc, loss_acc + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(ts_cfg.n_micro,
                                    x.shape[0] // ts_cfg.n_micro,
                                    *x.shape[1:]), batch)
            (grads, loss), _ = jax.lax.scan(
                micro, (zero, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / ts_cfg.n_micro, grads)
            loss = loss / ts_cfg.n_micro
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if ts_cfg.compression is not None:
            grads = ts_cfg.compression.round_trip(grads)

        updates, opt_state = optimizer.update(grads, state.opt_state, params)
        from repro.optim.optimizer import apply_updates, global_norm
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads),
                       step=state.step)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1, rng=state.rng)
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, optimizer) -> TrainState:
    with trace.span("train.init_state", arch=cfg.name, family=cfg.family):
        if cfg.family == "encdec":
            params = encdec_mod.init_encdec(key, cfg)
        elif cfg.family == "vlm":
            params = vlm_mod.init_vlm(key, cfg)
        elif cfg.family == "mlp":
            params = mlp_mod.init_mlp(key, cfg)
        else:
            params = lm_mod.init_lm(key, cfg)
        return TrainState(params=params, opt_state=optimizer.init(params),
                          step=jnp.zeros((), jnp.int32), rng=key)


def model_param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_mod.encdec_specs(cfg)
    if cfg.family == "vlm":
        return vlm_mod.vlm_specs(cfg)
    if cfg.family == "mlp":
        return mlp_mod.mlp_specs(cfg)
    return lm_mod.lm_specs(cfg)
