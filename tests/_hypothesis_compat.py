"""Degraded-but-deterministic stand-in for ``hypothesis``.

``hypothesis`` is an *optional* dev dependency (see pytest.ini).  When it is
installed, this module re-exports the real ``given``/``settings``/``st`` and
the property tests shrink failures as usual.  When it is missing, a minimal
fixed-examples engine runs each ``@given`` body against a deterministic
sample stream (seeded per test from the test's qualified name), so the suite
still *collects and exercises* every property — it just loses shrinking and
adaptive example generation.

Only the strategy surface this repo's tests use is emulated:
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``one_of``,
``just``.
"""
from __future__ import annotations

import functools
import math
import random

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def one_of(*strategies):
            return _Strategy(lambda rng: rng.choice(strategies).example(rng))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False, **_ignored):
            lo, hi = float(min_value), float(max_value)

            def draw(rng: random.Random) -> float:
                # log-uniform over wide positive ranges (the property tests
                # span many orders of magnitude; uniform would never sample
                # the small decades)
                if lo > 0 and hi / lo > 1e3:
                    return 10.0 ** rng.uniform(math.log10(lo), math.log10(hi))
                return rng.uniform(lo, hi)

            return _Strategy(draw)

    st = _Strategies()

    class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
        def __init__(self, max_examples=20, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(**strategies):
        def decorate(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples", 20))
                n = min(int(n), 100)  # fixed examples need no 500-deep sweep
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # NOT functools.wraps: pytest follows __wrapped__ to the original
            # signature and would demand fixtures for the strategy kwargs
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return decorate
