"""Suite-wide defaults: runtime shape contracts ON, silent NaN/inf fatal.

Two hardening knobs the production code keeps off by default are forced on
for every test run:

- ``REPRO_CHECK=1`` — the ``@shape_contract`` decorators on the vectorized
  kernels (``repro.analysis.contracts``) enforce their broadcast shapes at
  runtime.  The env var is set before any ``repro`` import (pytest loads
  conftest first) and ``set_checking`` is called as a belt-and-braces for
  anything imported earlier; benchmarks run without this conftest, so the
  BENCH pins still measure the disabled fast path.
- ``np.errstate(invalid="raise", divide="raise")`` around the broadcast
  pricing-pass test modules, so a NaN/inf born *outside* the engine's
  deliberate ``errstate`` guards (``core/sweep._safe_div`` and friends,
  which locally ignore-and-repair) fails the test instead of flowing into
  a ranking.  Scoped to those modules because timer/measure tests create
  NaN on purpose (degenerate-sample spreads).
"""
import os

os.environ.setdefault("REPRO_CHECK", "1")

import numpy as np
import pytest

from repro.analysis import contracts

if os.environ["REPRO_CHECK"] not in ("", "0"):
    contracts.set_checking(True)

#: broadcast pricing passes: any NaN/inf that escapes a deliberate
#: errstate guard in these modules' code under test is a bug
_ERRSTATE_RAISE_MODULES = {
    "tests.test_plan_grid", "test_plan_grid",
    "tests.test_sweep", "test_sweep",
    "tests.test_memory", "test_memory",
    "tests.test_collectives", "test_collectives",
}


@pytest.fixture(autouse=True)
def _raise_on_silent_nan(request):
    mod = getattr(request, "module", None)
    if mod is not None and mod.__name__ in _ERRSTATE_RAISE_MODULES:
        with np.errstate(invalid="raise", divide="raise"):
            yield
    else:
        yield
