"""Known-bad corpus for the contract lint (AST-only — never imported).

Importing this file would raise at decoration time (specs parse eagerly);
the static pass must report the same defects without importing.
"""
from repro.analysis.contracts import shape_contract


@shape_contract("(c,a) -> (c,b)")               # -> contract-bad-spec
def output_axis_unbound(x):
    return x


@shape_contract("(c,), (a,) -> (c,)")           # -> contract-arity
def more_operands_than_params(x):
    return x


@shape_contract("q:(c,) -> (c,)")               # -> contract-unknown-param
def names_missing_param(x):
    return x


@shape_contract("x:(c,), x:(c,) -> (c,)")       # -> contract-duplicate-param
def names_param_twice(x):
    return x


@shape_contract("payload_bytes:(*g), ep:(*g) -> (*g)")  # -> contract-unknown-param
def ep_dispatch_names_wrong_param(payload_bytes, group_size):
    # an ep-axis kernel whose contract names `ep` but whose signature says
    # `group_size` — the broadcast grid would silently skip the ep check
    return payload_bytes
