"""Known-bad corpus for the global-state lint (AST-only — never imported)."""
import threading

_CACHE = {}
_RESULTS = []
_SINGLETON = None
_LOCK = threading.Lock()


def remember(key, value):
    _CACHE[key] = value                         # -> state-unlocked-mutation


def accumulate(value):
    _RESULTS.append(value)                      # -> state-unlocked-mutation


def install(x):
    global _SINGLETON
    _SINGLETON = x                              # -> state-unlocked-global


def install_locked(x):
    # held lock: must NOT fire
    global _SINGLETON
    with _LOCK:
        _SINGLETON = x
        _CACHE["latest"] = x


class Holder:
    def __init__(self):
        # __init__ is exempt: the object under construction is unshared
        self.slots = {}
        _CACHE.setdefault("holders", 0)
