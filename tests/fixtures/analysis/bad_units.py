"""Known-bad corpus for the units lint (AST-only — never imported).

Each function below must fire exactly the rule named in its comment;
``tests/test_analysis.py`` asserts the full expected set, so an analyzer
change that silently stops detecting one of these fails the suite.
"""


def add_flops_to_bytes(work):
    return work.flops + work.mem_bytes          # -> unit-mismatch (add)


def subtract_rate_from_time(step_s, link_bw):
    return step_s - link_bw                     # -> unit-mismatch (sub)


def compare_time_to_traffic(step_s, wire_bytes):
    return step_s > wire_bytes                  # -> unit-mismatch (compare)


def mislabeled_assignment(step_s):
    total_bytes = step_s                        # -> unit-bad-assign
    return total_bytes


def wrong_collective_payload(step_s, collectives):
    return collectives.all_reduce(step_s, 8)    # -> unit-bad-arg


def alpha_for(wire_bytes):
    return wire_bytes                           # -> unit-bad-return (wants s)


def empty_suppression(step_s, wire_bytes):
    return step_s + wire_bytes  # unit: ignore[]
    # the empty reason above is itself a finding -> bad-suppression


def justified_suppression(step_s, wire_bytes):
    # a reasoned suppression silences the mismatch (round-trip test)
    return step_s + wire_bytes  # unit: ignore[fixture: demonstrates a reasoned suppression]


def goodput_plus_seconds(goodput, rework_s):
    return goodput + rework_s                   # -> unit-mismatch (goodput)


def seconds_masquerading_as_goodput(rework_s):
    goodput = rework_s                          # -> unit-bad-assign
    return goodput
