"""ISSUE 4 regression tests: algorithm-aware collective selection and the
size-dependent efficiency ceiling, hardened by property tests.

Three layers:

  * properties (hypothesis via ``tests/_hypothesis_compat``):
    ``best_all_reduce`` is always the brute-force argmin over the algorithm
    menu; ``EfficiencyModel.eff`` is monotone in F and bounded in (0, 1];
    the identity curve reproduces the PR 3 α–β times bit-for-bit;
  * calibration: the v3 efficiency fit recovers a synthetic Hill machine,
    exact α–β machines keep the intercept model, and v1/v2 registry entries
    read-compat into identity-eff specs;
  * planner/CLI: ``--algo auto`` selects per axis (tree below the flip,
    a bandwidth-optimal ring above), size-1 axes price zero network even
    with α > 0, and the ``--json`` key set is golden-pinned.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core import sweep as sweep_mod
from repro.core.hardware import (CALIBRATION_SCHEMA, CLX, TPU_V5E,
                                 EfficiencyModel, HardwareSpec,
                                 spec_from_calibration)
from repro.core.ridgeline import WorkUnit, analyze, resource_times
from repro.distributed import collectives as coll
from tests._hypothesis_compat import given, settings, st

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- efficiency model: algebra + properties -----------------------------------


class TestEfficiencyModel:
    def test_identity_is_default_and_exactly_one(self):
        em = EfficiencyModel()
        assert em.is_identity
        for q in (0.0, 1.0, 1e-30, 1e30, math.inf):
            assert em.eff(q) == 1.0
        for hw in (CLX, TPU_V5E):
            assert hw.compute_eff.is_identity

    def test_validation(self):
        with pytest.raises(ValueError):
            EfficiencyModel(f_half=-1.0)
        with pytest.raises(ValueError):
            EfficiencyModel(f_half=1.0, p=0.0)
        with pytest.raises(ValueError):
            EfficiencyModel(f_half=1.0, eff_min=1.5)

    def test_known_values(self):
        em = EfficiencyModel(f_half=1e9, p=1.0)
        assert em.eff(1e9) == pytest.approx(0.5)     # half headroom at f_half
        assert em.eff(math.inf) == 1.0
        assert em.eff(0.0) == 0.0                    # the eff_min floor
        floor = EfficiencyModel(f_half=1e9, p=1.0, eff_min=0.25)
        assert floor.eff(0.0) == 0.25
        assert floor.eff(1e9) == pytest.approx(0.625)

    @settings(max_examples=60)
    @given(f_half=st.floats(min_value=1e3, max_value=1e15),
           p=st.floats(min_value=0.1, max_value=4.0),
           eff_min=st.floats(min_value=0.0, max_value=1.0),
           q=st.floats(min_value=1e-6, max_value=1e18),
           scale=st.floats(min_value=1.0, max_value=1e6))
    def test_property_monotone_and_bounded(self, f_half, p, eff_min, q,
                                           scale):
        """eff is monotone non-decreasing in F and in (0, 1] for F > 0."""
        em = EfficiencyModel(f_half=f_half, p=p, eff_min=eff_min)
        lo, hi = em.eff(q), em.eff(q * scale)
        assert lo <= hi + 1e-15
        # F > 0 always yields eff in (0, 1]: the ceiling never collapses
        assert 0.0 < lo <= 1.0 and 0.0 < hi <= 1.0

    @settings(max_examples=60)
    @given(f_half=st.floats(min_value=1e3, max_value=1e15),
           p=st.floats(min_value=0.1, max_value=4.0),
           q=st.floats(min_value=0.0, max_value=1e18))
    def test_property_vectorized_matches_scalar(self, f_half, p, q):
        em = EfficiencyModel(f_half=f_half, p=p)
        grid = sweep_mod.eff_grid(em, np.array([q, q * 7.0, 0.0, np.inf]))
        assert grid[0] == pytest.approx(em.eff(q), rel=1e-12, abs=1e-300)
        assert grid[1] == pytest.approx(em.eff(q * 7.0), rel=1e-12,
                                        abs=1e-300)
        assert grid[2] == em.eff(0.0)
        assert grid[3] == em.eff(math.inf) == 1.0

    def test_identity_reproduces_alpha_beta_times_bit_for_bit(self):
        """eff ≡ 1 must not perturb a single PR 3 time by even one ulp."""
        base = HardwareSpec("b", 1e12, 1e11, 1e10, alpha_compute=1e-4,
                            alpha_memory=2e-5, alpha_network=1e-6)
        with_eff = HardwareSpec("b", 1e12, 1e11, 1e10, alpha_compute=1e-4,
                                alpha_memory=2e-5, alpha_network=1e-6,
                                compute_eff=EfficiencyModel())
        rng = np.random.RandomState(7)
        for _ in range(50):
            f, bm, bn = 10.0 ** rng.uniform(-2, 16, size=3)
            w = WorkUnit("w", f, bm, bn, net_steps=6.0)
            assert resource_times(w, base) == resource_times(w, with_eff)
        # and the vectorized path, elementwise exact
        f = 10.0 ** rng.uniform(-2, 16, size=64)
        r0 = sweep_mod.sweep(f, 1e8, 1e7, base, net_steps=6.0)
        r1 = sweep_mod.sweep(f, 1e8, 1e7, with_eff, net_steps=6.0)
        assert np.array_equal(r0.t_compute, r1.t_compute)
        assert np.array_equal(r0.runtime, r1.runtime)

    def test_sweep_scalar_parity_with_curve(self):
        """The vectorized sweep with a non-identity curve == analyze()."""
        hw = HardwareSpec("e", 1e12, 1e11, 1e10, alpha_compute=1e-5,
                          compute_eff=EfficiencyModel(f_half=1e9, p=0.7))
        f = np.array([0.0, 1e3, 1e6, 1e9, 1e12, 1e15])
        res = sweep_mod.sweep(f, 1e8, 1e7, hw, net_steps=2.0)
        for i, fi in enumerate(f):
            a = analyze(WorkUnit("w", fi, 1e8, 1e7, net_steps=2.0), hw)
            assert res.runtime[i] == pytest.approx(a.runtime, rel=1e-12)
            assert res.labels()[i] == a.bottleneck.value

    def test_effective_peak(self):
        em = EfficiencyModel(f_half=1e9, p=1.0)
        hw = HardwareSpec("e", 1e12, 1e11, 1e10, compute_eff=em)
        assert hw.effective_peak(1e9) == pytest.approx(5e11)
        assert CLX.effective_peak(1.0) == CLX.peak_flops

    def test_extreme_small_quantities_hit_the_floor_not_overflow(self):
        """(f_half/q)**p past 1e308 must degrade to eff_min, not raise."""
        em = EfficiencyModel(f_half=1e15, p=4.0)
        assert em.eff(1e-65) == 0.0
        floor = EfficiencyModel(f_half=1e15, p=4.0, eff_min=0.3)
        assert floor.eff(1e-65) == 0.3
        grid = sweep_mod.eff_grid(em, np.array([1e-65, 1.0]))
        assert grid[0] == em.eff(1e-65)


# --- best_all_reduce: brute-force property ------------------------------------


def _brute_force_best(payload, n, bw, alpha):
    best = None
    for algo in coll.ALGORITHMS:
        t = float(coll.all_reduce(payload, n, algo).time(bw, alpha))
        if best is None or t < best[1]:
            best = (algo, t)
    return best[0]


class TestBestAllReduce:
    @settings(max_examples=120)
    @given(payload=st.floats(min_value=1.0, max_value=1e12),
           n=st.integers(min_value=1, max_value=4096),
           bw=st.floats(min_value=1e6, max_value=1e12),
           alpha=st.one_of(st.just(0.0),
                           st.floats(min_value=1e-9, max_value=1e-2)))
    def test_property_matches_brute_force_argmin(self, payload, n, bw,
                                                 alpha):
        algo, cost = coll.best_all_reduce(payload, n, bw, alpha)
        assert algo == _brute_force_best(payload, n, bw, alpha)
        want = coll.all_reduce(payload, n, algo)
        assert float(cost.wire_bytes) == float(want.wire_bytes)
        assert float(cost.steps) == float(want.steps)

    def test_group_of_one_is_free_even_with_alpha(self):
        algo, cost = coll.best_all_reduce(1e9, 1, 1e9, alpha=1.0)
        assert float(cost.wire_bytes) == 0.0
        assert float(cost.steps) == 0.0
        assert float(cost.time(1e9, alpha=1.0)) == 0.0

    def test_menu_restriction_and_aliases(self):
        algo, _ = coll.best_all_reduce(1e3, 64, 50e9, 1e-5,
                                       algorithms=("ring", "bidir"))
        assert algo == "bidir_ring"                # alias resolved, tree out
        with pytest.raises(ValueError, match="unknown all-reduce"):
            coll.best_all_reduce(1.0, 4, 1e9, algorithms=("quantum",))
        with pytest.raises(ValueError, match="at least one"):
            coll.best_all_reduce(1.0, 4, 1e9, algorithms=())

    @settings(max_examples=40)
    @given(n=st.integers(min_value=8, max_value=1024),
           bw=st.floats(min_value=1e8, max_value=1e12),
           alpha=st.floats(min_value=1e-8, max_value=1e-3))
    def test_property_flip_point_consistent_with_argmin(self, n, bw, alpha):
        """Just below the flip the small-payload algo wins; just above,
        the large-payload one (the lower envelope really crosses there)."""
        flip = coll.all_reduce_flip_payload(n, bw, alpha)
        assert flip is not None        # n >= 8: tree's log steps < ring's
        payload, small, large = flip
        assert small == "tree" and large == "bidir_ring"
        assert _brute_force_best(payload * 0.9, n, bw, alpha) == small
        assert _brute_force_best(payload * 1.1, n, bw, alpha) == large

    def test_flip_none_cases(self):
        assert coll.all_reduce_flip_payload(64, 1e9, 0.0) is None   # α = 0
        assert coll.all_reduce_flip_payload(1, 1e9, 1e-5) is None   # no-op
        assert coll.all_reduce_flip_payload(4, 1e9, 1e-5) is None   # n small


# --- calibration: efficiency fit + schema compat ------------------------------


def _mk(name, flops, mem, net, seconds, category):
    from repro.measure.microbench import Measurement
    return Measurement(work=WorkUnit(name, flops, mem, net),
                       seconds=seconds, best_seconds=seconds,
                       category=category)


class TestEfficiencyFit:
    BASE = HardwareSpec("fake_ds", 5e12, 8e10, 9e9)

    def test_fit_recovers_synthetic_hill_machine(self):
        """Sized GEMMs from a known eff curve -> the curve comes back."""
        from repro.measure.calibrate import fit_ceilings
        peak, em = 2e11, EfficiencyModel(f_half=5e7, p=0.7)
        suite = [_mk(f"gemm{i}", f, 1e3, 0.0, f / (peak * em.eff(f)),
                     "compute")
                 for i, f in enumerate((1e6, 1e7, 1e8, 1e9, 1e10))]
        suite.append(_mk("stream", 1e3, 1e9, 0.0, 1e9 / 4e9, "memory"))
        calib = fit_ceilings(suite, self.BASE)
        assert not calib.compute_eff.is_identity
        assert calib.alpha_compute == 0.0          # curve subsumes intercept
        assert calib.peak_flops == pytest.approx(peak, rel=0.05)
        assert calib.compute_eff.p == pytest.approx(0.7, rel=0.1)
        assert calib.compute_eff.f_half == pytest.approx(5e7, rel=0.2)
        # the fitted spec prices every synthetic point almost exactly
        for m in calib.fit_measurements:
            if m.category == "compute":
                assert calib.rel_error(m) == pytest.approx(0.0, abs=0.02)

    def test_fit_never_selects_time_nonmonotone_exponent(self):
        """Data steeper than p = 1 (which would price tinier work as ever
        *slower*) must fall back to the α–β intercept, not fit p > 1."""
        from repro.measure.calibrate import fit_ceilings
        peak, steep = 2e11, EfficiencyModel(f_half=5e7, p=2.0)
        suite = [_mk(f"g{i}", f, 1e3, 0.0, f / (peak * steep.eff(f)),
                     "compute")
                 for i, f in enumerate((1e6, 1e7, 1e8, 1e9, 1e10))]
        calib = fit_ceilings(suite, self.BASE)
        assert calib.compute_eff.is_identity
        # and the model it does keep prices time monotone in F
        spec = calib.spec()
        times = [resource_times(WorkUnit("w", f, 0.0, 0.0), spec)[0]
                 for f in (1e2, 1e5, 1e8, 1e11)]
        assert times == sorted(times)

    def test_exact_alpha_beta_machine_keeps_intercept_model(self):
        """Data generated by t = α + F/peak must NOT grow a curve."""
        from repro.measure.calibrate import fit_ceilings
        a_c, peak = 1e-4, 1e11
        suite = [_mk(f"g{i}", f, 1e3, 0.0, a_c + f / peak, "compute")
                 for i, f in enumerate((1e9, 8e9, 5e10, 2e11))]
        calib = fit_ceilings(suite, self.BASE)
        assert calib.compute_eff.is_identity
        assert calib.alpha_compute == pytest.approx(a_c, rel=1e-6)
        assert calib.peak_flops == pytest.approx(peak, rel=1e-6)

    def test_v3_registry_roundtrip_carries_eff(self, tmp_path):
        from repro.measure.calibrate import fit_ceilings
        peak, em = 2e11, EfficiencyModel(f_half=5e7, p=0.7)
        suite = [_mk(f"gemm{i}", f, 1e3, 0.0, f / (peak * em.eff(f)),
                     "compute")
                 for i, f in enumerate((1e6, 1e7, 1e8, 1e9, 1e10))]
        calib = fit_ceilings(suite, self.BASE, name="effbox_cal")
        path = calib.save(str(tmp_path))
        d = json.loads(open(path).read())
        assert d["schema"] == CALIBRATION_SCHEMA == "repro.calibration/v3"
        assert set(d["compute_eff"]) == {"f_half", "p", "eff_min"}
        spec = spec_from_calibration(d)
        assert spec == calib.spec()
        assert spec.compute_eff == calib.compute_eff

    def test_v1_v2_read_compat_identity_eff(self, tmp_path):
        """Pre-v3 registry entries load with eff ≡ 1 (and v1 with α = 0)."""
        from repro.core.hardware import list_hardware, load_calibrated
        v1 = {"schema": "repro.calibration/v1", "name": "old1_cal",
              "base": "clx", "peak_flops": 2e11, "hbm_bw": 5e9,
              "net_bw": 8e8}
        v2 = {"schema": "repro.calibration/v2", "name": "old2_cal",
              "base": "clx", "peak_flops": 2e11, "hbm_bw": 5e9,
              "net_bw": 8e8, "alpha_compute": 3e-4, "alpha_network": 1e-5,
              "link_alphas": {"pod": 2e-5}, "extra_links": {"pod": 4e8}}
        for d in (v1, v2):
            (tmp_path / f"{d['name']}.json").write_text(json.dumps(d))
            spec = spec_from_calibration(d)
            assert spec.compute_eff.is_identity
            # the identity curve preserves the pre-v3 times bit-for-bit
            w = WorkUnit("w", 1e9, 1e6, 1e5, net_steps=6.0)
            t_c = (spec.alpha_compute if w.flops > 0 else 0.0) \
                + w.flops / spec.peak_flops
            assert resource_times(w, spec)[0] == t_c
        s1 = load_calibrated("old1_cal", str(tmp_path))
        assert s1.alpha_compute == 0.0
        s2 = load_calibrated("old2_cal", str(tmp_path))
        assert s2.alpha_compute == 3e-4
        assert s2.alpha_for("pod") == 2e-5
        listing = list_hardware(str(tmp_path))
        assert listing["old1_cal"] == listing["old2_cal"] == "calibrated"


# --- planner: auto selection, size-1 axes, golden CLI JSON --------------------


ALPHA_CAL = HardwareSpec(
    "alpha_cal", peak_flops=197e12, hbm_bw=819e9, net_bw=50e9,
    extra_links={"pod": 25e9}, alpha_network=1e-5,
    link_alphas={"pod": 5e-5})


class TestPlannerAlgoSelection:
    @staticmethod
    def _cfg(name="dlrm-mlp"):
        from repro.configs import get_config
        return get_config(name)

    def test_auto_is_default_and_selects_per_axis(self):
        from repro.launch.plan import plan
        plans = plan(self._cfg(), ALPHA_CAL, 16, batch=512)
        assert all(p.algorithm == "auto" for p in plans)
        assert all(p.dp_algo in coll.ALGORITHMS + ("-",) for p in plans)
        assert all(p.tp_algo in coll.ALGORITHMS + ("-",) for p in plans)

    def test_auto_never_ranks_worse_than_any_fixed_algorithm(self):
        from repro.launch.plan import best_step_time
        cfg = self._cfg()
        auto = best_step_time(cfg, ALPHA_CAL, 16, batch=512)
        for algo in coll.ALGORITHMS:
            fixed = best_step_time(cfg, ALPHA_CAL, 16, batch=512,
                                   algorithms=(algo,))
            assert auto <= fixed * (1 + 1e-12), algo

    def test_auto_flips_tree_to_ring_family_with_payload(self):
        """The acceptance-criterion flip, deterministic: small per-sync
        payloads pick the log-step tree, the MB-scale grad sync picks a
        bandwidth-optimal ring, and the reported flip payload separates
        them."""
        from repro.launch.plan import flip_points, plan
        cfg = self._cfg()
        plans = plan(cfg, ALPHA_CAL, 16, batch=512)
        by_mesh = {p.mesh: p for p in plans}
        p = by_mesh["dp16xtp1"]          # dp grad sync: params (MBs) -> ring
        assert p.dp_algo == "bidir_ring"
        from repro.launch.plan import param_counts
        flips = {(r["axis"], r["group_size"]): r
                 for r in flip_points(cfg, ALPHA_CAL, 16, batch=512)}
        r = flips[("dp", 16)]
        assert r["flip_payload_bytes"] is not None
        assert r["small_payload_algo"] == "tree"
        assert r["large_payload_algo"] == "bidir_ring"
        n_total, _ = param_counts(cfg)
        assert n_total * 4.0 > r["flip_payload_bytes"]   # grad sync above
        # a payload below the flip on the same axis must select tree
        algo, _ = coll.best_all_reduce(r["flip_payload_bytes"] / 10, 16,
                                       ALPHA_CAL.net_bw,
                                       ALPHA_CAL.alpha_network)
        assert algo == "tree"

    @pytest.mark.slow
    def test_qwen2_7b_auto_acceptance(self):
        """ISSUE 4 acceptance: on qwen2-7b with calibrated α > 0, auto
        selects tree below the flip payload (tiny per-sync act payloads)
        and a ring algorithm above it (the 7B-param grad sync)."""
        from repro.launch.plan import flip_points, plan
        # 32 MHA heads (vs the shipped 28/4 GQA) so tp = 16 stays a
        # head-safe split under the ISSUE 6 divisibility fix — the test
        # pins algorithm selection, not head feasibility
        cfg = self._cfg("qwen2-7b").replace(n_heads=32, n_kv_heads=32)
        # small global batch -> sub-MB per-sync act payloads on the tp axis
        plans = plan(cfg, ALPHA_CAL, 32, batch=16, seq=16)
        by_mesh = {p.mesh: p for p in plans}
        p = by_mesh["dp2xtp16"]
        assert p.dp_algo == "bidir_ring"     # GBs of grads: ring family wins
        assert p.tp_algo == "tree"           # sub-flip act payloads: tree
        flips = {(r["axis"], r["group_size"]): r
                 for r in flip_points(cfg, ALPHA_CAL, 32, batch=16)}
        r = flips[("tp", 16)]
        assert r["small_payload_algo"] == "tree"
        assert r["large_payload_algo"] == "bidir_ring"
        # the per-sync payload really sits below the reported flip...
        act_payload = (16.0 * 16 / 2) * cfg.d_model * 2
        assert act_payload < r["flip_payload_bytes"]
        # ...and the grad-sync payload above its axis's flip (if any)
        d = flips[("dp", 2)]
        assert d["flip_payload_bytes"] is None   # n=2: no tree advantage

    def test_size_one_axis_prices_zero_network_even_with_alpha(self):
        """Satellite bugfix pin: a size-1 mesh axis runs no collective, so
        it must contribute neither bytes nor α·steps — including under
        --pod-size routing and the auto selector."""
        from repro.launch.plan import plan
        cfg = self._cfg()
        for algorithms in (("auto",), ("ring",), ("tree",)):
            plans = plan(cfg, ALPHA_CAL, 8, batch=512,
                         algorithms=algorithms, pod_size=4)
            by_mesh = {p.mesh: p for p in plans}
            # pure-TP: the dp axis is size 1 -> all traffic is tp's
            p = by_mesh["dp1xtp8"]
            assert p.dp_algo == "-"
            tp_cost = coll.all_reduce(
                512.0 * cfg.mlp_widths[0] * 4, 8,
                p.tp_algo if algorithms == ("auto",) else
                coll.canonical_algorithm(algorithms[0]))
            scaled = tp_cost.scaled(2.0 * cfg.n_layers)
            want = float(scaled.time(ALPHA_CAL.bandwidth_for("pod"),
                                     ALPHA_CAL.alpha_for("pod")))
            assert p.t_network == pytest.approx(want, rel=1e-9)
            # pure-DP: the tp axis is size 1 -> all traffic is dp's
            q = by_mesh["dp8xtp1"]
            assert q.tp_algo == "-"
            assert q.net_steps > 0      # dp's own hops still counted

    def test_cli_algo_all_prints_flip_points(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "8", "--algo",
                     "all"]) == 0
        out = capsys.readouterr().out
        assert "flip points" in out
        # datasheet α = 0: one algorithm dominates every payload
        assert "no flip" in out

    def test_cli_algo_aliases_accepted(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "8", "--algo",
                     "bidir"]) == 0
        out = capsys.readouterr().out
        assert "bidir" in out


GOLDEN_TOP_KEYS = {"arch", "chips", "batch", "seq", "pod_size", "algo",
                   "algorithms", "flip_points", "hardware", "plans", "best",
                   # ISSUE 5: the pipeline-parallel third axis
                   "max_pp",
                   # ISSUE 6: ZeRO search space + the capacity-cut summary
                   "zero_stages", "remat", "capacity",
                   # ISSUE 9: expert parallelism + interleaved 1F1B
                   "max_ep", "interleave"}
GOLDEN_PLAN_KEYS = {"mesh", "chips", "algo_label", "dp", "tp", "algorithm",
                    "flops", "mem_bytes", "net_bytes", "t_compute",
                    "t_memory", "t_network", "runtime", "bottleneck",
                    "peak_fraction", "net_steps", "dp_link", "tp_link",
                    "dp_algo", "tp_algo", "runtime_lo", "runtime_hi",
                    # ISSUE 5: pp axis + 1F1B microbatching ride along
                    "pp", "microbatches", "pp_link",
                    # ISSUE 6: memory feasibility rides along
                    "zero_stage", "hbm_bytes", "hbm_used_gb", "fits",
                    "remat",
                    # ISSUE 9: ep axis + interleaved virtual stages
                    "ep", "ep_link", "vstages",
                    # ISSUE 10: failure-aware goodput terms (exact zeros /
                    # goodput 1.0 when failures are unmodeled)
                    "goodput", "ckpt_overhead_s", "rework_s", "restart_s",
                    "ckpt_interval_s"}
GOLDEN_FLIP_KEYS = {"axis", "group_size", "link", "bandwidth", "alpha",
                    "flip_payload_bytes", "small_payload_algo",
                    "large_payload_algo"}


class TestGoldenCliJson:
    def _json(self, capsys, *extra):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "8", "--json",
                     *extra]) == 0
        return json.loads(capsys.readouterr().out)

    def test_stable_key_set(self, capsys):
        d = self._json(capsys)
        assert set(d) == GOLDEN_TOP_KEYS
        assert d["algo"] == "auto"
        for p in d["plans"] + [d["best"]]:
            assert set(p) == GOLDEN_PLAN_KEYS
        for r in d["flip_points"]:
            assert set(r) == GOLDEN_FLIP_KEYS
        # hardware spec rides along with its efficiency model
        assert d["hardware"]["compute_eff"] == {"f_half": 0.0, "p": 1.0,
                                                "eff_min": 0.0}

    def test_algo_all_json_flip_fields(self, capsys):
        d = self._json(capsys, "--algo", "all")
        assert d["algo"] == "all"
        assert sorted(d["algorithms"]) == sorted(coll.ALGORITHMS)
        assert d["flip_points"], "flip report must not be empty"
        meshes = {(p["mesh"], p["algorithm"]) for p in d["plans"]}
        assert len(meshes) == len(d["plans"])    # one row per (mesh, algo)


# --- BENCH regression: the decode-gap acceptance ------------------------------


class TestBenchDecodeRegression:
    """Pins the committed BENCH_ridgeline.json calibration quality.

    The committed artifact is regenerated by `make ci` (calibrate smoke +
    benchmarks/run.py); these bounds are the ISSUE 4 acceptance criteria —
    the decode step's |rel error| must sit below 0.25 (down from the ~40%
    under-prediction ROADMAP recorded after PR 3) and the step-validation
    median must not regress past the old decode-defined level.
    """

    @pytest.fixture()
    def bench(self):
        path = os.path.join(_REPO_ROOT, "BENCH_ridgeline.json")
        if not os.path.exists(path):
            pytest.skip("no BENCH_ridgeline.json baseline")
        return json.loads(open(path).read())

    def test_decode_validation_below_quarter(self, bench):
        cal = bench.get("calibration") or {}
        if not cal:
            pytest.skip("baseline has no calibration section")
        decodes = [c.get("decode_validation") for c in cal.values()
                   if c.get("decode_validation")]
        assert decodes, "calibration records no decode validation point"
        for d in decodes:
            assert abs(d["rel_error"]) < 0.25, d

    def test_step_validation_median_does_not_regress(self, bench):
        cal = bench.get("calibration") or {}
        if not cal:
            pytest.skip("baseline has no calibration section")
        for name, c in cal.items():
            med = (c.get("validation") or {}).get("median_abs_rel_error")
            assert med is not None, name
            # pre-ISSUE-4 the decode point alone sat at ~0.40; the median
            # must stay clear of that regime
            assert med < 0.40, (name, med)
