"""α–β time model end to end: hardware α fields, collective latency,
α-aware analysis/sweeps, and the planner's per-axis link routing.

These are the regression tests for ISSUE 3: the network time model is
``α·steps + B_N/bw(axis)`` instead of bandwidth-only, per-link bandwidths
are first-class, and the planner prices each mesh axis on the link it
actually rides.
"""
import math

import numpy as np
import pytest

from repro.core import sweep as sweep_mod
from repro.core.hardware import CLX, TPU_V5E, HardwareSpec
from repro.core.ridgeline import (Resource, WorkUnit, analyze,
                                  analyze_multilink, classify_by_times,
                                  resource_times)
from repro.distributed import collectives as coll

ALPHA_HW = HardwareSpec(
    "alpha_box", peak_flops=1e12, hbm_bw=1e11, net_bw=1e10,
    extra_links={"pod": 2.5e9}, alpha_compute=1e-4, alpha_memory=2e-5,
    alpha_network=1e-6, link_alphas={"pod": 5e-6})


# --- hardware spec -----------------------------------------------------------


class TestHardwareAlpha:
    def test_defaults_are_bandwidth_only(self):
        for hw in (CLX, TPU_V5E):
            assert hw.alpha_compute == hw.alpha_memory == hw.alpha_network \
                == 0.0
            assert hw.model_rel_error == 0.0

    def test_bandwidth_for_unknown_link_is_actionable(self):
        with pytest.raises(KeyError) as exc:
            TPU_V5E.bandwidth_for("dci")
        msg = exc.value.args[0]
        assert "tpu_v5e" in msg and "'dci'" in msg
        assert "pod" in msg and "available links" in msg
        # spec with no extra links still names itself and the primary
        with pytest.raises(KeyError, match="clx"):
            CLX.bandwidth_for("pod")

    def test_primary_link_aliases(self):
        for alias in (None, "ici", "net"):
            assert ALPHA_HW.bandwidth_for(alias) == ALPHA_HW.net_bw
            assert ALPHA_HW.alpha_for(alias) == ALPHA_HW.alpha_network

    def test_alpha_for_falls_back_and_raises(self):
        assert ALPHA_HW.alpha_for("pod") == 5e-6
        no_override = HardwareSpec("x", 1e12, 1e11, 1e10,
                                   extra_links={"pod": 1e9},
                                   alpha_network=3e-6)
        assert no_override.alpha_for("pod") == 3e-6
        with pytest.raises(KeyError, match="available links"):
            ALPHA_HW.alpha_for("dci")


# --- collectives -------------------------------------------------------------


class TestCollectiveTime:
    def test_alpha_beta_time(self):
        c = coll.all_reduce(1e9, 8, "ring")          # steps = 14
        assert c.time(50e9) == pytest.approx(c.wire_bytes / 50e9)
        assert c.time(50e9, alpha=1e-5) == pytest.approx(
            14 * 1e-5 + c.wire_bytes / 50e9)

    def test_tree_fewer_steps_wins_at_small_payload(self):
        """With latency, log-step trees beat rings on tiny payloads."""
        ring = coll.all_reduce(1e3, 64, "ring")
        tree = coll.all_reduce(1e3, 64, "tree")
        alpha, bw = 1e-5, 50e9
        assert tree.time(bw, alpha) < ring.time(bw, alpha)
        # bandwidth-only, the ring's smaller wire volume wins
        assert ring.time(bw) < tree.time(bw)

    def test_cost_composition(self):
        a = coll.all_reduce(1e6, 4, "ring")
        b = coll.reduce_scatter(2e6, 4)
        s = a + b
        assert s.wire_bytes == pytest.approx(a.wire_bytes + b.wire_bytes)
        assert s.steps == pytest.approx(a.steps + b.steps)
        k = a.scaled(3.0)
        assert k.wire_bytes == pytest.approx(3 * a.wire_bytes)
        assert k.steps == pytest.approx(3 * a.steps)

    def test_strategy_costs_carry_steps(self):
        dp = coll.dp_grad_sync(1e8, 8, "ring")
        assert dp.steps == 14.0
        tp = coll.tp_act_sync(1e6, 4, 2.0, 10, "ring")
        assert tp.steps == pytest.approx(2 * 10 * 6.0)     # 2(n-1)=6 per sync
        assert tp.wire_bytes == pytest.approx(
            2 * 10 * coll.all_reduce_bytes(1e6, 4, "ring"))


# --- α-aware ridgeline -------------------------------------------------------


class TestAlphaAwareModel:
    def test_times_include_alpha(self):
        w = WorkUnit("w", flops=1e9, mem_bytes=1e8, net_bytes=1e7,
                     net_steps=14.0)
        t_c, t_m, t_n = resource_times(w, ALPHA_HW)
        assert t_c == pytest.approx(1e-4 + 1e9 / 1e12)
        assert t_m == pytest.approx(2e-5 + 1e8 / 1e11)
        assert t_n == pytest.approx(14 * 1e-6 + 1e7 / 1e10)
        a = analyze(w, ALPHA_HW)
        assert a.runtime == pytest.approx(max(t_c, t_m, t_n))

    def test_alpha_applies_only_with_traffic(self):
        """A resource with zero quantity pays no α (else everything ties)."""
        w = WorkUnit("w", flops=0.0, mem_bytes=1e8, net_bytes=0.0)
        t_c, t_m, t_n = resource_times(w, ALPHA_HW)
        assert t_c == 0.0 and t_n == 0.0
        assert classify_by_times(w, ALPHA_HW) == Resource.MEMORY

    def test_latency_flips_bottleneck(self):
        """A tiny collective is latency-, not bandwidth-, bound."""
        w = WorkUnit("tiny_ar", flops=1e6, mem_bytes=1e5, net_bytes=1e3,
                     net_steps=14.0)
        bandwidth_only = HardwareSpec("b", 1e12, 1e11, 1e10)
        assert classify_by_times(w, bandwidth_only) == Resource.COMPUTE
        latency = HardwareSpec("l", 1e12, 1e11, 1e10, alpha_network=1e-5)
        assert classify_by_times(w, latency) == Resource.NETWORK

    def test_sweep_matches_scalar_alpha_model(self):
        f = np.array([1e9, 1e3, 0.0])
        bm = np.array([1e8, 1e3, 0.0])
        bn = np.array([1e7, 1e3, 0.0])
        ns = np.array([14.0, 6.0, 0.0])
        res = sweep_mod.sweep(f, bm, bn, ALPHA_HW, net_steps=ns)
        for i in range(3):
            w = WorkUnit("w", f[i], bm[i], bn[i], net_steps=ns[i])
            a = analyze(w, ALPHA_HW)
            assert res.runtime[i] == pytest.approx(a.runtime)
            assert res.labels()[i] == a.bottleneck.value

    def test_sweep_string_spec_and_explicit_alpha(self):
        res = sweep_mod.sweep(1e9, 1e3, 1e3, CLX, net_steps=10.0,
                              alpha_network=1e-3)
        assert res.t_network == pytest.approx(1e-2 + 1e3 / CLX.net_bw)

    def test_multilink_uses_per_link_alpha(self):
        w_ici = WorkUnit("w", 1e12, 1e9, 1e9, net_steps=10.0)
        w_pod = WorkUnit("w", 1e12, 1e9, 1e8, net_steps=4.0)
        a = analyze_multilink({"ici": w_ici, "pod": w_pod}, ALPHA_HW)
        t_ici = 10 * 1e-6 + 1e9 / 1e10
        t_pod = 4 * 5e-6 + 1e8 / 2.5e9
        assert a.t_network == pytest.approx(max(t_ici, t_pod))

    def test_negative_net_steps_rejected(self):
        with pytest.raises(ValueError):
            WorkUnit("w", 1.0, 1.0, 1.0, net_steps=-1.0)


# --- crossover guard (satellite) ---------------------------------------------


class TestCrossoverGuard:
    def test_log_x_with_nonpositive_samples_does_not_raise(self):
        # grid starts at 0 — used to raise `math domain error`
        xs = np.array([0.0, 1.0, 2.0, 4.0])
        t_a = np.array([0.5, 0.5, 0.5, 0.5])
        t_b = np.array([0.0, 1.0, 2.0, 4.0])
        xc = sweep_mod.crossover(xs, t_a, t_b, log_x=True)
        # crossing bracket touches x=0 -> linear fallback, exact at 0.5
        assert xc == pytest.approx(0.5)

    def test_log_x_crossing_inside_nonpositive_bracket(self):
        xs = np.array([-1.0, 1.0])
        xc = sweep_mod.crossover(xs, [1.0, -1.0], [0.0, 0.0], log_x=True)
        assert xc == pytest.approx(0.0)              # linear fallback

    def test_log_x_still_log_interpolates_on_positive_grids(self):
        xs = np.array([1.0, 100.0])
        # difference linear in log10(x): crosses exactly at x = 10
        xc = sweep_mod.crossover(xs, [1.0, -1.0], [0.0, 0.0], log_x=True)
        assert xc == pytest.approx(10.0)


# --- planner: per-axis links + uncertainty band ------------------------------


class TestPlannerPodAxis:
    @staticmethod
    def _plans(pod_size=None, **kw):
        from repro.configs import get_config
        from repro.launch.plan import plan
        # 32 MHA heads so dp1xtp32..dp32xtp1 all stay head-safe splits
        # under the ISSUE 6 divisibility fix, and capacity checking off:
        # these tests pin the α–β pod-link pricing, and batch 32 × seq
        # 4096 at ZeRO-0 would not fit a 16 GB v5e
        cfg = get_config("qwen2-7b").replace(n_heads=32, n_kv_heads=32)
        return plan(cfg, TPU_V5E, 32, batch=32, seq=4096,
                    pod_size=pod_size, check_capacity=False, **kw)

    @pytest.mark.slow
    def test_dp_grad_sync_priced_on_pod_link(self):
        """Regression: pure-DP across 2 pods used to be priced at full ICI.

        Without pod routing the 32-way dp grad sync rides 50 GB/s and
        dp32xtp1 out-ranks dp2xtp16; priced at the 25 GB/s `pod` link the
        ranking flips.
        """
        def order(plans):
            rank = {p.mesh: i for i, p in enumerate(plans)}
            return rank["dp32xtp1"], rank["dp2xtp16"]

        r_dp, r_tp = order(self._plans())
        assert r_dp < r_tp                      # the buggy-looking ranking
        r_dp, r_tp = order(self._plans(pod_size=16))
        assert r_tp < r_dp                      # fixed: intra-pod TP wins

        by_mesh = {p.mesh: p for p in self._plans(pod_size=16)}
        assert by_mesh["dp32xtp1"].dp_link == "pod"
        assert by_mesh["dp32xtp1"].tp_link == "ici"
        assert by_mesh["dp1xtp32"].tp_link == "pod"
        assert by_mesh["dp2xtp16"].tp_link == "ici"    # tp fits in one pod
        # per-axis pricing reproduced from the published terms: tp=1 sends
        # nothing, so all wire bytes are the dp sync riding the pod link
        p = by_mesh["dp32xtp1"]
        assert p.t_network == pytest.approx(
            p.net_bytes / TPU_V5E.bandwidth_for("pod"), rel=1e-6)

    @pytest.mark.slow
    def test_pod_size_none_is_previous_behaviour(self):
        a = {p.mesh: p.runtime for p in self._plans()}
        assert all(p.dp_link == "ici" and p.tp_link == "ici"
                   for p in self._plans())
        assert min(a.values()) > 0

    def test_pod_size_without_pod_link_raises_actionable(self):
        from repro.configs import get_config
        from repro.launch.plan import plan
        cfg = get_config("dlrm-mlp")
        with pytest.raises(KeyError, match="clx"):
            plan(cfg, CLX, 32, batch=512, pod_size=16)

    def test_uncertainty_band_from_model_rel_error(self):
        from repro.configs import get_config
        from repro.launch.plan import plan
        cfg = get_config("dlrm-mlp")
        hw = HardwareSpec("cal_box", 1e12, 1e11, 1e10,
                          model_rel_error=0.2)
        plans = plan(cfg, hw, 8, batch=512)
        for p in plans:
            assert p.runtime_lo == pytest.approx(p.runtime * 0.8)
            assert p.runtime_hi == pytest.approx(p.runtime * 1.2)
        # datasheet spec (no measured error) -> degenerate band
        for p in plan(cfg, CLX, 8, batch=512):
            assert p.runtime_lo == p.runtime == p.runtime_hi

    def test_band_shown_in_table(self):
        from repro.configs import get_config
        from repro.launch.plan import format_plan_table, plan
        cfg = get_config("dlrm-mlp")
        hw = HardwareSpec("cal_box", 1e12, 1e11, 1e10, model_rel_error=0.1)
        table = format_plan_table(plan(cfg, hw, 8, batch=512))
        assert "band ms" in table
        table_plain = format_plan_table(plan(cfg, CLX, 8, batch=512))
        assert "band ms" not in table_plain


# --- MLP param accounting parity (satellite) ---------------------------------


class TestMlpParamParity:
    @pytest.mark.slow
    def test_closed_form_matches_eval_shape_for_every_mlp_config(self):
        """launch/plan's jax-free MLP count == launch/specs eval_shape count."""
        from repro.configs import get_config, get_reduced, list_archs
        from repro.launch.plan import param_counts as closed_form
        from repro.launch.specs import param_counts as exact

        mlp_cfgs = []
        for arch in list_archs():
            cfg = get_config(arch)
            if cfg.family != "mlp":
                continue
            mlp_cfgs += [cfg, get_reduced(arch)]
        # plus shapes exercising uneven towers
        base = mlp_cfgs[0]
        mlp_cfgs += [
            base.replace(n_layers=2, mlp_widths=(128, 64), d_model=128),
            base.replace(n_layers=5, mlp_widths=(64, 96, 32, 96, 16),
                         d_model=64),
        ]
        assert mlp_cfgs
        for cfg in mlp_cfgs:
            total, active = closed_form(cfg)
            total_x, active_x = exact(cfg)
            assert total == pytest.approx(total_x), cfg.mlp_widths
            assert active == pytest.approx(active_x), cfg.mlp_widths
