"""repro.analysis: unit algebra properties, fixture corpus, runtime contracts.

The two acceptance properties pinned here: every rule family provably fires
on its known-bad fixture file (``tests/fixtures/analysis/``), and the
analyzer exits 0 on the real ``src/repro`` tree — together they keep the CI
gate honest (a gate that can't fail proves nothing; a gate that fails on
main blocks everyone).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import contracts, report, runner
from repro.analysis.contracts import (ShapeContractError, parse_contract,
                                      shape_contract)
from repro.analysis.units import (BYTES, BYTES_PER_S, DIMENSIONLESS, FLOPS,
                                  FLOPS_PER_S, NAMED_UNITS, SECONDS, Unit,
                                  UnitError, parse_unit)
from tests._hypothesis_compat import given, settings, st

HERE = os.path.dirname(__file__)
FIXTURES = os.path.join(HERE, "fixtures", "analysis")
SRC_REPRO = os.path.join(HERE, os.pardir, "src", "repro")

UNIT_NAMES = sorted(NAMED_UNITS)


# --- unit algebra (property-tested) -------------------------------------------


@settings(max_examples=50)
@given(a=st.sampled_from(UNIT_NAMES), b=st.sampled_from(UNIT_NAMES))
def test_commensurability_is_symmetric(a, b):
    ua, ub = parse_unit(a), parse_unit(b)
    assert ua.commensurable(ub) == ub.commensurable(ua)


@settings(max_examples=50)
@given(a=st.sampled_from(UNIT_NAMES), b=st.sampled_from(UNIT_NAMES))
def test_mul_commutes_and_div_inverts(a, b):
    ua, ub = parse_unit(a), parse_unit(b)
    assert ua * ub == ub * ua
    assert (ua * ub) / ub == ua
    assert ua / ua == DIMENSIONLESS


@settings(max_examples=50)
@given(name=st.sampled_from(UNIT_NAMES))
def test_named_units_round_trip_through_str(name):
    u = parse_unit(name)
    assert parse_unit(str(u)) == u


def test_division_produces_the_model_rates():
    # the three derivations the cost model lives on
    assert BYTES / BYTES_PER_S == SECONDS
    assert FLOPS / FLOPS_PER_S == SECONDS
    assert BYTES / SECONDS == BYTES_PER_S
    # the ridge point is flops/byte — unnamed but printable and parseable
    ridge = FLOPS / BYTES
    assert parse_unit(str(ridge)) == ridge
    assert not ridge.commensurable(FLOPS)


def test_unit_power_and_errors():
    assert SECONDS ** 2 / SECONDS == SECONDS
    assert SECONDS ** 0 == DIMENSIONLESS
    with pytest.raises(UnitError, match="vocabulary"):
        parse_unit("furlongs")
    with pytest.raises(UnitError):
        Unit.of(s=1) ** 1.5


# --- suppressions -------------------------------------------------------------


def test_suppression_round_trip(tmp_path):
    src = ("def f(step_s, wire_bytes):\n"
           "    bad = step_s + wire_bytes\n"
           "    ok = step_s + wire_bytes  # unit: ignore[testing the table]\n")
    p = tmp_path / "mod.py"
    p.write_text(src)
    findings, suppressed = runner.check_file(str(p))
    assert [f.rule for f in findings] == ["unit-mismatch"]
    assert findings[0].line == 2
    assert len(suppressed) == 1
    assert suppressed[0]["suppressed_reason"] == "testing the table"
    assert suppressed[0]["line"] == 3


def test_empty_suppression_is_a_finding(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("x = 1  # state: ignore[]\n")
    findings, _ = runner.check_file(str(p))
    assert [f.rule for f in findings] == ["bad-suppression"]
    assert "needs a reason" in findings[0].message


def test_suppression_only_silences_its_family(tmp_path):
    # a state suppression must not hide a unit finding on the same line
    p = tmp_path / "mod.py"
    p.write_text("def f(step_s, wire_bytes):\n"
                 "    return step_s + wire_bytes  # state: ignore[wrong family]\n")
    findings, suppressed = runner.check_file(str(p))
    assert [f.rule for f in findings] == ["unit-mismatch"]
    assert suppressed == []


# --- fixture corpus: every rule family provably fires -------------------------


def _rules(path):
    findings, suppressed = runner.check_file(path)
    return findings, suppressed, {f.rule for f in findings}


def test_units_rules_fire_on_fixture():
    findings, suppressed, rules = _rules(os.path.join(FIXTURES, "bad_units.py"))
    assert {"unit-mismatch", "unit-bad-assign", "unit-bad-arg",
            "unit-bad-return", "bad-suppression"} <= rules
    # add, sub, and compare mismatches are distinct sites
    assert sum(f.rule == "unit-mismatch" for f in findings) >= 3
    # the goodput contract: a dimensionless delivered-fraction never mixes
    # with (or gets assigned from) seconds
    assert any(f.rule == "unit-mismatch"
               and "dimensionless" in f.message.lower()
               and "seconds" in f.message.lower() for f in findings)
    assert any(f.rule == "unit-bad-assign"
               and "goodput" in f.message.lower() for f in findings)
    # the reasoned suppression round-trips into the suppressed list
    assert any("reasoned suppression" in s["suppressed_reason"]
               for s in suppressed)


def test_contract_rules_fire_on_fixture():
    findings, _, rules = _rules(os.path.join(FIXTURES, "bad_contract.py"))
    assert {"contract-bad-spec", "contract-arity", "contract-unknown-param",
            "contract-duplicate-param"} <= rules
    # the ep-kernel fixture (contract names `ep`, signature disagrees)
    # fires too — ISSUE 9 pins the ep-axis kernels into the corpus
    src = open(os.path.join(FIXTURES, "bad_contract.py")).read().splitlines()
    ep_def = next(i for i, t in enumerate(src, start=1)
                  if "def ep_dispatch_names_wrong_param" in t)
    assert any(f.rule == "contract-unknown-param"
               and abs(f.line - ep_def) <= 2 for f in findings)


def test_state_rules_fire_on_fixture():
    findings, _, rules = _rules(os.path.join(FIXTURES, "bad_state.py"))
    assert {"state-unlocked-global", "state-unlocked-mutation"} <= rules
    # the lock-held writes and the __init__ write must NOT fire
    flagged_lines = {f.line for f in findings}
    src = open(os.path.join(FIXTURES, "bad_state.py")).read().splitlines()
    for lineno, text in enumerate(src, start=1):
        if "must NOT fire" in text or "exempt" in text:
            continue
        if "with _LOCK" in text:
            assert not any(lineno < ln <= lineno + 2 for ln in flagged_lines)


def test_analyzer_clean_on_real_tree(capsys):
    rc = runner.main([SRC_REPRO])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 finding(s)" in out


def test_cli_json_schema(capsys):
    rc = runner.main(["--json", os.path.join(FIXTURES, "bad_state.py")])
    assert rc == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["schema"] == report.SCHEMA
    assert doc["n_findings"] == len(doc["findings"]) > 0
    for f in doc["findings"]:
        assert set(f) == {"path", "line", "col", "rule", "family", "message"}


@pytest.mark.slow
def test_module_entrypoint_exit_codes():
    env = dict(os.environ, PYTHONPATH="src")
    ok = subprocess.run([sys.executable, "-m", "repro.analysis", "src/repro"],
                        cwd=os.path.join(HERE, os.pardir), env=env,
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run([sys.executable, "-m", "repro.analysis",
                          os.path.join("tests", "fixtures", "analysis")],
                         cwd=os.path.join(HERE, os.pardir), env=env,
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "bad_units.py" in bad.stdout


# --- runtime shape contracts --------------------------------------------------


@pytest.fixture
def checking_on():
    prev = contracts.set_checking(True)
    yield
    contracts.set_checking(prev)


def test_parse_contract_accepts_the_shipped_grammar():
    c = parse_contract("(c,), (c,a) -> (c,a)")
    assert [s.axes for s in c.inputs] == [("c",), ("c", "a")]
    c = parse_contract("batch:(*g), dp:(*g) -> (*g)")
    assert all(s.is_group for s in c.inputs)
    assert c.inputs[0].param == "batch"
    with pytest.raises(ValueError, match="->"):
        parse_contract("(c,)")
    with pytest.raises(ValueError, match="not bound"):
        parse_contract("(c,) -> (d,)")


def test_named_axis_contract_enforced(checking_on):
    @shape_contract("(c,), (c,a) -> (c,a)")
    def outer(wire, per_algo):
        return wire[:, None] * per_algo

    out = outer(np.zeros(3), np.zeros((3, 4)))
    assert out.shape == (3, 4)
    outer(np.zeros(1), np.zeros((3, 4)))      # size-1 broadcasts fine
    with pytest.raises(ShapeContractError, match="axis 'c'"):
        outer(np.zeros(3), np.zeros((5, 4)))


def test_group_contract_enforced_on_real_kernel(checking_on):
    from repro.distributed import collectives
    wire, steps, idx = collectives.best_all_reduce_grid(
        np.full(4, 1e9), np.full(4, 8.0), 1e11, 1e-6)
    assert wire.shape == steps.shape == idx.shape == (4,)
    with pytest.raises(ShapeContractError):
        collectives.best_all_reduce_grid(
            np.full(3, 1e9), np.full(4, 8.0), 1e11, 1e-6)


def test_contract_disabled_is_transparent():
    prev = contracts.set_checking(False)
    try:
        from repro.distributed import collectives
        with pytest.raises(ValueError):
            # numpy itself raises eventually, but no ShapeContractError
            try:
                collectives.best_all_reduce_grid(
                    np.full(3, 1e9), np.full(4, 8.0), 1e11, 1e-6)
            except ShapeContractError:  # pragma: no cover
                pytest.fail("contract fired while disabled")
    finally:
        contracts.set_checking(prev)


def test_wrapper_preserves_identity_and_exposes_contract():
    from repro.distributed import collectives
    fn = collectives.best_all_reduce_grid
    assert fn.__name__ == "best_all_reduce_grid"
    assert fn.__wrapped__ is not None
    assert fn.__shape_contract__.spec.startswith("(*g)")


def test_bad_contract_raises_at_decoration_time():
    with pytest.raises(ValueError, match="not bound"):
        @shape_contract("(c,) -> (d,)")
        def f(x):
            return x
    with pytest.raises(ValueError, match="does not take"):
        @shape_contract("q:(c,) -> (c,)")
        def g(x):
            return x
