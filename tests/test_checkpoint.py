"""Checkpointing: roundtrip, atomicity, GC, async, restart determinism,
elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import COMMIT_MARKER, Checkpointer
from repro.checkpoint.elastic import remap_data_configs, restore_on_mesh
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, make_stream
from repro.optim.optimizer import AdamW
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state

KEY = jax.random.PRNGKey(3)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.float32(3.5)}}


class TestRoundtrip:
    def test_save_restore_identical(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        t = _tree()
        ck.save(7, t)
        restored, step = ck.restore(t)
        assert step == 7
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     t, restored)

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(), async_=True)
        ck.wait()
        assert ck.latest_step() == 1

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree())
        ck.save(2, _tree())
        os.remove(os.path.join(str(tmp_path), "step_000000002", COMMIT_MARKER))
        assert ck.latest_step() == 1
        restored, step = ck.restore(_tree())
        assert step == 1

    def test_keep_n_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree())
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == [3, 4]

    def test_restore_missing_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            ck.restore(_tree())


class TestIntegrity:
    """Corruption of *committed* checkpoints: detect, quarantine, fall back."""

    def _shard(self, root, step):
        d = os.path.join(str(root), f"step_{step:09d}")
        name = next(n for n in sorted(os.listdir(d))
                    if n.startswith("shard_"))
        return os.path.join(d, name)

    def test_truncated_shard_skipped_by_latest_step(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree())
        ck.save(2, _tree())
        with open(self._shard(tmp_path, 2), "w"):
            pass                              # truncate to zero bytes
        assert ck.latest_step() == 1
        restored, step = ck.restore(_tree())
        assert step == 1

    def test_bitflip_quarantined_and_fallback(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree())
        ck.save(2, _tree())
        path = self._shard(tmp_path, 2)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:          # silent bitrot mid-file
            f.seek(size // 2)
            f.write(b"\xff\x00\xff\x00")
        assert ck.latest_step() == 2          # cheap scan cannot see it
        restored, step = ck.restore(_tree())
        assert step == 1                      # crc32 caught it, fell back
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     _tree(), restored)
        # the bad step is quarantined (kept as evidence), never rescanned
        assert any(".quarantined_" in n for n in os.listdir(tmp_path))
        assert ck.latest_step() == 1

    def test_explicit_corrupt_step_raises(self, tmp_path):
        from repro.checkpoint.checkpointer import CheckpointCorruptionError
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree())
        ck.save(2, _tree())
        path = self._shard(tmp_path, 2)
        with open(path, "r+b") as f:
            f.write(b"\x00\x00\x00\x00")
        # the caller asked for step 2's exact bytes: substituting step 1
        # silently would be worse than failing
        with pytest.raises(CheckpointCorruptionError):
            ck.restore(_tree(), step=2)

    def test_all_corrupt_raises_not_loops(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree())
        with open(self._shard(tmp_path, 1), "r+b") as f:
            f.write(b"\x00\x00\x00\x00")
        with pytest.raises(FileNotFoundError):
            ck.restore(_tree())

    def test_quarantined_dirs_do_not_break_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        ck.save(1, _tree())
        with open(self._shard(tmp_path, 1), "r+b") as f:
            f.write(b"\x00\x00\x00\x00")
        with pytest.raises(FileNotFoundError):
            ck.restore(_tree())               # quarantines step 1
        for s in (2, 3, 4):
            ck.save(s, _tree())               # _gc walks the dir again
        assert ck.latest_step() == 4

    def test_checksums_recorded_in_manifest(self, tmp_path):
        import json as json_mod
        ck = Checkpointer(str(tmp_path))
        ck.save(5, _tree())
        d = os.path.join(str(tmp_path), "step_000000005")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json_mod.load(f)
        assert meta["checksums"]              # one entry per shard
        for name in meta["checksums"]:
            assert os.path.exists(os.path.join(d, name))


@pytest.mark.slow
class TestRestartDeterminism:
    """train(2N) == train(N) -> save -> restore -> train(N): bitwise."""

    def test_bitwise_resume(self, tmp_path):
        cfg = get_reduced("smollm-135m").replace(compute_dtype=jnp.float32)
        opt = AdamW(learning_rate=1e-2)
        step_fn = jax.jit(build_train_step(cfg, opt, TrainStepConfig()))
        stream = make_stream(cfg, DataConfig(seed=5, global_batch=2, seq_len=16))

        def run(state, lo, hi):
            for s in range(lo, hi):
                state, _ = step_fn(state, jax.tree.map(
                    jnp.asarray, stream.batch(s)))
            return state

        straight = run(init_train_state(KEY, cfg, opt), 0, 6)

        ck = Checkpointer(str(tmp_path))
        half = run(init_train_state(KEY, cfg, opt), 0, 3)
        ck.save(3, half)
        restored, step = ck.restore(half)
        resumed = run(restored, step, 6)

        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)),
            straight.params, resumed.params)


class TestElastic:
    @pytest.mark.slow
    def test_restore_on_different_mesh(self, tmp_path):
        """Save unsharded, restore with shardings for a (1,1) mesh — the
        mesh-shape-independence contract (full logical arrays on disk)."""
        from repro.launch.mesh import make_mesh
        from repro.train.loop import model_param_specs
        cfg = get_reduced("smollm-135m").replace(compute_dtype=jnp.float32)
        opt = AdamW()
        state = init_train_state(KEY, cfg, opt)
        ck = Checkpointer(str(tmp_path))
        ck.save(1, state.params)

        mesh = make_mesh((1, 1), ("data", "model"))
        restored, _ = restore_on_mesh(ck, state.params,
                                      model_param_specs(cfg), mesh)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state.params, restored)

    def test_remap_data_configs(self):
        old = DataConfig(global_batch=16, n_hosts=4, host_id=0)
        new = remap_data_configs(old, 2)
        assert [c.host_id for c in new] == [0, 1]
        assert all(c.host_batch == 8 for c in new)
        with pytest.raises(ValueError):
            remap_data_configs(DataConfig(global_batch=10), 4)
