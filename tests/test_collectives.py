"""Analytic collective cost models: algebraic identities + vectorization."""
import math

import numpy as np
import pytest

from repro.distributed import collectives as coll


class TestRingAllReduce:
    def test_matches_2n1_over_n(self):
        for n in (2, 4, 8, 16, 256):
            got = coll.all_reduce_bytes(1e9, n, "ring")
            assert got == pytest.approx(2.0 * (n - 1) / n * 1e9)

    def test_large_n_asymptote_is_2x_payload(self):
        assert coll.all_reduce_bytes(1e9, math.inf, "ring") == \
            pytest.approx(2e9)

    def test_n1_degenerates_to_zero(self):
        for algo in coll.ALGORITHMS:
            c = coll.all_reduce(1e9, 1, algo)
            assert c.wire_bytes == 0.0 and c.steps == 0.0
        assert coll.reduce_scatter(1e9, 1).wire_bytes == 0.0
        assert coll.all_gather(1e9, 1).wire_bytes == 0.0
        assert coll.all_to_all(1e9, 1).wire_bytes == 0.0

    def test_steps(self):
        assert coll.all_reduce(1.0, 8, "ring").steps == 14           # 2(n-1)
        assert coll.all_reduce(1.0, 8, "bidir_ring").steps == 7
        assert coll.all_reduce(1.0, 8, "tree").steps == 6            # 2log2 n


class TestComposition:
    def test_rs_plus_ag_is_ring_allreduce(self):
        """Ring all-reduce *is* reduce-scatter + all-gather of the payload."""
        p = np.array([1e6, 3e7, 5e9])
        n = np.array([2, 7, 64])
        composed = (coll.reduce_scatter(p, n).wire_bytes
                    + coll.all_gather(p, n).wire_bytes)
        np.testing.assert_allclose(
            composed, coll.all_reduce_bytes(p, n, "ring"))

    def test_bidir_halves_ring(self):
        assert coll.all_reduce_bytes(8e8, 16, "bidir_ring") == \
            pytest.approx(coll.all_reduce_bytes(8e8, 16, "ring") / 2)

    def test_tree_is_n_independent(self):
        assert coll.all_reduce_bytes(1e9, 4, "tree") == \
            coll.all_reduce_bytes(1e9, 4096, "tree") == pytest.approx(2e9)

    def test_unknown_algorithm_raises(self):
        with pytest.raises(ValueError, match="unknown all-reduce"):
            coll.all_reduce(1.0, 4, "quantum")


class TestVectorization:
    def test_broadcast_grid(self):
        payload = np.array([[1e6], [1e9]])          # (2, 1)
        n = np.array([1, 2, 8])                     # (3,)
        got = coll.all_reduce_bytes(payload, n, "ring")
        assert got.shape == (2, 3)
        assert got[0, 0] == 0.0
        assert got[1, 2] == pytest.approx(2 * 7 / 8 * 1e9)

    def test_time_is_bytes_over_bw(self):
        c = coll.all_reduce(1e9, 4, "ring")
        assert c.time(50e9) == pytest.approx(c.wire_bytes / 50e9)


class TestStrategyAccounting:
    def test_dp_is_one_allreduce(self):
        assert coll.dp_grad_sync_bytes(7e8, 16, "ring") == \
            pytest.approx(coll.all_reduce_bytes(7e8, 16, "ring"))

    def test_tp_scales_with_syncs_and_layers(self):
        one = coll.all_reduce_bytes(1e6, 8, "ring")
        assert coll.tp_act_sync_bytes(1e6, 8, 4, 32, "ring") == \
            pytest.approx(4 * 32 * one)
        assert coll.tp_act_sync_bytes(1e6, 1, 4, 32, "ring") == 0.0

    def test_pp_boundary(self):
        assert coll.pp_boundary_bytes(1e6, 1) == 0.0
        assert coll.pp_boundary_bytes(1e6, 4) == pytest.approx(2e6)


class TestAllToAll:
    """α–β properties of the all-to-all the ep axis prices (ISSUE 9)."""

    def test_wire_is_n_minus_1_over_n(self):
        for n in (2, 4, 8, 60):
            c = coll.all_to_all(1e9, n)
            assert c.wire_bytes == pytest.approx((n - 1) / n * 1e9)

    def test_steps_are_n_minus_1(self):
        for n in (2, 4, 16):
            assert coll.all_to_all(1.0, n).steps == n - 1

    def test_size_1_group_is_exactly_zero(self):
        c = coll.all_to_all(1e9, 1)
        assert c.wire_bytes == 0.0 and c.steps == 0.0

    def test_wire_monotonic_in_group_size(self):
        sizes = np.array([1, 2, 4, 8, 64])
        wire = coll.all_to_all(1e9, sizes).wire_bytes
        assert (np.diff(wire) > 0).all()

    def test_time_is_alpha_steps_plus_bytes_over_bw(self):
        c = coll.all_to_all(1e9, 8)
        bw, alpha = 50e9, 1e-6
        assert c.time(bw, alpha) == pytest.approx(
            alpha * c.steps + c.wire_bytes / bw)


class TestEpDispatchCombine:
    def test_is_two_all_to_alls(self):
        one = coll.all_to_all(3e8, 4)
        both = coll.ep_dispatch_combine(3e8, 4)
        assert both.wire_bytes == pytest.approx(2 * one.wire_bytes)
        assert both.steps == pytest.approx(2 * one.steps)

    def test_ep1_is_exactly_zero(self):
        c = coll.ep_dispatch_combine(1e9, 1)
        assert c.wire_bytes == 0.0 and c.steps == 0.0

    def test_grid_equals_scalar(self):
        """Broadcast pricing must match per-candidate scalar pricing."""
        payload = np.array([1e6, 1e6, 5e8, 5e8])
        ep = np.array([1, 4, 2, 60])
        grid = coll.ep_dispatch_combine(payload, ep)
        for i in range(payload.size):
            one = coll.ep_dispatch_combine(float(payload[i]), int(ep[i]))
            assert grid.wire_bytes[i] == one.wire_bytes
            assert grid.steps[i] == one.steps
