"""Data pipeline: determinism, host sharding, learnability signal."""
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLMStream, make_stream


def test_batches_are_pure_functions_of_step():
    cfg = get_reduced("smollm-135m")
    d = DataConfig(seed=3, global_batch=4, seq_len=32)
    s1, s2 = SyntheticLMStream(cfg, d), SyntheticLMStream(cfg, d)
    for step in (0, 5, 1000):
        b1, b2 = s1.batch(step), s2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_different_hosts_get_different_shards():
    cfg = get_reduced("smollm-135m")
    b0 = SyntheticLMStream(cfg, DataConfig(
        seed=3, global_batch=8, n_hosts=2, host_id=0, seq_len=32)).batch(0)
    b1 = SyntheticLMStream(cfg, DataConfig(
        seed=3, global_batch=8, n_hosts=2, host_id=1, seq_len=32)).batch(0)
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_reduced("smollm-135m")
    b = SyntheticLMStream(cfg, DataConfig(global_batch=2, seq_len=16)).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_stream_is_predictable():
    """Transition entropy must be well below uniform (else the LM smoke
    tests could never show a learning signal)."""
    cfg = get_reduced("smollm-135m")
    stream = SyntheticLMStream(cfg, DataConfig(global_batch=2, seq_len=16))
    p = stream.trans
    ent = -(p * np.log(p + 1e-9)).sum(1).mean()
    assert ent < 0.7 * np.log(stream.v)


def test_family_specific_keys():
    for arch, key in [("whisper-tiny", "frames"),
                      ("internvl2-26b", "patches")]:
        cfg = get_reduced(arch)
        b = make_stream(cfg, DataConfig(global_batch=2, seq_len=8)).batch(0)
        assert key in b
    b = make_stream(get_reduced("dlrm-mlp"),
                    DataConfig(global_batch=4)).batch(0)
    assert set(b) == {"features", "click"}
