"""Collective-byte parsing over real (captured) partitioned-HLO text."""
import pytest

from repro.core.hlo_analysis import (_parse_groups,
                                     _shape_bytes, parse_collectives)

# real lines captured from jax 0.8.2 XLA:CPU SPMD output on 8 fake devices
REAL_HLO = """
HloModule jit_step, is_scheduled=true

%region_0.0.clone (x: f32[], y: f32[]) -> f32[] { ... }

ENTRY %main {
  %all-reduce = f32[] all-reduce(%wrapped_reduce), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%region_0.0.clone
  ROOT %all-reduce.1 = f32[] all-reduce(%all-reduce), channel_id=2, replica_groups=[4,2]<=[2,4]T(1,0), use_global_device_ids=true, to_apply=%region_0.0.clone.1
}
"""

SYNTH_HLO = """
  %ag = bf16[256,4096]{1,0} all-gather(%p0), channel_id=3, replica_groups=[4,4]<=[16], dimensions={0}
  %rs = f32[64,1024]{1,0} reduce-scatter(%g0), channel_id=4, replica_groups=[2,8]<=[16], dimensions={0}, to_apply=%add
  %a2a = bf16[128,512]{1,0} all-to-all(%x), channel_id=5, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = f32[32,32]{1,0} collective-permute(%y), channel_id=6, source_target_pairs={{0,1},{1,0}}
  %tup = (f32[100]{0}, f32[200]{0}) all-reduce(%a, %b), channel_id=7, replica_groups=[1,16]<=[16], to_apply=%add
  %ars = (f32[50]{0}, f32[50]{0}) all-reduce-start(%c), channel_id=8, replica_groups=[1,16]<=[16], to_apply=%add
"""


class TestShapeParsing:
    def test_dtype_bytes(self):
        assert _shape_bytes("bf16", "256,4096") == 256 * 4096 * 2
        assert _shape_bytes("f32", "") == 4          # scalar f32[]
        assert _shape_bytes("s8", "10") == 10

    def test_iota_groups(self):
        n, g = _parse_groups("replica_groups=[2,4]<=[8]", 8)
        assert n == 4 and g.shape == (2, 4) and list(g[0]) == [0, 1, 2, 3]

    def test_iota_transposed_groups(self):
        n, g = _parse_groups("replica_groups=[4,2]<=[2,4]T(1,0)", 8)
        assert n == 2 and g.shape == (4, 2)
        # transpose of arange(8).reshape(2,4) -> column pairs (0,4),(1,5)...
        assert list(g[0]) == [0, 4]

    def test_explicit_groups(self):
        n, g = _parse_groups("replica_groups={{0,1,2,3},{4,5,6,7}}", 8)
        assert n == 4 and g.shape == (2, 4)


class TestWireBytes:
    def test_real_scalar_allreduces(self):
        s = parse_collectives(REAL_HLO, 8)
        assert len(s.ops) == 2
        # f32[] = 4 bytes; ring factors 2*(4-1)/4 and 2*(2-1)/2
        assert s.ops[0].wire_bytes == pytest.approx(4 * 2 * 3 / 4)
        assert s.ops[1].wire_bytes == pytest.approx(4 * 2 * 1 / 2)

    def test_synthetic_kinds(self):
        s = parse_collectives(SYNTH_HLO, 16)
        kinds = s.by_kind()
        # all-gather: result 256*4096*2 bytes, n=4 -> (n-1)/n
        assert kinds["all-gather"][1] == pytest.approx(
            256 * 4096 * 2 * 3 / 4)
        # reduce-scatter: result is the shard -> factor (n-1)
        assert kinds["reduce-scatter"][1] == pytest.approx(
            64 * 1024 * 4 * 7)
        # all-to-all n=4
        assert kinds["all-to-all"][1] == pytest.approx(128 * 512 * 2 * 3 / 4)
        # collective-permute factor 1
        assert kinds["collective-permute"][1] == pytest.approx(32 * 32 * 4)
        # tuple all-reduce sums elements; -start takes max element only
        ar = kinds["all-reduce"][1]
        assert ar == pytest.approx(
            (100 + 200) * 4 * 2 * 15 / 16 + 50 * 4 * 2 * 15 / 16)

    def test_cross_pod_attribution(self):
        # groups spanning 2 pods of 8: [1,16]<=[16] ring crosses pods twice
        s = parse_collectives(SYNTH_HLO, 16, pod_size=8)
        tup = [o for o in s.ops if o.kind == "all-reduce"
               and o.group_size == 16]
        assert tup and all(o.cross_pod_fraction == pytest.approx(2 / 16)
                           for o in tup)
        # groups inside one pod: all-gather [4,4]<=[16] stays intra-pod
        ag = [o for o in s.ops if o.kind == "all-gather"][0]
        assert ag.cross_pod_fraction == 0.0


@pytest.mark.slow
class TestPerDeviceSemantics:
    """cost_analysis is per-device: verified by an 8-device subprocess
    compile (jax device count is locked at first init, so this cannot run
    in-process)."""

    def test_sharded_matmul_flops(self, tmp_path):
        import subprocess, sys, os, textwrap
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((8,), ("d",))
            s = NamedSharding(mesh, P("d", None))
            x = jax.ShapeDtypeStruct((1024, 512), jnp.float32, sharding=s)
            w = jax.ShapeDtypeStruct((512, 256), jnp.float32)
            c = jax.jit(lambda x, w: x @ w).lower(x, w).compile()
            from repro.core.hlo_analysis import cost_analysis_dict
            flops = cost_analysis_dict(c)["flops"]
            total = 2 * 1024 * 512 * 256
            assert abs(flops - total / 8) / total < 0.01, flops
            print("PER_DEVICE_OK")
        """)
        p = tmp_path / "probe.py"
        p.write_text(script)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, str(p)], capture_output=True,
                             text=True, env=env, timeout=300)
        assert "PER_DEVICE_OK" in out.stdout, out.stderr


import os  # noqa: E402  (used in the slow test)


def test_scan_body_counted_once():
    """XLA cost_analysis does NOT multiply while-loop bodies by trip count —
    the reason dryrun uses unrolled k-layer cost probes."""
    import jax
    import jax.numpy as jnp

    def body(x, w):
        return x @ w, None

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    scan = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0]).lower(x, w).compile()
    unroll = jax.jit(lambda x, w: jax.lax.scan(body, x, w, unroll=8)[0]
                     ).lower(x, w).compile()
    from repro.core.hlo_analysis import cost_analysis_dict
    f_scan = cost_analysis_dict(scan)["flops"]
    f_unroll = cost_analysis_dict(unroll)["flops"]
    assert f_unroll == pytest.approx(8 * f_scan, rel=0.01)
