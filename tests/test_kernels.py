"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.blocked_matmul import blocked_matmul
from repro.kernels.flash_attention import flash_attention_bhsd

KEY = jax.random.PRNGKey(42)


def _rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    denom = np.maximum(np.max(np.abs(want)), 1e-6)
    return float(np.max(np.abs(got - want))) / denom


class TestBlockedMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mkn", [(512, 512, 512), (1024, 512, 512),
                                     (512, 1024, 1536)])
    def test_shapes_dtypes(self, dtype, mkn):
        M, K, N = mkn
        a = jax.random.normal(KEY, (M, K), dtype)
        b = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N), dtype)
        got = blocked_matmul(a, b, interpret=True)
        want = ref.ref_matmul(a, b)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        assert _rel_err(got, want) < tol

    @pytest.mark.parametrize("act", [None, "relu", "relu2", "silu", "gelu"])
    def test_fused_epilogue(self, act):
        a = jax.random.normal(KEY, (512, 512), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(KEY, 2), (512, 512),
                              jnp.float32)
        bias = jax.random.normal(jax.random.fold_in(KEY, 3), (512,),
                                 jnp.float32)
        got = blocked_matmul(a, b, bias=bias, act=act, interpret=True)
        want = ref.ref_matmul(a, b, bias=bias, act=act)
        assert _rel_err(got, want) < 1e-5

    def test_small_block_shapes(self):
        a = jax.random.normal(KEY, (256, 384), jnp.float32)
        b = jax.random.normal(KEY, (384, 256), jnp.float32)
        got = blocked_matmul(a, b, block_m=128, block_n=128, block_k=128,
                             interpret=True)
        assert _rel_err(got, ref.ref_matmul(a, b)) < 1e-5

    def test_wrapper_pads_odd_shapes(self):
        a = jax.random.normal(KEY, (300, 700), jnp.float32)
        b = jax.random.normal(KEY, (700, 520), jnp.float32)
        got = ops.matmul(a, b, act="gelu")
        assert _rel_err(got, ref.ref_matmul(a, b, act="gelu")) < 1e-5

    def test_wrapper_leading_dims(self):
        a = jax.random.normal(KEY, (4, 128, 512), jnp.float32)
        b = jax.random.normal(KEY, (512, 512), jnp.float32)
        got = ops.matmul(a, b)
        assert got.shape == (4, 128, 512)
        assert _rel_err(got, ref.ref_matmul(a.reshape(-1, 512), b)
                        .reshape(4, 128, 512)) < 1e-5


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        dict(B=2, S=512, H=4, K=2, dh=64, causal=True, window=0),
        dict(B=1, S=512, H=4, K=4, dh=128, causal=True, window=0),
        dict(B=1, S=1024, H=8, K=2, dh=64, causal=True, window=256),
        dict(B=2, S=512, H=6, K=3, dh=64, causal=False, window=0),
    ])
    @pytest.mark.slow
    def test_sweep(self, dtype, cfg):
        B, S, H, K, dh = cfg["B"], cfg["S"], cfg["H"], cfg["K"], cfg["dh"]
        q = jax.random.normal(KEY, (B, S, H, dh), dtype)
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, K, dh), dtype)
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, K, dh), dtype)
        got = ops.flash_attention(q, k, v, causal=cfg["causal"],
                                  window=cfg["window"])
        want = ref.ref_flash_attention(q, k, v, causal=cfg["causal"],
                                       window=cfg["window"])
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        assert _rel_err(got, want) < tol

    def test_unpadded_seq(self):
        q = jax.random.normal(KEY, (1, 300, 4, 64), jnp.float32)
        k = jax.random.normal(KEY, (1, 300, 2, 64), jnp.float32)
        v = jax.random.normal(KEY, (1, 300, 2, 64), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True)
        want = ref.ref_flash_attention(q, k, v, causal=True)
        assert _rel_err(got, want) < 1e-4

    def test_matches_model_attention(self):
        """Kernel path == the model's jnp attention (apply_attention)."""
        from repro.models.attention import apply_attention, init_attention
        from repro.models.common import ModelConfig
        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=256,
                          n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=32,
                          compute_dtype=jnp.float32)
        p = init_attention(KEY, cfg)
        x = jax.random.normal(KEY, (2, 512, 256), jnp.float32)
        out_jnp = apply_attention(p, x, cfg)
        out_flash = apply_attention(p, x, cfg.replace(use_flash=True))
        assert _rel_err(out_flash, out_jnp) < 1e-4


@pytest.mark.slow
class TestFlashProperty:
    @given(s_blocks=st.integers(1, 3), h=st.sampled_from([2, 4]),
           kv=st.sampled_from([1, 2]), causal=st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_random_shapes(self, s_blocks, h, kv, causal):
        S = 256 * s_blocks
        q = jax.random.normal(KEY, (1, S, h, 64), jnp.float32)
        k = jax.random.normal(KEY, (1, S, kv, 64), jnp.float32)
        v = jax.random.normal(KEY, (1, S, kv, 64), jnp.float32)
        got = flash_attention_bhsd(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal, block_q=128, block_k=128,
            interpret=True)
        want = ref.ref_flash_attention(q, k, v, causal=causal)
        assert _rel_err(jnp.swapaxes(got, 1, 2), want) < 1e-4
