"""The production launchers run end-to-end on CPU (reduced configs)."""

import pytest


@pytest.mark.slow
def test_train_launcher(tmp_path, capsys):
    from repro.launch.train import main
    rc = main(["--arch", "smollm-135m", "--reduced", "--steps", "12",
               "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
               "--ckpt-every", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "CE" in out and "bound" in out     # ran + ridgeline report
    import os
    assert any(n.startswith("step_") for n in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_launcher(capsys):
    from repro.launch.serve import main
    rc = main(["--arch", "smollm-135m", "--reduced", "--batch", "2",
               "--prompt-len", "4", "--new-tokens", "6"])
    assert rc == 0
    assert "tok/s" in capsys.readouterr().out
