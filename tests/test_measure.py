"""Unit tests for the measurement & calibration subsystem (repro.measure).

Everything here is accelerator-free and nearly jax-free: the timing
statistics are pure Python, and the fitting tests run on *synthetic*
measurements generated from known peaks, so they are exact.
"""
import json
import math
import os

import pytest

from repro.core.hardware import (CALIBRATION_SCHEMA, HardwareSpec,
                                 get_hardware, list_hardware,
                                 load_calibrated, spec_from_calibration)
from repro.core.ridgeline import WorkUnit
from repro.measure.calibrate import fit_ceilings
from repro.measure.microbench import Measurement
from repro.measure.timers import (TimingStats, block_until_ready,
                                  robust_stats, time_callable)

# --- timers -------------------------------------------------------------------


def test_robust_stats_median_iqr():
    s = robust_stats([1.0, 2.0, 3.0, 4.0, 5.0])
    assert s.median == 3.0
    assert s.iqr == pytest.approx(2.0)     # q75=4, q25=2
    assert s.mean == 3.0
    assert s.best == 1.0 and s.worst == 5.0
    assert s.rel_spread == pytest.approx(2.0 / 3.0)


def test_robust_stats_discards_warmup():
    # the 100.0 compile-time sample must not pollute the statistics
    s = robust_stats([100.0, 1.0, 1.0, 1.0], warmup=1)
    assert s.median == 1.0
    assert s.warmup_samples == (100.0,)
    assert len(s.samples) == 3


def test_robust_stats_even_count_interpolates():
    s = robust_stats([1.0, 2.0, 3.0, 4.0])
    assert s.median == 2.5


def test_robust_stats_empty_raises():
    with pytest.raises(ValueError):
        robust_stats([1.0], warmup=1)
    with pytest.raises(ValueError):
        robust_stats([])


def test_time_callable_counts_calls_and_blocks():
    calls = []

    class Blocking:
        def __init__(self):
            self.blocked = False

        def block_until_ready(self):
            self.blocked = True

    outs = []

    def fn():
        calls.append(1)
        out = Blocking()
        outs.append(out)
        return {"a": [out]}        # nested pytree: blocker must be reached

    stats = time_callable(fn, repeats=3, warmup=2)
    assert isinstance(stats, TimingStats)
    assert len(calls) == 5                      # warmup + repeats
    assert len(stats.samples) == 3
    assert all(o.blocked for o in outs)


def test_time_callable_validates_args():
    with pytest.raises(ValueError):
        time_callable(lambda: None, repeats=0)
    with pytest.raises(ValueError):
        time_callable(lambda: None, calls_per_sample=0)


def test_block_until_ready_passthrough():
    assert block_until_ready(42) == 42
    assert block_until_ready([1, (2, {"k": 3})]) == [1, (2, {"k": 3})]


# --- synthetic calibration ----------------------------------------------------

TRUE = HardwareSpec(name="true_box", peak_flops=1e11, hbm_bw=4e9, net_bw=2e8)
#: a deliberately-wrong datasheet to initialize from
BASE = HardwareSpec(name="fake_ds", peak_flops=5e12, hbm_bw=8e10, net_bw=9e9)


def _synth(name, flops, mem, net, hw=TRUE, category="compute"):
    t = max(flops / hw.peak_flops, mem / hw.hbm_bw, net / hw.net_bw)
    return Measurement(work=WorkUnit(name, flops, mem, net), seconds=t,
                       best_seconds=t, category=category)


def synth_suite():
    return [
        _synth("gemm_small", 1e10, 1e7, 0.0),          # compute-bound
        _synth("gemm_big", 8e10, 3e7, 0.0),
        _synth("stream_small", 1e6, 4e8, 0.0, category="memory"),
        _synth("stream_big", 4e6, 1.6e9, 0.0, category="memory"),
        _synth("allreduce", 1e6, 1e7, 4e7, category="network"),
        _synth("allreduce_big", 4e6, 4e7, 1.6e8, category="network"),
    ]


def test_fit_recovers_known_peaks_exactly():
    calib = fit_ceilings(synth_suite(), BASE, name="true_box_cal")
    assert calib.peak_flops == pytest.approx(TRUE.peak_flops, rel=1e-9)
    assert calib.hbm_bw == pytest.approx(TRUE.hbm_bw, rel=1e-9)
    assert calib.net_bw == pytest.approx(TRUE.net_bw, rel=1e-9)
    assert calib.sources == {"peak_flops": "measured", "hbm_bw": "measured",
                             "net_bw": "measured"}
    errs = calib.error_summary("fit")
    assert errs["n"] == 6
    assert errs["max_abs_rel_error"] < 1e-9


def test_fit_with_noise_stays_close():
    noisy = []
    for i, m in enumerate(synth_suite()):
        factor = 1.0 + (0.05 if i % 2 else -0.05)
        noisy.append(Measurement(work=m.work, seconds=m.seconds * factor,
                                 best_seconds=m.seconds * factor,
                                 category=m.category))
    calib = fit_ceilings(noisy, BASE)
    assert calib.peak_flops == pytest.approx(TRUE.peak_flops, rel=0.1)
    assert calib.hbm_bw == pytest.approx(TRUE.hbm_bw, rel=0.1)
    assert calib.net_bw == pytest.approx(TRUE.net_bw, rel=0.1)
    assert calib.error_summary("fit")["max_abs_rel_error"] < 0.11


def test_unmeasured_resource_keeps_datasheet():
    # no network bench -> NET must stay at the datasheet number
    suite = [m for m in synth_suite() if m.category != "network"]
    calib = fit_ceilings(suite, BASE)
    assert calib.net_bw == BASE.net_bw
    assert calib.sources["net_bw"] == "datasheet"
    assert calib.sources["peak_flops"] == "measured"


def test_estimator_selects_statistic():
    m = Measurement(work=WorkUnit("g", 1e10, 1e6, 0.0),
                    seconds=2.0, best_seconds=1.0, category="compute")
    best = fit_ceilings([m], BASE, estimator="best")
    med = fit_ceilings([m], BASE, estimator="median")
    assert best.peak_flops == pytest.approx(1e10)
    assert med.peak_flops == pytest.approx(5e9)
    with pytest.raises(ValueError):
        fit_ceilings([m], BASE, estimator="mean")


def test_fit_empty_raises():
    with pytest.raises(ValueError):
        fit_ceilings([], BASE)


def test_step_points_in_fit_list_route_to_validation():
    """Passing a full suite (micro + steps) must not count steps as fit."""
    step = _synth("step", 1e10, 1e9, 0.0, category="step")
    calib = fit_ceilings(synth_suite() + [step], BASE)
    assert calib.peak_flops == pytest.approx(TRUE.peak_flops, rel=1e-9)
    assert calib.error_summary("fit")["n"] == 6          # steps excluded
    assert calib.error_summary("validation")["n"] == 1   # ...and validated
    with pytest.raises(ValueError, match="only validate"):
        fit_ceilings([step], BASE)


def test_validation_points_do_not_steer_fit():
    step = _synth("step", 1e10, 1e9, 0.0, category="step")
    wild = Measurement(work=step.work, seconds=step.seconds * 100,
                       best_seconds=step.seconds * 100, category="step")
    calib = fit_ceilings(synth_suite(), BASE, validation=[wild])
    assert calib.peak_flops == pytest.approx(TRUE.peak_flops, rel=1e-9)
    # ... but they do show up in the validation error summary
    assert calib.error_summary("validation")["n"] == 1
    assert calib.error_summary("validation")["max_abs_rel_error"] > 0.9


# --- registry schema & round trip ---------------------------------------------


def test_registry_roundtrip(tmp_path):
    calib = fit_ceilings(synth_suite(), BASE, name="true_box_cal",
                         validation=[_synth("step", 1e10, 1e9, 0.0,
                                            category="step")])
    path = calib.save(str(tmp_path))
    assert os.path.basename(path) == "true_box_cal.json"

    with open(path) as f:
        d = json.load(f)
    for key in ("schema", "name", "base", "estimator", "peak_flops",
                "hbm_bw", "net_bw", "sources", "datasheet", "fit",
                "validation", "measurements", "validation_measurements"):
        assert key in d, key
    assert d["schema"] == CALIBRATION_SCHEMA
    assert d["base"] == "fake_ds"
    assert len(d["measurements"]) == 6
    for m in d["measurements"]:
        for key in ("name", "flops", "mem_bytes", "net_bytes", "seconds",
                    "assigned", "model_seconds", "rel_error"):
            assert key in m, key

    spec = spec_from_calibration(d)
    assert spec == calib.spec()
    assert spec.name == "true_box_cal"
    assert spec.peak_flops == pytest.approx(TRUE.peak_flops, rel=1e-9)


def test_registry_resolution_through_hardware(tmp_path):
    calib = fit_ceilings(synth_suite(), BASE, name="true_box_cal")
    calib.save(str(tmp_path))
    reg = str(tmp_path)

    # by exact name, by base name, and via get_hardware both ways
    assert load_calibrated("true_box_cal", reg).hbm_bw == calib.hbm_bw
    assert load_calibrated("fake_ds", reg).hbm_bw == calib.hbm_bw
    assert get_hardware("true_box_cal", registry_dir=reg) == calib.spec()
    assert get_hardware("fake_ds", calibrated=True,
                        registry_dir=reg) == calib.spec()
    # datasheet presets still win without calibrated=True
    assert get_hardware("clx", registry_dir=reg).name == "clx"

    listing = list_hardware(reg)
    assert listing["true_box_cal"] == "calibrated"
    assert listing["clx"] == "datasheet"

    with pytest.raises(KeyError):
        load_calibrated("never_measured", reg)
    with pytest.raises(ValueError):
        spec_from_calibration({"schema": "bogus", "name": "x"})


def test_bad_schema_entries_do_not_list(tmp_path):
    (tmp_path / "junk.json").write_text('{"name": "junk"}')
    (tmp_path / "broken.json").write_text("{nope")
    assert "junk" not in list_hardware(str(tmp_path))


def test_corrupt_registry_entries_never_escape_keyerror(tmp_path):
    # a corrupt file in the registry must not turn name-resolution errors
    # into JSONDecodeError tracebacks
    (tmp_path / "broken.json").write_text("{nope")
    with pytest.raises(KeyError) as exc:
        get_hardware("typo", registry_dir=str(tmp_path))
    assert "unknown hardware spec" in exc.value.args[0]
    # and a healthy entry next to it still resolves
    fit_ceilings(synth_suite(), BASE, name="true_box_cal").save(str(tmp_path))
    assert get_hardware("true_box_cal",
                        registry_dir=str(tmp_path)).name == "true_box_cal"


def test_missing_calibration_error_lists_only_calibrated(tmp_path):
    fit_ceilings(synth_suite(), BASE, name="true_box_cal").save(str(tmp_path))
    with pytest.raises(KeyError) as exc:
        load_calibrated("tpu_v5e", str(tmp_path))
    msg = exc.value.args[0]
    assert "true_box_cal" in msg
    assert "'tpu_v5e'" not in msg.split("no calibration for")[1].split(";")[1]


def test_calibration_name_cannot_shadow_preset(tmp_path):
    calib = fit_ceilings(synth_suite(), BASE, name="clx")
    with pytest.raises(ValueError, match="shadows a datasheet preset"):
        calib.save(str(tmp_path))
    # an entry that somehow got written under a preset name never lists
    good = fit_ceilings(synth_suite(), BASE, name="true_box_cal")
    path = good.save(str(tmp_path))
    d = json.load(open(path))
    d["name"] = "clx"
    (tmp_path / "shadow.json").write_text(json.dumps(d))
    listing = list_hardware(str(tmp_path))
    assert listing["clx"] == "datasheet"


def test_unmeasured_links_keep_datasheet_values():
    """v2 behaviour: per-link bandwidths are fitted, never ratio-scaled."""
    base = HardwareSpec(name="b", peak_flops=1e12, hbm_bw=1e11, net_bw=1e10,
                        extra_links={"pod": 5e9})
    m = Measurement(work=WorkUnit("ar", 0.0, 0.0, 1e8), seconds=0.1,
                    best_seconds=0.1, category="network")
    pod_step = Measurement(
        work=WorkUnit("pod_step", 0.0, 0.0, 5e9, net_steps=0.0),
        seconds=1.0, best_seconds=1.0, category="step",
        meta=(("link", "pod"),))
    calib = fit_ceilings([m], base, validation=[pod_step])
    assert calib.net_bw == pytest.approx(1e9)
    # the primary link was measured 10x slower than datasheet, but nobody
    # timed the pod link — it must NOT be scaled by the primary's ratio
    assert calib.spec().extra_links["pod"] == pytest.approx(5e9)
    assert calib.sources["link:pod"] == "datasheet"
    # error reporting prices pod-tagged measurements at the same datasheet
    # bandwidth the spec would use, not at the fitted primary link
    assert calib.model_seconds(pod_step) == pytest.approx(5e9 / 5e9)


def test_measured_link_fits_independently():
    base = HardwareSpec(name="b", peak_flops=1e12, hbm_bw=1e11, net_bw=1e10,
                        extra_links={"pod": 5e9})
    # primary link at 1e9 B/s; pod link at 1e8 B/s with 1ms/hop latency
    prim = [Measurement(work=WorkUnit(f"ar{i}", 0.0, 0.0, q, net_steps=6.0),
                        seconds=q / 1e9 + 6 * 1e-5, category="network",
                        meta=(("link", "net"),))
            for i, q in enumerate((1e5, 1e8))]
    pod = [Measurement(work=WorkUnit(f"pod{i}", 0.0, 0.0, q, net_steps=2.0),
                       seconds=q / 1e8 + 2 * 1e-3, category="network",
                       meta=(("link", "pod"),))
           for i, q in enumerate((1e5, 1e8))]
    calib = fit_ceilings(prim + pod, base, estimator="median")
    assert calib.net_bw == pytest.approx(1e9, rel=1e-6)
    assert calib.alpha_network == pytest.approx(1e-5, rel=1e-6)
    assert calib.link_bws["pod"] == pytest.approx(1e8, rel=1e-6)
    assert calib.link_alphas["pod"] == pytest.approx(1e-3, rel=1e-6)
    assert calib.sources["link:pod"] == "measured"
    spec = calib.spec()
    assert spec.bandwidth_for("pod") == pytest.approx(1e8, rel=1e-6)
    assert spec.alpha_for("pod") == pytest.approx(1e-3, rel=1e-6)
    # model error is exact for the synthetic points
    assert calib.error_summary("fit")["max_abs_rel_error"] < 1e-9


def test_alpha_beta_fit_recovers_known_latency():
    """t = α + q/peak per resource, α·steps + q/bw for the network."""
    a_c, a_m, a_n = 1e-4, 5e-5, 2e-6
    suite = []
    for i, f in enumerate((1e9, 8e9, 5e10)):
        t = a_c + f / TRUE.peak_flops
        suite.append(Measurement(work=WorkUnit(f"g{i}", f, 1e3, 0.0),
                                 seconds=t, best_seconds=t,
                                 category="compute"))
    for i, bm in enumerate((4e8, 1.6e9)):
        t = a_m + bm / TRUE.hbm_bw
        suite.append(Measurement(work=WorkUnit(f"s{i}", 1e3, bm, 0.0),
                                 seconds=t, best_seconds=t,
                                 category="memory"))
    for i, bn in enumerate((4e4, 4e7)):
        t = a_n * 6.0 + bn / TRUE.net_bw
        suite.append(Measurement(work=WorkUnit(f"ar{i}", 1e2, 1e3, bn,
                                               net_steps=6.0),
                                 seconds=t, best_seconds=t,
                                 category="network"))
    calib = fit_ceilings(suite, BASE)
    assert calib.peak_flops == pytest.approx(TRUE.peak_flops, rel=1e-6)
    assert calib.hbm_bw == pytest.approx(TRUE.hbm_bw, rel=1e-6)
    assert calib.net_bw == pytest.approx(TRUE.net_bw, rel=1e-6)
    assert calib.alpha_compute == pytest.approx(a_c, rel=1e-6)
    assert calib.alpha_memory == pytest.approx(a_m, rel=1e-6)
    assert calib.alpha_network == pytest.approx(a_n, rel=1e-6)
    assert calib.error_summary("fit")["max_abs_rel_error"] < 1e-9
    # the calibrated spec reproduces the α-aware model end to end
    spec = calib.spec()
    from repro.core.ridgeline import analyze
    for m in suite:
        assert analyze(m.work, spec).runtime == \
            pytest.approx(m.seconds, rel=1e-6)


def test_v1_registry_entries_still_load(tmp_path):
    """Read-compat: a v1 (bandwidth-only) entry loads with all α = 0."""
    v1 = {"schema": "repro.calibration/v1", "name": "old_cal",
          "base": "clx", "peak_flops": 2e11, "hbm_bw": 5e9, "net_bw": 8e8,
          "extra_links": {"pod": 4e8}, "vmem_bytes": 1024}
    (tmp_path / "old_cal.json").write_text(json.dumps(v1))
    spec = spec_from_calibration(v1)
    assert spec.peak_flops == 2e11
    assert spec.alpha_compute == spec.alpha_memory == spec.alpha_network == 0.0
    assert spec.extra_links["pod"] == 4e8
    assert spec.model_rel_error == 0.0
    # and resolves through the registry loaders
    assert load_calibrated("old_cal", str(tmp_path)).net_bw == 8e8
    assert list_hardware(str(tmp_path))["old_cal"] == "calibrated"
    with pytest.raises(ValueError, match="schema"):
        spec_from_calibration({"schema": "repro.calibration/v99", "name": "x"})


def test_calibrated_spec_carries_validation_error():
    calib = fit_ceilings(
        synth_suite(), BASE, name="true_box_cal",
        validation=[Measurement(work=WorkUnit("step", 1e10, 1e9, 0.0),
                                seconds=0.125, best_seconds=0.125,
                                category="step")])
    spec = calib.spec()
    assert spec.model_rel_error == pytest.approx(
        calib.error_summary("validation")["median_abs_rel_error"])
    assert spec.model_rel_error > 0.0


# --- measurement serialization ------------------------------------------------


def test_measurement_roundtrip_and_validation():
    m = Measurement(work=WorkUnit("x", 1.0, 2.0, 3.0, net_steps=6.0),
                    seconds=0.5,
                    best_seconds=0.4, category="memory", rel_spread=0.1,
                    backend="cpu", meta=(("link", "pod"), ("via", "ref")))
    assert Measurement.from_dict(m.to_dict()) == m
    assert m.link == "pod"
    # dicts predating net_steps (v1 registries) still round-trip
    old = {k: v for k, v in m.to_dict().items() if k != "net_steps"}
    assert Measurement.from_dict(old).work.net_steps == 0.0
    with pytest.raises(ValueError):
        Measurement(work=WorkUnit("x", 1.0, 2.0, 3.0), seconds=0.5,
                    category="warp")
    with pytest.raises(ValueError):
        Measurement(work=WorkUnit("x", 1.0, 2.0, 3.0), seconds=0.0,
                    category="memory")
    # best falls back to median when unset
    m2 = Measurement(work=m.work, seconds=0.5, category="memory")
    assert m2.best == 0.5


# --- overlay ------------------------------------------------------------------


def _calib():
    return fit_ceilings(
        synth_suite(), BASE, name="true_box_cal",
        validation=[_synth("step_mlp", 1e10, 1e9, 0.0, category="step")])


def test_attach_measurement_sets_cell_fields():
    from repro.core.report import CellReport
    from repro.measure.overlay import attach_measurement
    rep = CellReport(
        arch="a", shape="s", mesh="1", step_kind="train_step", num_devices=1,
        hardware="clx", flops=1e9, mem_bytes=1e8, wire_bytes=0.0,
        wire_bytes_by_kind={}, peak_memory_per_device=0.0, model_flops=1e9,
        params_total=0.0, params_active=0.0, tokens_per_step=0.0)
    rep.finalize(get_hardware("clx"))
    attach_measurement(rep, rep.runtime * 2.0, source="test")
    assert rep.measured_runtime == pytest.approx(rep.runtime * 2.0)
    assert rep.measured_rel_error == pytest.approx(-0.5)
    assert rep.measured_source == "test"


def test_measured_cells_and_table():
    from repro.measure.overlay import measured_cell_reports, measured_table
    reports = measured_cell_reports(_calib())
    assert len(reports) == 1
    rep = reports[0]
    assert rep.hardware == "true_box_cal"
    assert rep.measured_runtime > 0
    assert rep.measured_source.startswith("calibrate:true_box_cal")
    # synthetic validation point is exact -> model error ~0
    assert abs(rep.measured_rel_error) < 1e-9
    table = measured_table(reports)
    assert "step_mlp" in table and "rel err" in table


def test_write_calibration_figs(tmp_path):
    from repro.measure.overlay import write_calibration_figs
    paths = write_calibration_figs(str(tmp_path), _calib())
    assert len(paths) == 2
    svg = open(paths[0]).read()
    txt = open(paths[1]).read()
    assert "measured" in svg and "meas " in svg     # hollow markers + notes
    assert "meas " in txt and "vs model" in txt
    assert "calibration true_box_cal" in txt        # summary block rides along


def test_point_notes_format():
    from repro.measure.overlay import point_notes
    calib = _calib()
    notes = point_notes(calib)
    assert set(notes) == {m.work.name for m in
                          calib.fit_measurements +
                          calib.validation_measurements}
    assert all("vs model" in v for v in notes.values())


# --- CLI end-to-end (slow: really times kernels on CPU) -----------------------


@pytest.mark.slow
def test_calibrate_cli_smoke(tmp_path):
    from repro.launch import plan as plan_mod
    from repro.measure import calibrate as cal_mod

    figs = tmp_path / "figs"
    rc = cal_mod.main(["--backend", "cpu", "--smoke", "--repeats", "2",
                       "--name", "clx_test_cal", "--hardware", "clx",
                       "--out", str(tmp_path), "--figures", str(figs)])
    assert rc == 0
    entry = json.loads((tmp_path / "clx_test_cal.json").read_text())
    assert entry["schema"] == CALIBRATION_SCHEMA
    assert entry["sources"]["peak_flops"] == "measured"
    # single device in-process -> no wire to measure
    assert entry["sources"]["net_bw"] == "datasheet"
    assert entry["alpha_network"] == 0.0
    assert entry["alpha_compute"] >= 0.0        # fitted (possibly clamped 0)
    assert entry["validation"]["n"] == 3
    cells = sorted(os.listdir(tmp_path / "cells"))
    assert any("train_step" in c for c in cells)
    assert any(f.startswith("calibration_clx_test_cal")
               for f in os.listdir(figs))

    # the calibrated spec must round-trip into planner rankings
    spec = get_hardware("clx", calibrated=True, registry_dir=str(tmp_path))
    assert spec.name == "clx_test_cal"
    from repro.configs import get_config
    plans = plan_mod.plan(get_config("dlrm-mlp"), spec, 4, batch=512)
    assert plans and math.isfinite(plans[0].runtime)
    assert plans[0].runtime > 0


# --- bench retry + budget guard (PR 10) --------------------------------------
class TestGuardedStats:
    def test_transient_failure_retried(self):
        from repro.measure.microbench import _guarded_stats
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] <= 2:
                raise RuntimeError("allocator burst")
            return calls["n"]

        stats = _guarded_stats("flaky", flaky, repeats=3, warmup=0,
                               retries=2, timeout_s=0.0)
        assert len(stats.samples) == 3
        # 2 failed probes + 1 good probe + 3 timed repeats
        assert calls["n"] == 6

    def test_bounded_retries_reraise(self):
        from repro.measure.microbench import _guarded_stats

        def broken():
            raise RuntimeError("dead backend")

        with pytest.raises(RuntimeError, match="dead backend"):
            _guarded_stats("broken", broken, repeats=3, warmup=0, retries=1)

    def test_programming_errors_not_retried(self):
        from repro.measure.microbench import _guarded_stats
        calls = {"n": 0}

        def bad_shapes():
            calls["n"] += 1
            raise ValueError("shape mismatch")

        with pytest.raises(ValueError):
            _guarded_stats("bad", bad_shapes, repeats=3, warmup=0, retries=3)
        assert calls["n"] == 1

    def test_budget_clamps_repeats(self):
        import time as time_mod

        from repro.measure.microbench import _guarded_stats

        def slow():
            time_mod.sleep(0.02)

        # probe ~0.02s, budget 0.1s -> far fewer than 50 samples kept
        stats = _guarded_stats("slow", slow, repeats=50, warmup=1,
                               timeout_s=0.1)
        assert 1 <= len(stats.samples) <= 5

    def test_no_budget_keeps_all_repeats(self):
        from repro.measure.microbench import _guarded_stats
        stats = _guarded_stats("fast", lambda: 1.0, repeats=5, warmup=1,
                               timeout_s=0.0)
        assert len(stats.samples) == 5
