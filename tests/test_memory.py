"""ISSUE 6 tests: the per-chip working-set model (``launch/memory``).

Three layers:

  * closed-form accounting: the training footprint decomposes into
    exactly 4/4/8 bytes per (tp·pp-sharded) parameter for params /
    grads / AdamW states — pinned against the eval_shape-exact
    ``param_counts`` for attention models and the by-hand closed form
    for the MLP tower;
  * properties (hypothesis via ``tests/_hypothesis_compat``): the
    vectorized broadcast path agrees elementwise with scalar calls on
    random candidate grids, ZeRO stages are monotone (stage k+1 never
    needs more memory than stage k, strictly less when dp > 1), and
    ``min_zero_stage`` returns the first fitting stage;
  * the remat and decode models: remat exactly halves the saved
    activations, decode carries bf16 weights + the KV cache and nothing
    else.
"""
import numpy as np
import pytest

from repro.launch import memory as mem
from repro.launch.plan_grid import param_counts
from tests._hypothesis_compat import given, settings, st


def _cfg(name="qwen2-7b"):
    from repro.configs import get_config
    return get_config(name)


# --- closed-form accounting ---------------------------------------------------


class TestTrainingAccounting:
    def test_state_bytes_per_param_pinned(self):
        """Unsharded single chip: params 4 B, grads 4 B, AdamW μ+ν 8 B
        per parameter — 16 B/param total, the fp32-master accounting of
        ``optim/optimizer``."""
        for name in ("dlrm-mlp", "qwen2-7b"):
            cfg = _cfg(name)
            n_total, _ = param_counts(cfg)
            ws = mem.training_working_set(cfg, batch=1)
            assert float(ws.params) == 4.0 * n_total
            assert float(ws.grads) == 4.0 * n_total
            assert float(ws.opt) == 8.0 * n_total
            assert float(ws.kv_cache) == 0.0
            assert float(ws.total) == pytest.approx(
                16.0 * n_total + float(ws.activations))

    def test_mlp_closed_form_footprint(self):
        """The MLP tower's whole footprint, restated by hand: 16 B/param
        plus 2 saved fp32 boundary tensors per layer."""
        cfg = _cfg("dlrm-mlp")
        n_total, _ = param_counts(cfg)
        batch, width = 512, cfg.mlp_widths[0]
        ws = mem.training_working_set(cfg, batch=batch)
        want_acts = 2.0 * cfg.n_layers * batch * width * 4.0
        assert float(ws.activations) == want_acts
        assert float(ws.total) == 16.0 * n_total + want_acts

    def test_model_sharding_divides_state(self):
        cfg = _cfg()
        base = mem.training_working_set(cfg, batch=8, seq=128)
        shard = mem.training_working_set(cfg, batch=8, seq=128, tp=2, pp=2)
        assert float(shard.params) == float(base.params) / 4.0
        assert float(shard.grads) == float(base.grads) / 4.0
        assert float(shard.opt) == float(base.opt) / 4.0

    def test_zero_stages_shard_exactly_their_state(self):
        cfg = _cfg()
        dp = 4
        z0, z1, z2, z3 = (
            mem.training_working_set(cfg, batch=8, seq=128, dp=dp,
                                     zero_stage=z) for z in range(4))
        assert float(z1.opt) == float(z0.opt) / dp
        assert float(z1.params) == float(z0.params)
        assert float(z1.grads) == float(z0.grads)
        assert float(z2.grads) == float(z0.grads) / dp
        assert float(z2.params) == float(z0.params)
        assert float(z3.params) == float(z0.params) / dp
        # activations are already dp-sharded, untouched by ZeRO
        for z in (z1, z2, z3):
            assert float(z.activations) == float(z0.activations)

    def test_remat_halves_saved_activations_only(self):
        cfg = _cfg()
        kw = dict(batch=8, seq=256, dp=2, tp=2)
        full = mem.training_working_set(cfg, **kw)
        rem = mem.training_working_set(cfg, remat=True, **kw)
        assert float(rem.activations) == float(full.activations) / 2.0
        assert float(rem.params) == float(full.params)
        assert float(rem.opt) == float(full.opt)
        assert mem.REMAT_FLOPS_FACTOR == pytest.approx(4.0 / 3.0)

    def test_inflight_microbatches_cap_at_pp(self):
        """1F1B holds min(m, pp) microbatches of activations in flight:
        splitting the batch further than pp frees memory, beyond that
        the in-flight count saturates."""
        cfg = _cfg("dlrm-mlp")              # n_layers = 8
        kw = dict(batch=512, pp=4)
        a4 = float(mem.training_working_set(cfg, microbatches=4,
                                            **kw).activations)
        a8 = float(mem.training_working_set(cfg, microbatches=8,
                                            **kw).activations)
        a16 = float(mem.training_working_set(cfg, microbatches=16,
                                             **kw).activations)
        assert a8 == a4 / 2.0               # m above pp keeps shrinking...
        assert a16 == a4 / 4.0
        a1 = float(mem.training_working_set(cfg, microbatches=1,
                                            **kw).activations)
        a2 = float(mem.training_working_set(cfg, microbatches=2,
                                            **kw).activations)
        # ...but m below pp holds every microbatch it has: same bytes
        assert a1 == a2 == a4

    def test_ep_shards_exactly_the_expert_state(self):
        """ep divides the routed expert tensors (and their grads and
        optimizer states); the dense remainder replicates (ISSUE 9)."""
        from repro.launch.specs import expert_param_counts
        cfg = _cfg("qwen2-moe-a2.7b")
        n_total, _ = param_counts(cfg)
        e_total, _ = expert_param_counts(cfg)
        base = mem.training_working_set(cfg, batch=8, seq=128)
        ep4 = mem.training_working_set(cfg, batch=8, seq=128, ep=4)
        want_frac = ((n_total - e_total) + e_total / 4.0) / n_total
        for field in ("params", "grads", "opt"):
            assert float(getattr(ep4, field)) == pytest.approx(
                float(getattr(base, field)) * want_frac, rel=1e-12)
        # activations are per-token, not per-expert: untouched by ep
        assert float(ep4.activations) == float(base.activations)
        # ep = 1 lanes inside a mixed grid stay bit-identical
        mixed = mem.training_working_set(cfg, batch=8, seq=128,
                                         ep=np.array([1.0, 4.0]))
        assert float(mixed.total[0]) == float(base.total)
        assert float(mixed.total[1]) == float(ep4.total)

    def test_ep_is_a_noop_for_dense_models(self):
        cfg = _cfg()                        # qwen2-7b: no routed experts
        base = mem.training_working_set(cfg, batch=8, seq=128)
        ep = mem.training_working_set(cfg, batch=8, seq=128, ep=4)
        assert float(ep.total) == float(base.total)


# --- vectorized path ≡ scalar reference on random grids -----------------------


class TestVectorizedAgreesWithScalar:
    @settings(max_examples=30)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           n=st.integers(min_value=1, max_value=40),
           remat=st.booleans())
    def test_property_elementwise(self, seed, n, remat):
        cfg = _cfg()
        rng = np.random.RandomState(seed)
        dp = 2 ** rng.randint(0, 5, size=n)
        tp = rng.choice([1, 2, 4], size=n)
        pp = rng.choice([1, 2, 4, 7], size=n)
        m = pp * 2 ** rng.randint(0, 3, size=n)
        zero = rng.randint(0, 4, size=n)
        batch = (dp * 2 ** rng.randint(0, 4, size=n)).astype(np.int64)
        vec = mem.training_working_set(
            cfg, batch=batch, seq=128, dp=dp, tp=tp, pp=pp, microbatches=m,
            zero_stage=zero, remat=remat).total
        assert vec.shape == (n,)
        for i in range(n):
            scalar = mem.training_working_set(
                cfg, batch=int(batch[i]), seq=128, dp=int(dp[i]),
                tp=int(tp[i]), pp=int(pp[i]), microbatches=int(m[i]),
                zero_stage=int(zero[i]), remat=remat).total
            assert float(vec[i]) == float(scalar)

    @settings(max_examples=30)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           cap_gb=st.floats(min_value=0.5, max_value=200.0))
    def test_property_mask_equals_scalar_reference(self, seed, cap_gb):
        """The planner's feasibility mask on a random candidate set is
        exactly the per-candidate scalar comparison."""
        cfg = _cfg()
        rng = np.random.RandomState(seed)
        n = 32
        dp = 2 ** rng.randint(0, 4, size=n)
        tp = rng.choice([1, 2, 4], size=n)
        zero = rng.randint(0, 4, size=n)
        batch = dp * 2 ** rng.randint(0, 6, size=n)
        cap = cap_gb * 1e9
        total = mem.training_working_set(
            cfg, batch=batch, seq=512, dp=dp, tp=tp,
            zero_stage=zero).total
        mask = total <= cap
        for i in range(n):
            want = float(mem.training_working_set(
                cfg, batch=int(batch[i]), seq=512, dp=int(dp[i]),
                tp=int(tp[i]), zero_stage=int(zero[i])).total) <= cap
            assert bool(mask[i]) == want


# --- ZeRO monotonicity and min_zero_stage -------------------------------------


class TestZeroMonotonicity:
    @settings(max_examples=40)
    @given(dp=st.sampled_from([1, 2, 4, 8, 16]),
           tp=st.sampled_from([1, 2, 4]),
           batch_per_dp=st.integers(min_value=1, max_value=64))
    def test_property_higher_stage_never_needs_more(self, dp, tp,
                                                    batch_per_dp):
        cfg = _cfg()
        totals = [float(mem.training_working_set(
            cfg, batch=dp * batch_per_dp, seq=128, dp=dp, tp=tp,
            zero_stage=z).total) for z in range(4)]
        for lo, hi in zip(totals[1:], totals[:-1]):
            assert lo <= hi
        if dp > 1:
            assert totals[3] < totals[0]    # ZeRO-3 strictly shrinks
        else:
            assert totals == [totals[0]] * 4    # nothing to shard over

    def test_min_zero_stage_is_first_fit(self):
        cfg = _cfg()
        kw = dict(batch=8, seq=128, dp=4, tp=4)
        totals = [float(mem.training_working_set(cfg, zero_stage=z,
                                                 **kw).total)
                  for z in range(4)]
        for z in range(4):
            cap = totals[z] * 1.001
            got = int(mem.min_zero_stage(cfg, cap, **kw))
            want = min(s for s in range(4) if totals[s] <= cap)
            assert got == want
        assert int(mem.min_zero_stage(cfg, totals[3] * 0.5, **kw)) == 4
        assert int(mem.min_zero_stage(cfg, 0.0, **kw)) == 0   # unknown cap

    def test_min_zero_stage_vectorizes(self):
        cfg = _cfg()
        got = mem.min_zero_stage(cfg, 16e9, batch=8, seq=128,
                                 dp=np.array([4, 8, 1]),
                                 tp=np.array([4, 2, 1]))
        assert got.shape == (3,)
        assert got.dtype == np.int64
        assert int(got[2]) == 4             # one chip can never fit 7B


# --- decode (serving) footprint -----------------------------------------------


class TestDecodeWorkingSet:
    def test_bf16_weights_plus_kv_cache(self):
        cfg = _cfg()
        n_total, _ = param_counts(cfg)
        batch, seq = 16, 1024
        ws = mem.decode_working_set(cfg, batch=batch, seq=seq)
        assert float(ws.params) == 2.0 * n_total
        want_kv = cfg.n_layers * batch * seq * 2.0 * cfg.kv_dim * 2.0
        assert float(ws.kv_cache) == want_kv
        assert float(ws.grads) == float(ws.opt) == 0.0
        assert float(ws.activations) == 0.0

    def test_kv_cache_shards_over_every_axis(self):
        cfg = _cfg()
        base = mem.decode_working_set(cfg, batch=16, seq=1024)
        shard = mem.decode_working_set(cfg, batch=16, seq=1024,
                                       dp=2, tp=2, pp=2)
        assert float(shard.kv_cache) == float(base.kv_cache) / 8.0
        assert float(shard.params) == float(base.params) / 4.0   # tp·pp

    def test_headless_family_has_no_kv_cache(self):
        ws = mem.decode_working_set(_cfg("dlrm-mlp"), batch=512, seq=1)
        assert float(ws.kv_cache) == 0.0
        assert float(ws.params) > 0.0
