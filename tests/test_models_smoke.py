"""Per-arch smoke tests: REDUCED config, one forward + one train step on CPU,
asserting output shapes and finite values (the brief's required per-arch
smoke coverage).  Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_reduced
from repro.data.pipeline import DataConfig, make_stream
from repro.launch import specs as sp
from repro.optim.optimizer import AdamW
from repro.train.loop import (TrainStepConfig, build_train_step,
                              init_train_state, make_loss_fn)

ALL_ARCHS = list(REGISTRY)   # 10 assigned + dlrm-mlp


def _batch_for(cfg, B=2, S=16):
    data = DataConfig(seed=0, global_batch=B, seq_len=S)
    stream = make_stream(cfg, data)
    return jax.tree.map(jnp.asarray, stream.batch(0))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch).replace(compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    batch = _batch_for(cfg)
    loss_fn = make_loss_fn(cfg)
    state = init_train_state(key, cfg, AdamW(learning_rate=1e-3))
    loss, metrics = loss_fn(state.params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    if cfg.family != "mlp":
        # CE at init should be near log(vocab_cap) for the synthetic stream
        v = min(cfg.vocab_size, 512)
        assert float(metrics["ce"]) < np.log(v) * 1.5


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_updates_params(arch):
    cfg = get_reduced(arch).replace(compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    opt = AdamW(learning_rate=1e-2)
    state = init_train_state(key, cfg, opt)
    step = jax.jit(build_train_step(cfg, opt, TrainStepConfig()))
    batch = _batch_for(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # at least one parameter leaf must have moved
    moved = jax.tree_util.tree_leaves(jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params))
    assert any(moved), f"{arch}: no parameter changed"
    # nothing became NaN
    bad = jax.tree_util.tree_leaves(jax.tree.map(
        lambda p: bool(jnp.any(~jnp.isfinite(p))), new_state.params))
    assert not any(bad), f"{arch}: NaN/Inf parameter after one step"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_counts_full_config_scale(arch):
    """Full-config parameter totals are in the right ballpark for the
    published model size (catches config transcription errors)."""
    expected = {
        "whisper-tiny": (0.02e9, 0.08e9),
        "qwen2.5-3b": (2.0e9, 4.0e9),
        "minitron-8b": (7.0e9, 10.0e9),
        "smollm-135m": (0.10e9, 0.18e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "qwen2-moe-a2.7b": (12.0e9, 16.5e9),
        "qwen3-moe-30b-a3b": (27.0e9, 33.0e9),
        "xlstm-125m": (0.10e9, 0.22e9),
        "internvl2-26b": (19.0e9, 27.0e9),   # LM backbone (ViT is stubbed)
        "hymba-1.5b": (1.0e9, 2.0e9),
    }
    from repro.configs import get_config
    total, active = sp.param_counts(get_config(arch))
    lo, hi = expected[arch]
    assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    assert active <= total


def test_moe_active_params_much_smaller_than_total():
    from repro.configs import get_config
    total, active = sp.param_counts(get_config("qwen3-moe-30b-a3b"))
    assert active < total * 0.2   # 3B active of 30B


def test_moe_active_params_use_padded_expert_count():
    """Padding the expert table must not inflate *active* params: the k-of-E
    selection divides by the padded count the router actually scores over
    (regression: divisor used raw n_experts, overcounting active FLOPs)."""
    from repro.configs import get_config
    cfg = get_config("qwen2-moe-a2.7b")
    base_total, base_active = sp.param_counts(cfg)
    padded = cfg.replace(pad_experts_to=64)          # 60 -> 64
    pad_total, pad_active = sp.param_counts(padded)
    assert pad_total > base_total                    # 4 extra expert tensors
    # tensors grow by E_pad/E but the k-of-E_pad fraction shrinks by the
    # same ratio: active per token is invariant under padding (the buggy
    # raw-E divisor inflated it by E_pad/E)
    assert pad_active == pytest.approx(base_active, rel=1e-12)


def test_expert_param_counts_subset_of_totals():
    from repro.configs import get_config
    cfg = get_config("qwen2-moe-a2.7b")
    total, active = sp.param_counts(cfg)
    e_total, e_active = sp.expert_param_counts(cfg)
    assert 0 < e_active < e_total < total
    # routed experts dominate this config's parameter budget
    assert e_total > total * 0.5
    # dense config has no routed experts
    assert sp.expert_param_counts(get_config("qwen2-7b")) == (0.0, 0.0)


def test_balanced_topk_routing_gives_unit_aux_loss():
    """A perfectly balanced top-k assignment must score aux ≈ 1 (the loss's
    fixed point).  Regression: counting only the top-1 choice left ce
    summing to 1/k and dragged balanced aux toward 1/k."""
    from repro.configs import get_config
    from repro.models import moe as moe_mod
    cfg = get_config("qwen2-moe-a2.7b")
    E, k = cfg.n_experts, cfg.moe_top_k
    T = 3 * E
    # token t prefers experts {t, t+1, ..., t+k-1} (mod E): every expert is
    # chosen by exactly T*k/E tokens, i.e. a perfectly balanced router
    logits = np.full((T, E), -20.0, dtype=np.float32)
    for t in range(T):
        for j in range(k):
            logits[t, (t + j) % E] = 20.0
    gates, idx, aux = moe_mod.route(jnp.asarray(logits), cfg)
    assert gates.shape == (T, k) and idx.shape == (T, k)
    counts = np.bincount(np.asarray(idx).ravel(), minlength=E)
    assert (counts == T * k // E).all()
    assert float(aux) == pytest.approx(1.0, rel=1e-3)
