"""obs subsystem: tracer round-trips, metrics registry, explain attribution.

The property that matters most here is pinned twice: the per-candidate
``breakdown`` terms must sum to the planner's priced step time (within
float tolerance — the engine adds them in a different order), and the
qwen2-7b explain JSON is golden-pinned byte-for-byte so an accidental
re-pricing shows up as a diff, not a silent drift.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hardware import get_hardware
from repro.launch.plan_grid import plan_grid
from repro.measure import timers
from repro.measure.microbench import Measurement, WorkUnit
from repro.obs import explain, metrics, trace

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


# --- trace: spans, counters, export, validation -------------------------------


def test_trace_roundtrip_and_validation(tmp_path):
    t = trace.Tracer()
    with t.span("outer", arch="x"):
        with t.span("inner") as sp:
            sp.set(n=3)
        with t.span("inner2"):
            pass
    t.count("things", 2)
    t.count("things", 3)
    path = t.write(str(tmp_path / "t.json"))
    summary = trace.validate_chrome_trace(path)
    assert summary["n_spans"] == 3
    assert summary["n_counter_events"] == 2
    assert summary["max_depth"] == 2
    assert summary["n_threads"] == 1
    assert summary["counters"] == {"things": 5.0}
    with open(path) as f:
        doc = json.load(f)
    args = {e["name"]: e.get("args", {}) for e in doc["traceEvents"]
            if e["ph"] == "X"}
    assert args["inner"] == {"n": 3}          # set() args survive export
    assert "provenance" in doc["otherData"]


def test_trace_write_is_atomic_and_makes_dirs(tmp_path):
    t = trace.Tracer(str(tmp_path / "deep" / "nested" / "t.json"))
    with t.span("s"):
        pass
    path = t.write()
    assert os.path.exists(path) and not os.path.exists(path + ".tmp")


def test_validate_rejects_missing_fields():
    with pytest.raises(ValueError, match="missing 'dur'"):
        trace.validate_chrome_trace(
            {"traceEvents": [{"name": "a", "ph": "X", "ts": 0,
                              "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError, match="negative dur"):
        trace.validate_chrome_trace(
            {"traceEvents": [{"name": "a", "ph": "X", "ts": 0, "dur": -1,
                              "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError, match="traceEvents"):
        trace.validate_chrome_trace({"events": []})


def test_validate_rejects_partial_overlap():
    # [0, 10] and [5, 15] on one thread: neither disjoint nor nested
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="partially overlaps"):
        trace.validate_chrome_trace(bad)


def test_disabled_module_span_is_shared_noop():
    assert not trace.enabled()
    sp = trace.span("anything", heavy_arg=object())
    sp2 = trace.span("other")
    # one shared singleton, no allocation per call site on the hot path
    assert sp is sp2 is trace._NULL_SPAN
    with sp as s:
        s.set(n=1)
    assert trace.count("c") is None
    assert trace.counters() == {}
    assert trace.write() is None


def test_enable_disable_module_tracer(tmp_path):
    try:
        t = trace.enable(str(tmp_path / "m.json"))
        assert trace.enabled() and trace.active() is t
        with trace.span("top", k=1):
            trace.count("seen")
        assert t.n_events == 2
        assert trace.counters() == {"seen": 1}
        path = trace.write()
        assert trace.validate_chrome_trace(path)["n_spans"] == 1
    finally:
        assert trace.disable() is t
    assert not trace.enabled()


# --- metrics registry ---------------------------------------------------------


def test_counter_gauge_histogram():
    reg = metrics.MetricsRegistry()
    c = reg.counter("c")
    assert reg.counter("c") is c          # create-or-get
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    assert g.value is None
    g.set(2.5)
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 2.5
    hs = snap["histograms"]["h"]
    assert hs["count"] == 4 and hs["min"] == 1.0 and hs["max"] == 4.0
    assert hs["p50"] == pytest.approx(2.5)
    assert json.dumps(snap)               # JSON-clean by construction
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_histogram_time_and_section():
    reg = metrics.MetricsRegistry()
    with reg.histogram("lat").time():
        pass
    assert reg.histogram("lat").count == 1
    with reg.section("section.x_s"):
        pass
    assert reg.gauge("section.x_s").value >= 0.0


def test_histogram_window_bounds_memory():
    h = metrics.Histogram("h")
    for i in range(metrics._HIST_WINDOW + 100):
        h.observe(float(i))
    assert len(h._window) == metrics._HIST_WINDOW
    assert h.count == metrics._HIST_WINDOW + 100   # exact stats keep counting


def test_provenance_keys():
    p = metrics.provenance()
    assert set(p) == {"git_sha", "hostname", "wall_clock_utc", "python",
                      "platform", "numpy", "jax"}
    assert p["numpy"] is not None
    assert json.dumps(p)


# --- timers: degenerate-sample spread (satellite a) ---------------------------


def test_rel_spread_nan_below_min_samples():
    for n in (1, 2):
        st = timers.robust_stats([0.5] * n)
        assert math.isnan(st.rel_spread)
        assert "spread not measurable" in st.summary()
    st3 = timers.robust_stats([0.5, 0.5, 0.5])
    assert st3.rel_spread == 0.0          # measured, genuinely stable
    assert "not measurable" not in st3.summary()


def test_rel_spread_nan_fails_noise_gates():
    st = timers.robust_stats([0.5])
    # the reason NaN (not 0.0): an acceptance check must FAIL, not pass
    assert not (st.rel_spread < 0.1)


def test_measurement_nan_spread_json_roundtrip():
    w = WorkUnit("probe", 1e9, 1e6, 0.0)
    m = Measurement(work=w, category="compute", seconds=1.0,
                    best_seconds=1.0, rel_spread=math.nan)
    d = m.to_dict()
    assert d["rel_spread"] is None        # NaN is not valid JSON
    json.dumps(d)
    m2 = Measurement.from_dict(d)
    assert math.isnan(m2.rel_spread)
    # and the non-degenerate path is untouched
    m3 = Measurement.from_dict(Measurement(
        work=w, category="compute", seconds=1.0, best_seconds=1.0,
        rel_spread=0.25).to_dict())
    assert m3.rel_spread == 0.25


# --- explain: attribution terms, prune reasons, golden ------------------------


QWEN = dict(seq=128, zero_stages=(0, 1, 2, 3))


def _qwen_grid(**kw):
    return plan_grid(get_config("qwen2-7b"), get_hardware("tpu_v5e"),
                     [16], [8], **QWEN, **kw)


def test_explain_terms_sum_to_step_time():
    cfg = get_config("dlrm-mlp")
    grid = plan_grid(cfg, get_hardware("clx"), [8, 16], [512, 1024],
                     max_pp=4, zero_stages=(0, 1), explain=True)
    d = explain.explain_dict(grid)
    n = 0
    for point in d["points"]:
        for rec in point["candidates"]:
            total = sum(rec["breakdown"].values())
            assert total == pytest.approx(rec["runtime"], rel=1e-9), \
                f"{rec['mesh']} z{rec['zero_stage']} ({rec['bottleneck']})"
            # the full terms reconstruct each resource time too
            t = rec["terms"]
            assert t["compute"]["alpha"] + t["compute"]["flops"] == \
                pytest.approx(rec["t_compute"], rel=1e-9)
            assert t["memory"]["alpha"] + t["memory"]["bytes"] == \
                pytest.approx(rec["t_memory"], rel=1e-9)
            net = sum(ax["total"] for ax in t["network"].values())
            assert net == pytest.approx(rec["t_network"], rel=1e-9)
            n += 1
    assert n == grid.n_candidates         # every candidate is explained


def test_explain_prune_reasons_match_capacity_mask():
    grid = _qwen_grid(explain=True)
    point = explain.explain_point(grid)
    assert point["prune_reasons"]["capacity"] == int(grid.n_pruned.sum())
    assert point["min_zero_to_fit"] == 2  # qwen2-7b@16 v5e needs ZeRO-2
    kept = point["prune_reasons"]["kept_mesh_tuples"]
    assert kept * len(QWEN["zero_stages"]) == grid.n_enumerated


def test_explain_off_by_default_and_bit_identical():
    g0 = _qwen_grid()
    assert g0.explain_terms is None and g0.prune_reasons is None
    with pytest.raises(ValueError, match="explain=True"):
        explain.explain_dict(g0)
    g1 = _qwen_grid(explain=True)
    # attribution must observe the pricing, never perturb it
    np.testing.assert_array_equal(g0.runtime, g1.runtime)
    np.testing.assert_array_equal(g0.n_pruned, g1.n_pruned)


def test_explain_golden_qwen2_7b():
    grid = _qwen_grid(explain=True)
    got = json.loads(explain.to_json(grid))
    with open(os.path.join(GOLDEN_DIR,
                           "explain_qwen2_7b_c16_zero.json")) as f:
        want = json.load(f)
    assert got == want, (
        "explain attribution drifted from tests/golden/"
        "explain_qwen2_7b_c16_zero.json — if the pricing change is "
        "intentional, regenerate the golden and say so in the PR")


def test_explain_table_and_prune_line_render():
    grid = _qwen_grid(explain=True)
    point = explain.explain_point(grid)
    table = explain.format_explain_table(point["candidates"])
    assert "step ms" in table and "dp4xtp4" in table
    line = explain.format_prune_reasons(point)
    assert "capacity=5" in line and "ZeRO-2" in line


def test_plan_grid_emits_spans_when_traced(tmp_path):
    try:
        trace.enable(str(tmp_path / "plan.json"))
        _qwen_grid(explain=True)
        names = {e["name"] for e in trace.active().to_dict()["traceEvents"]}
    finally:
        trace.disable()
    assert {"plan_grid", "plan_grid.enumerate", "plan_grid.feasibility",
            "plan_grid.price_collectives", "plan_grid.sweep_classify",
            "core.sweep"} <= names
    assert {"planner.candidates_enumerated",
            "planner.candidates_evaluated"} <= names  # counter tracks


def test_explain_cli_json(capsys):
    from repro.launch import plan as plan_mod
    rc = plan_mod.main(["--arch", "qwen2-7b", "--hardware", "tpu_v5e",
                        "--chips", "16", "--batch", "8", "--seq", "128",
                        "--zero", "auto", "--explain", "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    ex = doc["explain"]
    assert ex["schema"] == explain.EXPLAIN_SCHEMA
    recs = ex["points"][0]["candidates"]
    assert [r["mesh"] for r in recs][0] == doc["plans"][0]["mesh"]
