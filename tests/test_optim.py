"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim.compression import (Int8Compressor,
                                     TopKCompressor)
from repro.optim.optimizer import (SGD, AdamW, apply_updates, global_norm,
                                   warmup_cosine)


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(learning_rate=0.1, weight_decay=0.0)
        params = {"x": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2

    def test_weight_decay_shrinks(self):
        opt = AdamW(learning_rate=0.1, weight_decay=0.5, clip_norm=0)
        params = {"x": jnp.array([10.0])}
        state = opt.init(params)
        updates, _ = opt.update({"x": jnp.array([0.0])}, state, params)
        assert float(updates["x"][0]) < 0  # decay pulls toward zero

    def test_clip_bounds_update(self):
        opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
        params = {"x": jnp.zeros(4)}
        state = opt.init(params)
        g = {"x": jnp.full(4, 1e6)}
        _, state = opt.update(g, state, params)
        # first moment reflects the clipped gradient
        assert float(global_norm(state.mu)) <= 0.12

    def test_sgd_momentum(self):
        opt = SGD(learning_rate=0.05, momentum=0.9)
        params = {"x": jnp.array([4.0])}
        state = opt.init(params)
        for _ in range(250):
            updates, state = opt.update({"x": 2 * params["x"]}, state, params)
            params = apply_updates(params, updates)
        assert abs(float(params["x"][0])) < 1e-2


class TestSchedule:
    def test_warmup_then_cosine(self):
        sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
        assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
        assert float(sched(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
        mid = float(sched(jnp.int32(55)))
        assert 0.1 < mid < 1.0


class TestInt8Compression:
    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip_error_bounded(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (1000,))
        comp = Int8Compressor(chunk=256)
        state = comp.init({"g": g})
        deq, state = comp.round_trip_tree({"g": g}, state)
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert float(jnp.max(jnp.abs(deq["g"] - g))) <= scale * 1.01

    def test_error_feedback_accumulates(self):
        """Residual carries the quantization error to the next step: the
        SUM of decompressed grads over steps tracks the true sum."""
        comp = Int8Compressor(chunk=64)
        g = {"g": jnp.full((64,), 0.003)}   # small vs scale -> big rel error
        state = comp.init(g)
        total = jnp.zeros(64)
        for _ in range(50):
            deq, state = comp.round_trip_tree(g, state)
            total = total + deq["g"]
        np.testing.assert_allclose(total, 50 * 0.003 * jnp.ones(64),
                                   rtol=0.05)

    def test_wire_fraction(self):
        assert Int8Compressor(chunk=4096).wire_fraction == pytest.approx(
            0.2502, abs=1e-3)

    @pytest.mark.slow
    def test_training_with_compression_still_converges(self):
        from repro.optim.compression import StatelessRoundTrip
        comp = StatelessRoundTrip(Int8Compressor(chunk=128))
        opt = AdamW(learning_rate=0.1, weight_decay=0.0)
        params = {"x": jnp.array([5.0, -3.0, 2.0, -1.0] * 32)}
        state = opt.init(params)
        for _ in range(300):
            grads = comp.round_trip({"x": 2 * params["x"]})
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


class TestTopK:
    def test_keeps_largest(self):
        comp = TopKCompressor(keep=0.1)
        g = {"g": jnp.arange(100.0)}
        state = comp.init(g)
        deq, state = comp.round_trip_tree(g, state)
        kept = np.asarray(deq["g"])
        assert (kept[:90] == 0).all() and (kept[90:] > 0).all()

    def test_error_feedback_recovers_small_entries(self):
        comp = TopKCompressor(keep=0.05)
        g = {"g": jnp.ones(100) * 0.01}
        state = comp.init(g)
        total = jnp.zeros(100)
        for _ in range(100):
            deq, state = comp.round_trip_tree(g, state)
            total = total + deq["g"]
        # every coordinate eventually transmitted via residual accumulation
        assert float(jnp.min(total)) > 0.5
