"""ISSUE 5 regression tests: the grid-scale vectorized planner.

Four layers:

  * properties (hypothesis via ``tests/_hypothesis_compat``):
    ``collectives.best_all_reduce_grid`` agrees elementwise with the
    scalar argmin, and the whole grid engine agrees with a
    straightforward per-candidate scalar reference (dp/tp/pp, pod
    routing, auto algorithm selection, 1F1B fill);
  * pinned pp = 1 bit-parity: the grid slice reproduces the committed
    PR 4 planner output (``tests/golden/plan_pr4_*.json``) exactly —
    ranking, runtimes, per-axis algorithms, every float bit-for-bit;
  * the pipeline model itself: feasibility (pp | n_layers,
    m | batch/dp), the (m + pp − 1) fill algebra, p2p link routing;
  * BENCH regression: the committed ``BENCH_ridgeline.json`` must record
    ≥ 10⁵ candidates/s on the grid path and ≥ 10× speedup over per-point
    ``plan()`` looping.

ISSUE 6 additions: head-divisibility (tp | n_heads, and tp | n_kv_heads
under GQA) over every shipped config; the m ≥ pp 1F1B clamp; the memory
feasibility cut (default flags never rank a candidate over
``hbm_capacity_bytes``; the pinned ZeRO-flip golden where the
unconstrained winner is infeasible and ZeRO-2 flips the ranking); and the
masked-grid throughput pin from ``planner_feasibility``.
"""
import json
import os

import numpy as np
import pytest

from repro.core.hardware import CLX, TPU_V5E, HardwareSpec
from repro.distributed import collectives as coll
from repro.launch import plan_grid as pg
from repro.launch.plan import plan
from tests._hypothesis_compat import given, settings, st

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden")

ALPHA_POD = HardwareSpec(
    "alpha_pod", peak_flops=197e12, hbm_bw=819e9, net_bw=50e9,
    extra_links={"pod": 25e9}, alpha_network=1e-5,
    link_alphas={"pod": 5e-5})


def _cfg(name="dlrm-mlp"):
    from repro.configs import get_config
    return get_config(name)


# --- vectorized best_all_reduce == scalar argmin ------------------------------


class TestBestAllReduceGrid:
    @settings(max_examples=60)
    @given(payload=st.floats(min_value=1.0, max_value=1e12),
           n=st.integers(min_value=1, max_value=2048),
           bw=st.floats(min_value=1e6, max_value=1e12),
           alpha=st.one_of(st.just(0.0),
                           st.floats(min_value=1e-9, max_value=1e-2)))
    def test_property_elementwise_matches_scalar(self, payload, n, bw,
                                                 alpha):
        """Each element of a mixed grid selects what the scalar selects,
        with identical wire bytes / steps — including the tie-break."""
        payloads = np.array([payload, payload * 3.0, 1.0])
        ns = np.array([n, max(1, n // 2), n])
        wire, steps, idx = coll.best_all_reduce_grid(payloads, ns, bw, alpha)
        for i in range(payloads.size):
            algo, cost = coll.best_all_reduce(float(payloads[i]),
                                              float(ns[i]), bw, alpha)
            assert coll.ALGORITHMS[int(idx[i])] == algo
            assert float(wire[i]) == float(cost.wire_bytes)
            assert float(steps[i]) == float(cost.steps)

    def test_per_element_link_terms(self):
        """bw and alpha broadcast per element (the per-axis link gather)."""
        payload, n = 1e5, 16
        bws = np.array([50e9, 25e9])
        alphas = np.array([0.0, 5e-5])
        _, _, idx = coll.best_all_reduce_grid(payload, n, bws, alphas)
        for i in range(2):
            algo, _ = coll.best_all_reduce(payload, n, float(bws[i]),
                                           float(alphas[i]))
            assert coll.ALGORITHMS[int(idx[i])] == algo

    def test_allowed_mask_pins_fixed_algorithms(self):
        payload = np.array([1e3, 1e9])
        allowed = np.zeros((len(coll.ALGORITHMS), 2), dtype=bool)
        allowed[coll.ALGORITHMS.index("tree"), :] = True
        wire, steps, idx = coll.best_all_reduce_grid(
            payload, 16, 50e9, 1e-5, allowed=allowed)
        assert [coll.ALGORITHMS[int(i)] for i in idx] == ["tree", "tree"]
        want = coll.all_reduce(payload, 16.0, "tree")
        assert np.array_equal(wire, want.wire_bytes)
        assert np.array_equal(steps, np.broadcast_to(want.steps, (2,)))

    def test_empty_menu_raises(self):
        with pytest.raises(ValueError, match="at least one"):
            coll.best_all_reduce_grid(1.0, 4, 1e9, algorithms=())

    def test_fully_masked_element_raises(self):
        """A column with no allowed algorithm is a caller bug, not a
        silent algorithm-0 selection."""
        allowed = np.array([[True, False], [True, False], [True, False]])
        with pytest.raises(ValueError, match="excludes every algorithm"):
            coll.best_all_reduce_grid(np.array([1e3, 1e6]), 8, 1e9,
                                      allowed=allowed)


# --- the scalar reference the grid must agree with ----------------------------


def _scalar_reference(cfg, hw, chips, batch, seq, pod_size, max_pp,
                      algorithms):
    """Straightforward per-candidate evaluation — the model, stated plainly.

    Returns {(dp, tp, pp, m, algo_requested): dict of quantities}.
    Deliberately re-derives everything with scalar calls (no grid code) so
    elementwise agreement is a real check, not a tautology.
    """
    n_total, n_active = pg.param_counts(cfg)
    width = pg._model_width(cfg)
    tokens = float(batch) if cfg.family == "mlp" else float(batch) * seq
    act_dtype = 4 if cfg.family == "mlp" else 2
    syncs = 4.0 if cfg.family in pg._ATTENTION_FAMILIES else 2.0
    params_bytes = n_total * 4.0
    eff = hw.compute_eff.eff

    def link_of(n, inner):
        if pod_size is None or n <= 1 or n * inner <= pod_size:
            return None
        return "pod"

    def axis(payload, n, link, algo):
        """(algo_name, time, wire, steps) of one axis under one request."""
        bw, alpha = hw.bandwidth_for(link), hw.alpha_for(link)
        if n <= 1:
            return "-", 0.0, 0.0, 0.0
        if algo == "auto":
            name, cost = coll.best_all_reduce(payload, n, bw, alpha)
        else:
            name = coll.canonical_algorithm(algo)
            cost = coll.all_reduce(payload, n, name)
        return name, float(cost.time(bw, alpha)), \
            float(cost.wire_bytes), float(cost.steps)

    out = {}
    for pp in pg.pp_choices(cfg, chips, max_pp):
        for dp, tp in pg._factor_pairs(chips // pp):
            if batch % dp or \
                    not pg._tp_ok(tp, width, cfg.n_heads, cfg.n_kv_heads):
                continue
            for m in pg.microbatch_choices(batch // dp, pp):
                fill = m + pp - 1.0
                # ceil split: when pp ∤ n_layers the widest stage is the
                # critical path (ISSUE 9); exact n_layers/pp when pp | L
                stage_layers = float(np.ceil(cfg.n_layers / pp))
                f_step = 6.0 * n_active * tokens / (dp * tp * pp) \
                    * (stage_layers * pp / cfg.n_layers)
                f_mb = f_step / m
                act = (tokens / dp) * width * act_dtype
                act_mb = act / m
                mem_mb = params_bytes / (tp * pp) \
                    + 2.0 * stage_layers * act_mb
                dp_link = link_of(dp, tp * pp)
                tp_link = link_of(tp, 1)
                pp_link = link_of(pp, tp)
                for algo in algorithms:
                    dp_algo, dp_t, _, _ = axis(params_bytes / (tp * pp),
                                               dp, dp_link, algo)
                    tp_algo, tp_t1, _, _ = axis(act_mb, tp, tp_link, algo)
                    tp_t = syncs * stage_layers * tp_t1
                    pp_t = 0.0
                    if pp > 1:
                        pp_t = hw.alpha_for(pp_link) * 2.0 \
                            + 2.0 * act_mb / hw.bandwidth_for(pp_link)
                    t_n = fill * (tp_t + pp_t) + dp_t
                    t_c = fill * ((hw.alpha_compute if f_mb > 0 else 0.0)
                                  + f_mb / (hw.peak_flops * eff(f_mb)))
                    t_m = fill * ((hw.alpha_memory if mem_mb > 0 else 0.0)
                                  + mem_mb / hw.hbm_bw)
                    out[(dp, tp, pp, m, algo)] = {
                        "runtime": max(t_c, t_m, t_n),
                        "t_compute": t_c, "t_memory": t_m, "t_network": t_n,
                        "dp_algo": dp_algo, "tp_algo": tp_algo,
                        "dp_link": dp_link or "ici",
                        "tp_link": tp_link or "ici",
                        "pp_link": pp_link or "ici",
                        "flops": f_step}
    return out


class TestGridMatchesScalarReference:
    @settings(max_examples=20)
    @given(chips=st.sampled_from([4, 8, 16, 32]),
           batch=st.sampled_from([32, 64, 512]),
           pod=st.sampled_from([None, 4, 8]),
           max_pp=st.sampled_from([1, 2, 4, 8]),
           alpha_n=st.one_of(st.just(0.0),
                             st.floats(min_value=1e-8, max_value=1e-4)))
    def test_property_elementwise_agreement(self, chips, batch, pod,
                                            max_pp, alpha_n):
        cfg = _cfg()
        hw = HardwareSpec("box", 197e12, 819e9, 50e9,
                          extra_links={"pod": 25e9}, alpha_network=alpha_n,
                          link_alphas={"pod": 5.0 * alpha_n})
        plans = plan(cfg, hw, chips, batch=batch, pod_size=pod,
                     max_pp=max_pp)
        ref = _scalar_reference(cfg, hw, chips, batch, 1, pod, max_pp,
                                ("auto",))
        assert len(plans) == len(ref)
        for p in plans:
            r = ref[(p.dp, p.tp, p.pp, p.microbatches, p.algorithm)]
            assert p.runtime == pytest.approx(r["runtime"], rel=1e-9)
            assert p.t_compute == pytest.approx(r["t_compute"], rel=1e-9,
                                                abs=1e-300)
            assert p.t_memory == pytest.approx(r["t_memory"], rel=1e-9)
            assert p.t_network == pytest.approx(r["t_network"], rel=1e-9,
                                                abs=1e-300)
            assert p.flops == pytest.approx(r["flops"], rel=1e-12)
            assert (p.dp_algo, p.tp_algo) == (r["dp_algo"], r["tp_algo"])
            assert (p.dp_link, p.tp_link, p.pp_link) == \
                (r["dp_link"], r["tp_link"], r["pp_link"])

    def test_fixed_algorithms_agree_too(self):
        cfg = _cfg()
        for algo in coll.ALGORITHMS:
            plans = plan(cfg, ALPHA_POD, 16, batch=64, pod_size=8,
                         max_pp=4, algorithms=(algo,))
            ref = _scalar_reference(cfg, ALPHA_POD, 16, 64, 1, 8, 4,
                                    (algo,))
            assert len(plans) == len(ref)
            for p in plans:
                r = ref[(p.dp, p.tp, p.pp, p.microbatches, algo)]
                assert p.runtime == pytest.approx(r["runtime"], rel=1e-9)
                assert (p.dp_algo, p.tp_algo) == (r["dp_algo"], r["tp_algo"])


# --- pinned pp = 1 bit-parity with the PR 4 planner ---------------------------


def _golden(fname):
    path = os.path.join(_GOLDEN_DIR, fname)
    with open(path) as f:
        return json.load(f)


def _assert_bit_identical(plans, golden):
    """Every float of every golden plan must survive the grid rewrite
    bit-for-bit (JSON repr round-trips doubles exactly)."""
    assert [p.mesh for p in plans] == [g["mesh"] for g in golden["plans"]]
    from repro.launch.plan import _plan_dict
    for p, g in zip(plans, golden["plans"]):
        d = _plan_dict(p)
        for key, want in g.items():
            assert d[key] == want, (p.mesh, key, want, d[key])


class TestPinnedPr4Parity:
    def test_dlrm_mlp_chips16(self):
        g = _golden("plan_pr4_dlrm_mlp_c16.json")
        plans = plan(_cfg("dlrm-mlp"), TPU_V5E, 16, batch=g["batch"])
        _assert_bit_identical(plans, g)

    @pytest.mark.slow
    def test_qwen2_7b_chips32_pod16(self):
        """The golden predates two ISSUE 6 fixes, so its comparable slice
        is the rows a correct planner still enumerates: tp must divide
        n_kv_heads = 4 (the old planner priced tp = 8..32 layouts the
        sharding layer would have replaced), and the capacity check is
        disabled (batch 256 × seq 4096 does not fit a 16 GB v5e at
        ZeRO-0 — the old planner silently recommended it anyway).  Every
        surviving row must still be bit-identical."""
        g = _golden("plan_pr4_qwen2_7b_c32_pod16.json")
        cfg = _cfg("qwen2-7b")
        keep = [row for row in g["plans"]
                if cfg.n_kv_heads % row["tp"] == 0]
        assert len(keep) >= 3               # the slice is not vacuous
        assert len(keep) < len(g["plans"])  # and the fix does remove rows
        g = dict(g, plans=keep)
        plans = plan(cfg, TPU_V5E, 32, batch=g["batch"],
                     seq=g["seq"], pod_size=g["pod_size"],
                     check_capacity=False)
        _assert_bit_identical(plans, g)
        assert not any(p.fits for p in plans)   # why the check is off

    def test_pp1_candidates_identical_inside_larger_grid(self):
        """The pp = 1 rows of a max_pp > 1 search carry the exact same
        numbers as the pure dp × tp search — the pipeline axis only adds
        candidates, never perturbs existing ones."""
        cfg = _cfg()
        base = {(p.dp, p.tp): p for p in plan(cfg, TPU_V5E, 16, batch=512)}
        wide = [p for p in plan(cfg, TPU_V5E, 16, batch=512, max_pp=8)
                if p.pp == 1]
        assert {(p.dp, p.tp) for p in wide} == set(base)
        for p in wide:
            b = base[(p.dp, p.tp)]
            assert (p.runtime, p.t_compute, p.t_memory, p.t_network) == \
                (b.runtime, b.t_compute, b.t_memory, b.t_network)
            assert (p.dp_algo, p.tp_algo) == (b.dp_algo, b.tp_algo)
            assert p.microbatches == 1


# --- head divisibility: tp | n_heads (and n_kv_heads under GQA) ---------------


class TestHeadDivisibility:
    def test_every_shipped_config_only_gets_head_safe_tp(self):
        """Regression for the ISSUE 6 bugfix: ``feasible_meshes`` used to
        check only ``width % tp``, so attention models were offered tp
        splits the sharding layer cannot express head-wise (and, under
        GQA, splits that fracture the KV heads)."""
        from repro.configs import get_config, list_archs
        checked = 0
        for name in list_archs():
            cfg = get_config(name)
            if not cfg.n_heads:
                continue
            for chips in (8, 16, 32, 64):
                for _, tp in pg.feasible_meshes(cfg, chips, 3072):
                    assert cfg.n_heads % tp == 0, (name, tp)
                    if 0 < cfg.n_kv_heads < cfg.n_heads:
                        assert cfg.n_kv_heads % tp == 0, (name, tp)
                    checked += 1
        assert checked > 0

    def test_gqa_kv_heads_bound_tp(self):
        cfg = _cfg("qwen2-7b")              # 28 heads, 4 KV heads
        tps = {tp for _, tp in pg.feasible_meshes(cfg, 32, 256)}
        assert tps == {1, 2, 4}             # 8/16/32 fracture the KV heads

    def test_headless_families_only_need_width(self):
        cfg = _cfg("dlrm-mlp")              # n_heads == 0
        tps = {tp for _, tp in pg.feasible_meshes(cfg, 32, 256)}
        assert tps == {1, 2, 4, 8, 16, 32}

    def test_tp_ok_scalar_cases(self):
        assert pg._tp_ok(8, 4096, 0, 0)           # headless: width only
        assert pg._tp_ok(4, 3584, 28, 4)
        assert not pg._tp_ok(8, 3584, 28, 4)      # fractures KV heads
        assert not pg._tp_ok(3, 4096, 32, 32)     # width % tp
        assert not pg._tp_ok(16, 4096, 24, 24)    # heads % tp
        assert pg._tp_ok(1, 7, 5, 1)              # tp = 1 always fine

    def test_infeasible_error_names_the_head_constraint(self):
        cfg = _cfg("qwen2-7b")
        # 56 chips, batch 1: dp must be 1, so tp = 56 > 4 KV heads
        with pytest.raises(ValueError, match="n_heads=28"):
            pg.plan_grid(cfg, TPU_V5E, [56], [1], seq=8,
                         check_capacity=False)


# --- the pipeline model itself ------------------------------------------------


class TestPipelineAxis:
    def test_pp_divides_layers_and_m_divides_per_dp_batch(self):
        cfg = _cfg()                       # n_layers = 8
        plans = plan(cfg, TPU_V5E, 16, batch=96, max_pp=16)
        assert any(p.pp > 1 for p in plans)
        for p in plans:
            assert cfg.n_layers % p.pp == 0
            assert p.dp * p.tp * p.pp == 16 == p.chips
            assert 96 % p.dp == 0
            assert (96 // p.dp) % p.microbatches == 0
            if p.pp == 1:
                assert p.microbatches == 1
        # pp = 16 does not divide 8 layers -> never enumerated
        assert all(p.pp in (1, 2, 4, 8) for p in plans)

    def test_fill_factor_algebra(self):
        """A pp candidate's resource times carry exactly the 1F1B fill
        (m + pp − 1) over its per-microbatch compute time."""
        cfg = _cfg()
        plans = plan(cfg, CLX, 8, batch=512, max_pp=4)
        n_total, n_active = pg.param_counts(cfg)
        for p in plans:
            if p.pp == 1:
                continue
            fill = p.microbatches + p.pp - 1.0
            f_mb = p.flops / p.microbatches
            want_tc = fill * (f_mb / CLX.peak_flops)
            assert p.t_compute == pytest.approx(want_tc, rel=1e-12)
            assert p.runtime == pytest.approx(
                max(p.t_compute, p.t_memory, p.t_network), rel=1e-12)
            assert 0.0 < p.bubble_fraction < 1.0

    def test_more_microbatches_shrink_the_bubble(self):
        """With α = 0 the fill overhead is the only cost of small m on the
        compute term: t_compute is non-increasing in m at fixed mesh."""
        cfg = _cfg()
        plans = [p for p in plan(cfg, CLX, 8, batch=512, max_pp=4)
                 if (p.dp, p.tp, p.pp) == (1, 1, 8)] or \
                [p for p in plan(cfg, CLX, 8, batch=512, max_pp=8)
                 if p.pp == 8]
        by_m = sorted(plans, key=lambda p: p.microbatches)
        ts = [p.t_compute for p in by_m]
        assert ts == sorted(ts, reverse=True)

    def test_pp_p2p_rides_the_pod_link_when_axis_spans_pods(self):
        cfg = _cfg()
        plans = plan(cfg, ALPHA_POD, 32, batch=64, pod_size=4, max_pp=8)
        spanning = [p for p in plans if p.pp > 1 and p.pp * p.tp > 4]
        contained = [p for p in plans if p.pp > 1 and p.pp * p.tp <= 4]
        assert spanning and contained
        assert all(p.pp_link == "pod" for p in spanning)
        assert all(p.pp_link == "ici" for p in contained)

    def test_pipelining_can_win_when_network_bound(self):
        """The acceptance scenario: with more chips than the dp × tp
        space can use well, a pipelined mesh must rank strictly better."""
        cfg = _cfg()
        flat = plan(cfg, CLX, 64, batch=256)[0]
        piped = plan(cfg, CLX, 64, batch=256, max_pp=8)[0]
        assert piped.pp > 1
        assert piped.runtime < flat.runtime

    @settings(max_examples=60)
    @given(batch_per_dp=st.integers(min_value=1, max_value=768),
           pp=st.integers(min_value=1, max_value=16))
    def test_property_microbatch_choices_fill_the_pipeline(self, batch_per_dp,
                                                           pp):
        """ISSUE 6 bugfix: m < pp describes a pipeline that never fills —
        every offered m divides the per-dp batch AND is ≥ pp (pp = 1
        stays pinned to m = 1)."""
        ms = pg.microbatch_choices(batch_per_dp, pp)
        if pp <= 1:
            assert ms == (1,)
            return
        for m in ms:
            assert batch_per_dp % m == 0
            assert m >= pp
        # every valid divisor ≥ pp is offered — the clamp removes only
        # the never-filling ones
        assert ms == tuple(d for d in pg._divisors(batch_per_dp) if d >= pp)

    @settings(max_examples=15)
    @given(chips=st.sampled_from([8, 16, 32, 64]),
           batch=st.sampled_from([64, 96, 256]),
           max_pp=st.sampled_from([2, 4, 8, 16]))
    def test_property_best_plan_never_starves_the_pipeline(self, chips,
                                                           batch, max_pp):
        for p in plan(_cfg(), CLX, chips, batch=batch, max_pp=max_pp):
            assert p.microbatches >= p.pp or p.pp == 1
            if p.pp == 1:
                assert p.microbatches == 1

    def test_starved_pp_pair_is_dropped_not_mispriced(self):
        """A per-dp batch of 4 has no m ≥ 8 divisor: the pp = 8 pairs
        must vanish rather than price a phantom under-filled pipeline
        (the old planner offered m ∈ {1, 2, 4} there)."""
        cfg = _cfg()                        # n_layers = 8
        plans = plan(cfg, CLX, 8, batch=4, max_pp=8)
        assert plans                        # pp ∈ {1, 2, 4} still exist
        assert any(p.pp == 4 for p in plans)
        assert not any(p.pp == 8 for p in plans)
        assert all((4 // p.dp) % p.microbatches == 0 for p in plans)


# --- expert parallelism: the ep mesh axis (ISSUE 9) ---------------------------


def _moe_cfg():
    return _cfg("qwen2-moe-a2.7b")         # 60 routed experts, top-4, cf 1.25


class TestExpertParallelAxis:
    def test_ep_choices_divide_padded_expert_count(self):
        cfg = _moe_cfg()                    # E_pad = 60
        assert pg.ep_choices(cfg, 16, 16) == [1, 2, 4]   # 8, 16 ∤ 60
        assert pg.ep_choices(cfg, 16, 2) == [1, 2]       # max_ep caps
        padded = cfg.replace(pad_experts_to=64)
        assert pg.ep_choices(padded, 16, 16) == [1, 2, 4, 8, 16]
        dense = _cfg("qwen2-7b")
        assert pg.ep_choices(dense, 16, 16) == [1]       # no routed experts

    def test_ep1_candidates_identical_inside_larger_grid(self):
        """The ep axis only adds candidates — ep = 1 rows carry the exact
        same numbers as a search that never heard of expert parallelism."""
        cfg = _moe_cfg()
        base = {(p.dp, p.tp, p.pp, p.microbatches, p.zero_stage): p
                for p in plan(cfg, TPU_V5E, 16, batch=16, seq=512,
                              max_pp=2, check_capacity=False)}
        wide = [p for p in plan(cfg, TPU_V5E, 16, batch=16, seq=512,
                                max_pp=2, max_ep=4, check_capacity=False)
                if p.ep == 1]
        assert {(p.dp, p.tp, p.pp, p.microbatches, p.zero_stage)
                for p in wide} == set(base)
        for p in wide:
            b = base[(p.dp, p.tp, p.pp, p.microbatches, p.zero_stage)]
            assert (p.runtime, p.t_compute, p.t_memory, p.t_network) == \
                (b.runtime, b.t_compute, b.t_memory, b.t_network)
            assert (p.mesh, p.hbm_bytes, p.flops) == \
                (b.mesh, b.hbm_bytes, b.flops)

    def test_ep_meshes_use_all_chips_and_divide_experts(self):
        plans = plan(_moe_cfg(), TPU_V5E, 16, batch=16, seq=512, max_pp=2,
                     max_ep=4, check_capacity=False)
        assert any(p.ep > 1 for p in plans)
        for p in plans:
            assert p.dp * p.tp * p.pp * p.ep == 16 == p.chips
            if p.ep > 1:
                assert 60 % p.ep == 0
                assert f"xep{p.ep}" in p.mesh
            else:
                assert "xep" not in p.mesh

    def test_ep_dispatch_pricing_matches_scalar_recomputation(self):
        """An ep > 1 row's attributed dispatch+combine time re-derives
        exactly from scalar collective calls: fill · layers-per-stage ·
        (α·steps + derated wire / bw) on the axis's own link."""
        cfg = _moe_cfg()
        hw = ALPHA_POD                      # nonzero α so both terms bite
        grid = pg.plan_grid(cfg, hw, [16], [16], seq=512, max_pp=2,
                            max_ep=4, check_capacity=False, explain=True)
        t = grid.explain_terms
        width = pg._model_width(cfg)
        tokens = 16.0 * 512
        checked = 0
        for i in range(grid.runtime.size):
            ep = int(grid.ep[i])
            if ep <= 1:
                assert t.net_ep_alpha_s[i] == 0.0
                assert t.net_ep_bytes_s[i] == 0.0
                continue
            checked += 1
            dp, pp = float(grid.dp[i]), float(grid.pp[i])
            m = float(grid.microbatches[i])
            fill = m + pp - 1.0
            act_mb = (tokens / dp) * width * 2 / m
            payload = act_mb * cfg.moe_top_k * cfg.capacity_factor
            cost = coll.ep_dispatch_combine(payload, ep)
            derate = float(pg.moe_routing_derate(
                np.float64(ep), np.float64(tokens / (dp * m)),
                n_experts=cfg.n_experts, pad_experts=cfg.pad_experts_to,
                top_k=cfg.moe_top_k, capacity_factor=cfg.capacity_factor))
            link = "pod" if grid.ep_pod[i] else None
            stage_layers = float(np.ceil(cfg.n_layers / pp))
            assert t.net_ep_alpha_s[i] == pytest.approx(
                fill * hw.alpha_for(link) * stage_layers * cost.steps,
                rel=1e-12)
            assert t.net_ep_bytes_s[i] == pytest.approx(
                fill * stage_layers * cost.wire_bytes * derate
                / hw.bandwidth_for(link), rel=1e-12)
        assert checked > 0

    def test_ep_a2a_rides_the_pod_link_when_axis_spans_pods(self):
        """ep nests outside tp: the dispatch all-to-all leaves the pod
        exactly when ep · tp exceeds the pod size."""
        plans = plan(_moe_cfg(), ALPHA_POD, 16, batch=16, seq=512,
                     pod_size=4, max_pp=2, max_ep=4, check_capacity=False)
        spanning = [p for p in plans if p.ep > 1 and p.ep * p.tp > 4]
        contained = [p for p in plans if p.ep > 1 and p.ep * p.tp <= 4]
        assert spanning and contained
        assert all(p.ep_link == "pod" for p in spanning)
        assert all(p.ep_link == "ici" for p in contained)

    def test_routing_derate_properties(self):
        kw = dict(n_experts=60, pad_experts=0, top_k=4,
                  capacity_factor=1.25)
        # ep = 1 is exactly 1.0 — the dense slice stays bit-identical
        assert pg.moe_routing_derate(
            np.array([1.0]), np.array([4096.0]), **kw)[0] == 1.0
        # imbalance always costs, and costs more with more shards
        d = pg.moe_routing_derate(np.array([2.0, 4.0]),
                                  np.array([4096.0, 4096.0]), **kw)
        assert (d > 1.0).all() and d[1] > d[0]
        # more tokens per shard → tighter concentration → smaller derate
        busy = pg.moe_routing_derate(np.array([4.0]), np.array([65536.0]),
                                     **kw)
        assert busy[0] < d[1]
        # the capacity factor caps what overflow can cost
        starved = pg.moe_routing_derate(np.array([60.0]), np.array([1.0]),
                                        **kw)
        assert starved[0] <= 1.25 * (1.0 + 1e-12)
        # padding experts dilutes real ones: E_pad/E shows up directly
        pad = pg.moe_routing_derate(np.array([2.0]), np.array([4096.0]),
                                    n_experts=60, pad_experts=64, top_k=4,
                                    capacity_factor=1.25)
        assert pad[0] > d[0]

    def test_dense_config_rejects_ep_request(self):
        with pytest.raises(ValueError, match="max_ep"):
            pg.plan_grid(_cfg(), CLX, [8], [512], max_ep=0)

    def test_pinned_pr9_moe_golden(self):
        """The ISSUE 9 acceptance golden: qwen2-moe on 16 v5e chips with
        the ep axis open.  Committed bit-for-bit; the capacity check is
        off because a 14 B fp32 working set does not fit 16 GB chips at
        these meshes (same precedent as the PR 4 pod golden).  The grid
        must still rank ep > 1 meshes whose network term is dominated by
        the dispatch+combine all-to-all."""
        g = _golden("plan_pr9_qwen2_moe_c16_ep.json")
        cfg = _moe_cfg()
        plans = plan(cfg, TPU_V5E, 16, batch=g["batch"], seq=g["seq"],
                     max_pp=g["max_pp"], max_ep=g["max_ep"],
                     check_capacity=False)
        _assert_bit_identical(plans, g)
        ep_rows = [p for p in plans if p.ep > 1]
        assert len(ep_rows) >= 10
        # attribution: the best ep > 1 row is network-bound on dispatch
        grid = pg.plan_grid(cfg, TPU_V5E, [16], [g["batch"]], seq=g["seq"],
                            max_pp=g["max_pp"], max_ep=g["max_ep"],
                            check_capacity=False, explain=True)
        t = grid.explain_terms
        i = min(np.flatnonzero(grid.ep > 1),
                key=lambda j: grid.runtime[j])
        ep_s = t.net_ep_alpha_s[i] + t.net_ep_bytes_s[i]
        assert ep_s > 0.5 * grid.t_network[i]      # a2a dominates network
        assert grid.t_network[i] == grid.runtime[i]  # and network binds


# --- uneven pipeline stages + interleaved 1F1B (ISSUE 9) ----------------------


class TestUnevenAndInterleavedPipeline:
    def test_indivisible_pp_priced_with_ceil_stage(self):
        """28 layers / pp 8 → 4-layer widest stage: flops carry exactly
        the 32/28 round-up, and the mesh is enumerated at all (the old
        planner required pp | n_layers)."""
        cfg = _cfg("qwen2-7b")              # 28 layers
        plans = plan(cfg, TPU_V5E, 16, batch=16, seq=128, max_pp=8,
                     check_capacity=False)
        _, n_active = pg.param_counts(cfg)
        tokens = 16.0 * 128
        p8 = [p for p in plans if p.pp == 8]
        assert p8
        for p in p8:
            want = 6.0 * n_active * tokens / (p.dp * p.tp * 8) * (32.0 / 28.0)
            assert p.flops == pytest.approx(want, rel=1e-12)

    def test_pp_beyond_layer_count_is_pruned(self):
        cfg = _cfg()                        # 8 layers
        plans = plan(cfg, CLX, 64, batch=64, max_pp=64)
        assert all(p.pp <= 8 for p in plans)
        grid = pg.plan_grid(cfg, CLX, [64], [64], max_pp=64, explain=True)
        stats = grid.prune_reasons[(0, 0)]
        assert stats["pp_exceeds_layers"] > 0

    def test_interleave_shrinks_bubble_and_grows_p2p(self):
        """Interleaved 1F1B divides the bubble by the virtual-stage count
        and multiplies the boundary p2p traffic by it."""
        cfg = _cfg("qwen2-7b")              # 28 layers
        kw = dict(batch=16, seq=128, max_pp=4, check_capacity=False)
        base = {(p.dp, p.tp, p.pp, p.microbatches): p
                for p in plan(cfg, TPU_V5E, 16, **kw)}
        inter = plan(cfg, TPU_V5E, 16, interleave=7, **kw)
        saw = 0
        for p in inter:
            b = base[(p.dp, p.tp, p.pp, p.microbatches)]
            if p.pp == 1:
                assert p.vstages == 1
                assert (p.runtime, p.net_bytes) == (b.runtime, b.net_bytes)
                continue
            saw += 1
            assert p.vstages == min(7, 28 // p.pp)
            assert b.vstages == 1
            assert p.bubble_fraction < b.bubble_fraction
            assert p.net_bytes > b.net_bytes        # v× boundary p2p
            assert p.flops == b.flops               # compute untouched
        assert saw

    def test_interleave_bubble_algebra(self):
        """bubble = ramp / (m + ramp) with ramp = (pp − 1)/vstages."""
        cfg = _cfg("qwen2-7b")
        plans = plan(cfg, TPU_V5E, 16, batch=16, seq=128, max_pp=4,
                     interleave=4, check_capacity=False)
        for p in plans:
            if p.pp <= 1:
                continue
            ramp = (p.pp - 1.0) / p.vstages
            assert p.bubble_fraction == pytest.approx(
                ramp / (p.microbatches + ramp), rel=1e-12)

    def test_bad_interleave_rejected(self):
        with pytest.raises(ValueError, match="interleave"):
            pg.plan_grid(_cfg(), CLX, [8], [512], interleave=0)


# --- memory-capacity feasibility (the ISSUE 6 tentpole) -----------------------


class TestCapacityFeasibility:
    def test_default_flags_never_rank_a_candidate_over_capacity(self):
        """The headline acceptance criterion: with the capacity check on
        (the default), no ranked plan's working set exceeds the spec's
        HBM — at any searched ZeRO stage."""
        cfg = _cfg("qwen2-7b")
        grid = pg.plan_grid(cfg, TPU_V5E, [16], [8], seq=128,
                            zero_stages=(0, 1, 2, 3))
        plans = grid.plans()
        assert plans
        for p in plans:
            assert p.fits
            assert p.hbm_bytes <= TPU_V5E.hbm_capacity_bytes
        assert np.all(grid.hbm_bytes <= TPU_V5E.hbm_capacity_bytes)
        assert grid.n_enumerated > grid.n_candidates    # the cut did work
        assert 0.0 < grid.pruned_fraction < 1.0

    def test_capacity_unknown_spec_prunes_nothing(self):
        """A custom spec without a capacity (the 0.0 default) keeps the
        pre-ISSUE 6 behaviour: everything is ranked, trivially fits."""
        hw = HardwareSpec("box", 197e12, 819e9, 50e9)
        grid = pg.plan_grid(_cfg("qwen2-7b"), hw, [16], [8], seq=128)
        assert grid.pruned_fraction == 0.0
        assert all(p.fits for p in grid.plans())
        assert all(p.hbm_bytes > 0 for p in grid.plans())

    def test_whatif_view_keeps_and_marks_infeasible_rows(self):
        cfg = _cfg("qwen2-7b")
        grid = pg.plan_grid(cfg, TPU_V5E, [16], [8], seq=128,
                            check_capacity=False)
        assert grid.n_candidates == grid.n_enumerated
        assert not any(p.fits for p in grid.plans())

    def test_emptied_point_raises_with_zero_hint(self):
        with pytest.raises(ValueError, match="ZeRO-2"):
            pg.plan_grid(_cfg("qwen2-7b"), TPU_V5E, [16], [8], seq=128)

    def test_bad_zero_stage_rejected(self):
        with pytest.raises(ValueError, match="unknown ZeRO stage"):
            pg.plan_grid(_cfg(), CLX, [8], [512], zero_stages=(0, 5))
        with pytest.raises(ValueError, match="at least one ZeRO stage"):
            pg.plan_grid(_cfg(), CLX, [8], [512], zero_stages=())

    def test_zero_rows_price_rs_ag_and_shrink_footprint(self):
        """A zero ≥ 1 row reprices its dp sync as the structural RS+AG
        schedule and strictly shrinks the footprint of its zero-0 twin
        (dp > 1); dp = 1 zero rows are deduplicated away entirely."""
        cfg = _cfg("qwen2-7b")
        grid = pg.plan_grid(cfg, TPU_V5E, [16], [8], seq=128,
                            zero_stages=(0, 1, 2, 3),
                            check_capacity=False)
        plans = grid.plans()
        by_key = {(p.dp, p.tp, p.pp, p.zero_stage): p for p in plans}
        assert len(by_key) == len(plans)    # dp = 1 dupes really dropped
        saw_pair = False
        for (dp, tp, pp, z), p in by_key.items():
            if dp <= 1:
                assert z == 0
                continue
            if z >= 1:
                assert p.dp_algo == "rs+ag"
                base = by_key.get((dp, tp, pp, 0))
                if base is not None:
                    saw_pair = True
                    assert p.hbm_bytes < base.hbm_bytes
        assert saw_pair

    def test_remat_trades_footprint_for_flops(self):
        from repro.launch import memory as mem
        cfg = _cfg("qwen2-7b")
        base = {(p.dp, p.tp): p for p in plan(cfg, TPU_V5E, 4, batch=4,
                                              seq=64, check_capacity=False)}
        remat = plan(cfg, TPU_V5E, 4, batch=4, seq=64,
                     check_capacity=False, remat=True)
        for p in remat:
            b = base[(p.dp, p.tp)]
            assert p.flops == pytest.approx(
                mem.REMAT_FLOPS_FACTOR * b.flops, rel=1e-12)
            assert p.hbm_bytes < b.hbm_bytes
            assert p.remat and not b.remat

    @pytest.mark.slow
    def test_pinned_zero_flip_golden(self):
        """The ISSUE 6 acceptance golden: at qwen2-7b / 16 v5e chips /
        batch 8 the unconstrained winner (dp4xtp4, ZeRO-0) does not fit
        in 16 GB, and ZeRO-2 flips the ranking — same mesh, sharded
        states, feasible, and committed bit-for-bit."""
        g = _golden("plan_pr6_qwen2_7b_c16_zero.json")
        cfg = _cfg("qwen2-7b")
        plans = plan(cfg, TPU_V5E, 16, batch=g["batch"], seq=g["seq"],
                     zero_stages=tuple(g["zero_stages"]))
        _assert_bit_identical(plans, g)
        best = plans[0]
        assert best.zero_stage == 2 and best.fits
        # the what-if view shows what the old planner would have picked:
        # the same mesh at ZeRO-0, faster on paper, over capacity
        unconstrained = plan(cfg, TPU_V5E, 16, batch=g["batch"],
                             seq=g["seq"], check_capacity=False)[0]
        assert unconstrained.mesh == best.mesh
        assert not unconstrained.fits
        assert unconstrained.runtime < best.runtime


# --- failure-aware goodput (ISSUE 10) -----------------------------------------


class TestGoodput:
    def test_inf_mtbf_bit_identical_to_pr4_golden(self):
        """ISSUE 10 acceptance: ``goodput=True`` with the default
        (infinite-MTBF) failure model reproduces the committed PR 4
        golden bit-for-bit — every overhead term is exactly +0.0."""
        g = _golden("plan_pr4_dlrm_mlp_c16.json")
        plans = plan(_cfg("dlrm-mlp"), TPU_V5E, 16, batch=g["batch"],
                     goodput=True)
        _assert_bit_identical(plans, g)
        for p in plans:
            assert p.goodput == 1.0
            assert p.ckpt_overhead_s == 0.0
            assert p.rework_s == 0.0 and p.restart_s == 0.0

    def test_inf_mtbf_runtime_array_identical(self):
        cfg = _cfg("dlrm-mlp")
        g0 = pg.plan_grid(cfg, TPU_V5E, [16, 64], [4096], max_pp=2)
        g1 = pg.plan_grid(cfg, TPU_V5E, [16, 64], [4096], max_pp=2,
                          goodput=True)
        assert np.array_equal(g0.runtime, g1.runtime)
        assert np.array_equal(g0.runtime_lo, g1.runtime_lo)
        assert np.array_equal(g0.runtime_hi, g1.runtime_hi)
        assert np.all(g1.goodput == 1.0)

    def test_pinned_goodput_flip_golden(self):
        """The ISSUE 10 acceptance golden: dlrm-mlp at batch 4096, 1 h
        per-chip MTBF.  Healthy, 64 chips out-rank 16; once the failure
        bill is priced (64 chips fail 4x as often and pay a bigger
        restart bill) the 16-chip mesh wins — pinned bit-for-bit."""
        from repro.launch.plan import _plan_dict
        from repro.resilience import FailureModel
        g = _golden("plan_pr10_goodput_flip.json")
        fm = FailureModel(mtbf_chip_s=g["failure"]["mtbf_chip_s"],
                          restart_s=g["failure"]["restart_s"],
                          reshard_s=g["failure"]["reshard_s"])
        grid = pg.plan_grid(_cfg(g["arch"]), TPU_V5E, g["chips_grid"],
                            g["batch_grid"], max_pp=g["max_pp"],
                            goodput=True, failure=fm)
        for pt in g["points"]:
            got = _plan_dict(grid.best(pt["chips"], pt["batch"]))
            for key, want in pt["best"].items():
                assert got[key] == want, (pt["chips"], key, want, got[key])
        # the flip itself: priced, the small mesh beats the big one...
        priced = grid.best_runtime_grid().ravel()
        assert priced[0] < priced[1]
        # ...which inverts the healthy ranking
        healthy = pg.plan_grid(
            _cfg(g["arch"]), TPU_V5E, g["chips_grid"], g["batch_grid"],
            max_pp=g["max_pp"]).best_runtime_grid().ravel()
        assert healthy[1] < healthy[0]

    def test_goodput_monotone_in_mtbf(self):
        """Shorter per-chip MTBF can only lower goodput and raise the
        effective step time, elementwise across the whole grid."""
        from repro.resilience import FailureModel
        cfg = _cfg("dlrm-mlp")
        prev_good, prev_rt = None, None
        for hours in (100.0, 10.0, 1.0):
            g = pg.plan_grid(cfg, TPU_V5E, [16, 64], [512], max_pp=2,
                             goodput=True,
                             failure=FailureModel.from_mtbf_hours(hours))
            if prev_good is not None:
                assert np.all(g.goodput <= prev_good)
                assert np.all(g.runtime >= prev_rt)
            prev_good, prev_rt = g.goodput, g.runtime

    def test_goodput_needs_ckpt_bw(self):
        """A spec that does not know its checkpoint bandwidth refuses to
        price goodput rather than dividing by zero."""
        from repro.resilience import FailureModel
        bare = HardwareSpec("bare", peak_flops=197e12, hbm_bw=819e9,
                            net_bw=50e9)
        assert bare.ckpt_bw == 0.0
        with pytest.raises(ValueError, match="ckpt_bw"):
            pg.plan_grid(_cfg("dlrm-mlp"), bare, [16], [512],
                         goodput=True,
                         failure=FailureModel.from_mtbf_hours(1.0))

    def test_goodput_cli_json(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "16",
                     "--mtbf-hours", "100", "--json"]) == 0
        d = json.loads(capsys.readouterr().out)
        assert d["failure"]["mtbf_chip_s"] == 100.0 * 3600.0
        best = d["best"]
        assert 0.0 < best["goodput"] < 1.0
        assert best["runtime"] == pytest.approx(
            best["t_network"] + best["ckpt_overhead_s"]
            + best["rework_s"] + best["restart_s"], rel=1e-12)


# --- plan_grid API ------------------------------------------------------------


class TestPlanGridApi:
    def test_grid_equals_per_point_plan_calls(self):
        cfg = _cfg()
        chips_l, batch_l = [8, 16, 32], [256, 512]
        grid = pg.plan_grid(cfg, CLX, chips_l, batch_l, max_pp=4)
        bests = grid.best_runtime_grid()
        assert bests.shape == (3, 2)
        for i, c in enumerate(chips_l):
            for j, b in enumerate(batch_l):
                pts = plan(cfg, CLX, c, batch=b, max_pp=4)
                assert bests[i, j] == pts[0].runtime
                assert grid.best(c, b).mesh == pts[0].mesh
                got = grid.plans(c, b)
                assert [p.mesh for p in got] == [p.mesh for p in pts]
                assert [p.runtime for p in got] == \
                    [p.runtime for p in pts]

    def test_accepts_spec_names(self):
        grid = pg.plan_grid(_cfg(), "clx", [8], [512])
        assert grid.hardware == "clx"
        assert grid.n_candidates == len(grid.runtime)

    def test_infeasible_point_raises_with_the_point_named(self):
        with pytest.raises(ValueError, match="chips=12"):
            pg.plan_grid(_cfg(), CLX, [8, 12], [8])

    def test_empty_grid_raises(self):
        with pytest.raises(ValueError, match="non-empty"):
            pg.plan_grid(_cfg(), CLX, [], [512])

    def test_divisors_and_factor_pairs(self):
        for n in (1, 2, 12, 36, 97, 1024):
            want = [d for d in range(1, n + 1) if n % d == 0]
            assert list(pg._divisors(n)) == want
            assert pg._factor_pairs(n) == [(n // t, t) for t in want]

    def test_param_counts_memoized(self):
        pg.param_counts.cache_clear()
        cfg = _cfg()
        a = pg.param_counts(cfg)
        b = pg.param_counts(_cfg())        # equal config -> cache hit
        assert a == b
        info = pg.param_counts.cache_info()
        assert info.hits >= 1 and info.misses == 1


# --- CLI: --pp and grid modes -------------------------------------------------


class TestGridCli:
    def test_pp_flag_ranks_pipelined_meshes(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "16", "--pp",
                     "4"]) == 0
        out = capsys.readouterr().out
        assert "xpp" in out and " pp " in out and " mb " in out

    def test_grid_mode_table(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips-grid", "8,16",
                     "--batch-grid", "256,512", "--hardware", "clx",
                     "--pp", "4"]) == 0
        out = capsys.readouterr().out
        assert "grid on clx" in out and "one pass" in out
        assert out.count("\n") >= 6        # header + 4 grid points

    def test_grid_mode_honors_top_and_prints_flips(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips-grid", "8,16",
                     "--batch-grid", "512", "--top", "3"]) == 0
        out = capsys.readouterr().out
        assert " rank " in out               # ranked rows per grid point
        assert "flip points" in out          # same report as point mode
        # 2 grid points x 3 ranks of table rows
        assert sum(l.lstrip().startswith(("8 ", "16 "))
                   for l in out.splitlines()) == 6

    def test_grid_json_top_adds_ranked_plans(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips-grid", "8",
                     "--batch-grid", "512", "--top", "2", "--json"]) == 0
        d = json.loads(capsys.readouterr().out)
        assert "flip_points" in d
        assert len(d["points"][0]["plans"]) == 2
        assert d["points"][0]["plans"][0] == d["points"][0]["best"]

    def test_grid_mode_json(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips-grid", "8,16",
                     "--batch-grid", "512", "--json"]) == 0
        d = json.loads(capsys.readouterr().out)
        assert d["mode"] == "grid"
        assert d["chips_grid"] == [8, 16] and d["batch_grid"] == [512]
        assert len(d["points"]) == 2
        for pt in d["points"]:
            assert pt["best"]["runtime"] > 0
            assert {"pp", "microbatches", "pp_link"} <= set(pt["best"])

    def test_single_point_json_carries_max_pp_and_pp_fields(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "8", "--pp", "2",
                     "--json"]) == 0
        d = json.loads(capsys.readouterr().out)
        assert d["max_pp"] == 2
        assert any(p["pp"] > 1 for p in d["plans"])

    def test_bad_grid_spec_errors(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips-grid", "8,x"]) == 2
        assert "comma list" in capsys.readouterr().err


# --- BENCH regression: grid throughput pins -----------------------------------


class TestBenchGridRegression:
    """Pins the committed BENCH_ridgeline.json grid-planner numbers.

    The committed artifact is regenerated by `make ci`; these bounds are
    the ISSUE 5 acceptance criteria — ≥ 10⁵ candidates/s through the grid
    path and ≥ 10× over per-point ``plan()`` looping on the same grid.
    """

    @pytest.fixture()
    def bench(self):
        path = os.path.join(_REPO_ROOT, "BENCH_ridgeline.json")
        if not os.path.exists(path):
            pytest.skip("no BENCH_ridgeline.json baseline")
        return json.loads(open(path).read())

    @pytest.fixture()
    def grid_stats(self, bench):
        stats = bench.get("planner_grid")
        if not stats:
            pytest.skip("baseline predates the grid planner")
        return stats

    def test_candidates_per_s_at_least_1e5(self, grid_stats):
        assert grid_stats["candidates_per_s"] >= 1e5, grid_stats

    def test_grid_at_least_10x_faster_than_plan_loop(self, grid_stats):
        assert grid_stats["speedup_vs_plan_loop"] >= 10.0, grid_stats

    @pytest.fixture()
    def feasibility_stats(self, bench):
        stats = bench.get("planner_feasibility")
        if not stats:
            pytest.skip("baseline predates the capacity cut")
        return stats

    def test_masked_grid_still_clears_1e5_candidates_per_s(
            self, feasibility_stats):
        """The feasibility mask runs before pricing and must not cost the
        grid its raw-speed win — the ISSUE 6 CI pin."""
        assert feasibility_stats["candidates_per_s"] >= 1e5, \
            feasibility_stats

    def test_capacity_cut_actually_prunes(self, feasibility_stats):
        assert 0.0 < feasibility_stats["prune_fraction"] < 1.0, \
            feasibility_stats

    @pytest.fixture()
    def goodput_stats(self, bench):
        stats = bench.get("planner_goodput")
        if not stats:
            pytest.skip("baseline predates goodput planning")
        return stats

    def test_goodput_grid_still_clears_1e5_candidates_per_s(
            self, goodput_stats):
        """The Young/Daly overlay is a handful of broadcast kernels on
        already-sized arrays and must not cost the grid its raw-speed
        win — the ISSUE 10 CI pin."""
        assert goodput_stats["candidates_per_s"] >= 1e5, goodput_stats

    def test_goodput_actually_prices_failures(self, goodput_stats):
        assert 0.0 < goodput_stats["min_goodput"] < 1.0, goodput_stats
