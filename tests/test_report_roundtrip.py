"""CellReport JSON round-trip + persistence guarantees (core/report)."""
import dataclasses
import json

import pytest

from repro.core.hardware import get_hardware
from repro.core.report import CellReport, load_reports, roofline_table


def _report(**overrides) -> CellReport:
    kw = dict(
        arch="dlrm-mlp", shape="train_4k", mesh="16x16",
        step_kind="train_step", num_devices=256, hardware="clx",
        flops=1.2e12, mem_bytes=3.4e9, wire_bytes=5.6e8,
        wire_bytes_by_kind={"all-reduce": 5.6e8},
        peak_memory_per_device=2.0 * 2**30,
        model_flops=3.0e14, params_total=1.3e8, params_active=1.3e8,
        tokens_per_step=2.1e6, notes="round-trip fixture")
    kw.update(overrides)
    return CellReport(**kw).finalize(get_hardware("clx"))


def test_json_roundtrip_equal():
    rep = _report()
    back = CellReport.from_json(rep.to_json())
    assert back == rep


def test_roundtrip_preserves_measured_overlay():
    rep = _report()
    rep.measured_runtime = 0.123
    rep.measured_rel_error = -0.07
    rep.measured_source = "calibrate:clx_cal@cpu"
    back = CellReport.from_json(rep.to_json())
    assert back.measured_runtime == 0.123
    assert back.measured_rel_error == -0.07
    assert back.measured_source == "calibrate:clx_cal@cpu"
    assert back == rep


def test_from_json_ignores_unknown_fields():
    d = json.loads(_report().to_json())
    d["field_from_the_future"] = 1
    rep = CellReport.from_json(json.dumps(d))
    assert rep.arch == "dlrm-mlp"


def test_measured_fields_default_empty():
    rep = _report()
    assert rep.measured_runtime == 0.0
    assert rep.measured_rel_error == 0.0
    assert rep.measured_source == ""
    # and they serialize (schema carries them even before a clock ran)
    d = json.loads(rep.to_json())
    assert d["measured_runtime"] == 0.0
    assert d["measured_source"] == ""


def test_save_load_directory_roundtrip(tmp_path):
    reports = [_report(), _report(shape="decode_32k", variant="tree"),
               _report(mesh="2x16x16")]
    for r in reports:
        r.save(str(tmp_path))
    loaded = load_reports(str(tmp_path))
    assert len(loaded) == 3
    assert sorted(r.shape for r in loaded) == \
        sorted(r.shape for r in reports)
    by_key = {(r.shape, r.mesh, r.variant): r for r in loaded}
    for r in reports:
        assert by_key[(r.shape, r.mesh, r.variant)] == r


def test_load_reports_missing_dir_is_empty(tmp_path):
    assert load_reports(str(tmp_path / "nope")) == []


def test_finalize_derives_consistent_times():
    rep = _report()
    hw = get_hardware("clx")
    assert rep.t_compute == pytest.approx(rep.flops / hw.peak_flops)
    assert rep.t_memory == pytest.approx(rep.mem_bytes / hw.hbm_bw)
    assert rep.t_network == pytest.approx(rep.wire_bytes / hw.net_bw)
    assert rep.runtime == pytest.approx(
        max(rep.t_compute, rep.t_memory, rep.t_network))
    assert rep.bottleneck in ("compute", "memory", "network")
    # and the markdown emitter accepts the round-tripped object
    assert rep.arch in roofline_table([CellReport.from_json(rep.to_json())])


def test_all_fields_json_serializable():
    d = dataclasses.asdict(_report())
    json.dumps(d)          # no exotic types anywhere in the schema
