"""Resilience subsystem: fault plans, analytic failure kernels, and the
acceptance replay — a seeded 200-step fault-injection run through the real
ResilientRunner whose measured goodput must match the analytic model."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, make_stream
from repro.optim.optimizer import AdamW
from repro.resilience import failures
from repro.resilience.failures import FailureModel
from repro.resilience.faults import (CORRUPT_CKPT, LINK_FLAP, PREEMPTION,
                                     STRAGGLER, FaultEvent, FaultPlan)
from repro.resilience.harness import (ReplayResult, VirtualCosts,
                                      predicted_goodput, replay)
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state

KEY = jax.random.PRNGKey(0)


# --- fault plans -------------------------------------------------------------
class TestFaultPlan:
    def test_same_seed_same_plan(self):
        a = FaultPlan.generate(17, 300)
        b = FaultPlan.generate(17, 300)
        assert a == b

    def test_different_seed_different_plan(self):
        assert FaultPlan.generate(1, 300) != FaultPlan.generate(2, 300)

    def test_no_step_collisions_and_sorted(self):
        p = FaultPlan.generate(3, 100, n_preemptions=10, n_stragglers=10)
        steps = [e.step for e in p.events]
        assert len(set(steps)) == len(steps)
        assert steps == sorted(steps)
        assert all(1 <= s < 100 for s in steps)

    def test_counts(self):
        p = FaultPlan.generate(0, 200, n_preemptions=3, n_link_flaps=1,
                               n_stragglers=2, n_corrupt_ckpts=1)
        assert p.count(PREEMPTION) == 3
        assert p.count(LINK_FLAP) == 1
        assert p.count(STRAGGLER) == 2
        assert p.count(CORRUPT_CKPT) == 1
        assert p.n_restart_faults == 4
        assert len(p.by_step()) == 7

    def test_straggler_slowdown_applied(self):
        p = FaultPlan.generate(0, 200, straggler_slowdown=5.0)
        slows = [e.slowdown for e in p.events if e.kind == STRAGGLER]
        assert slows and all(s == 5.0 for s in slows)
        assert all(e.slowdown == 1.0 for e in p.events
                   if e.kind != STRAGGLER)

    def test_too_many_events_raises(self):
        with pytest.raises(ValueError, match="do not fit"):
            FaultPlan.generate(0, 5, n_preemptions=10)

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(step=1, kind="meteor")


# --- analytic kernels --------------------------------------------------------
class TestFailureKernels:
    def test_mesh_mtbf_scales_with_chips(self):
        m = FailureModel.from_mtbf_hours(1000.0)
        one = failures.mesh_mtbf_s(np.array([1.0]), m.mtbf_chip_s)
        k = failures.mesh_mtbf_s(np.array([64.0]), m.mtbf_chip_s)
        assert one[0] == pytest.approx(1000.0 * 3600.0)
        assert k[0] == pytest.approx(one[0] / 64.0)

    def test_young_daly_interval(self):
        # tau* = sqrt(2 * t_ckpt * mtbf)
        tau = failures.young_daly_interval_s(np.array([8.0]),
                                             np.array([3600.0]))
        assert tau[0] == pytest.approx(math.sqrt(2 * 8.0 * 3600.0))

    def test_infinite_mtbf_zero_overhead(self):
        ck, rw, rs = failures.failure_overhead_terms(
            np.array([1.0]), np.array([5.0]), np.array([100.0]),
            np.array([np.inf]), 60.0)
        assert ck[0] == 0.0 and rw[0] == 0.0 and rs[0] == 0.0

    def test_overhead_terms_positive_for_finite_mtbf(self):
        ck, rw, rs = failures.failure_overhead_terms(
            np.array([1.0]), np.array([5.0]), np.array([100.0]),
            np.array([3600.0]), 60.0)
        assert ck[0] > 0 and rw[0] > 0 and rs[0] > 0
        g = failures.goodput_fraction(np.array([1.0]), ck, rw, rs)
        assert 0.0 < g[0] < 1.0


# --- the acceptance replay ---------------------------------------------------
# Seed 6 gives 3 preemptions + 1 link flap + 2 stragglers + 1 corrupt
# checkpoint, with the corruption (step 101) inside the same checkpoint
# interval as a later preemption (step 105) — so the restart restores
# through the corrupted step_100 and must quarantine it and fall back.
SEED = 6
N_STEPS = 200
CKPT_EVERY = 10


@pytest.fixture(scope="module")
def replay_result(tmp_path_factory):
    cfg = get_reduced("dlrm-mlp").replace(compute_dtype=jnp.float32)
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(build_train_step(cfg, opt, TrainStepConfig()))
    stream = make_stream(cfg, DataConfig(seed=11, global_batch=8))
    state = init_train_state(KEY, cfg, opt)
    plan = FaultPlan.generate(SEED, N_STEPS)
    d = str(tmp_path_factory.mktemp("replay_ckpt"))
    res = replay(step, state, stream, plan, d, ckpt_every=CKPT_EVERY,
                 straggler_sleep_s=0.02, keep_history=True)
    return plan, res, d


class TestReplay:
    def test_plan_meets_acceptance_shape(self, replay_result):
        plan, _, _ = replay_result
        assert plan.n_steps >= 200
        assert plan.count(PREEMPTION) >= 3
        assert plan.count(CORRUPT_CKPT) == 1

    def test_completes_all_steps(self, replay_result):
        _, res, _ = replay_result
        assert int(res.final_state.step) == N_STEPS

    def test_no_committed_progress_lost(self, replay_result):
        """Every step 0..N-1 ran at least once, none was skipped, and the
        recorded history ends exactly at the last step — replays may repeat
        work but never lose it."""
        _, res, _ = replay_result
        steps_run = [h["step"] for h in res.history]
        assert set(steps_run) == set(range(N_STEPS))
        assert steps_run[-1] == N_STEPS - 1

    def test_all_restart_faults_survived(self, replay_result):
        plan, res, _ = replay_result
        assert res.restarts == plan.n_restart_faults == 4
        assert res.replayed_steps > 0       # restarts really cost rework

    def test_corrupt_checkpoint_quarantined(self, replay_result):
        _, res, root = replay_result
        assert res.quarantined == 1
        assert any(".quarantined_" in n for n in os.listdir(root))

    def test_stragglers_flagged_not_restarted(self, replay_result):
        plan, res, _ = replay_result
        assert res.stragglers_flagged >= 1
        # stragglers never enter the restart path
        assert res.restarts == plan.n_restart_faults

    def test_measured_goodput_matches_analytic(self, replay_result):
        """The pinned acceptance tolerance: the replay's virtual-time
        goodput agrees with the failures-kernel prediction evaluated at
        the job's cadence and empirical fault rate.  The gap is real
        rework the analytic model does not price (the quarantine
        fallback replays one extra interval), so it stays one-sided:
        measured <= analytic."""
        plan, res, _ = replay_result
        measured = res.goodput_measured
        analytic = res.goodput_analytic(CKPT_EVERY, plan.n_restart_faults)
        assert analytic == pytest.approx(
            predicted_goodput(plan, ckpt_every=CKPT_EVERY))
        assert 0.0 < measured <= analytic
        assert abs(measured - analytic) < 0.05, (measured, analytic)

    def test_replay_accounting_is_deterministic(self, replay_result):
        """Virtual-time accounting depends only on (plan, cadence), never
        on wall-clock — pin the exact counters the seed produces."""
        _, res, _ = replay_result
        assert res.executed_steps == 233
        assert res.saves == 22
        assert res.goodput_measured == pytest.approx(0.7181328, abs=1e-6)

    def test_virtual_costs_price_the_wall(self, replay_result):
        _, res, _ = replay_result
        c = res.costs
        want = (res.executed_steps * c.t_step_s + res.saves * c.t_ckpt_s
                + res.restarts * c.downtime_s)
        assert res.wall_s == pytest.approx(want)
        assert res.goodput_measured == pytest.approx(
            res.useful_s / want)


# --- degraded restart --------------------------------------------------------
class TestDegradedRestart:
    def test_replan_on_survivors_failure_aware(self):
        from repro.resilience.degraded import replan_on_survivors
        cfg = get_reduced("dlrm-mlp")
        plan = replan_on_survivors(
            cfg, "tpu_v5e", 16, 4096, max_pp=2,
            failure=FailureModel.from_mtbf_hours(100.0))
        assert plan.chips == 16
        assert 0.0 < plan.goodput < 1.0      # failures actually priced
        healthy = replan_on_survivors(cfg, "tpu_v5e", 16, 4096, max_pp=2)
        assert healthy.goodput == 1.0

    def test_no_survivors_raises(self):
        from repro.resilience.degraded import replan_on_survivors
        with pytest.raises(ValueError, match="no survivors"):
            replan_on_survivors(get_reduced("dlrm-mlp"), "tpu_v5e", 0, 64)

    def test_restart_restores_onto_surviving_mesh(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.resilience.degraded import degraded_restart
        from repro.train.loop import model_param_specs
        cfg = get_reduced("dlrm-mlp").replace(compute_dtype=jnp.float32)
        opt = AdamW(learning_rate=1e-3)
        state = init_train_state(KEY, cfg, opt)
        ck = Checkpointer(str(tmp_path))
        ck.save(40, state.params)

        out = degraded_restart(
            ck, state.params, model_param_specs(cfg), cfg, "tpu_v5e",
            surviving_chips=1, global_batch=64,
            failure=FailureModel.from_mtbf_hours(50.0),
            data_cfg=DataConfig(global_batch=64), surviving_hosts=1)
        assert out.step == 40
        assert out.plan.chips == 1
        assert out.mesh.devices.size == 1
        assert [c.host_id for c in out.data_configs] == [0]
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)),
            state.params, out.state)

    def test_restart_skips_corrupt_latest(self, tmp_path):
        """A degraded restart never resumes from bytes that fail their
        checksum: the corrupt latest step quarantines, restore falls back."""
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.resilience.degraded import degraded_restart
        from repro.resilience.harness import _corrupt_latest
        from repro.train.loop import model_param_specs
        cfg = get_reduced("dlrm-mlp").replace(compute_dtype=jnp.float32)
        opt = AdamW(learning_rate=1e-3)
        state = init_train_state(KEY, cfg, opt)
        ck = Checkpointer(str(tmp_path))
        ck.save(10, state.params)
        ck.save(20, state.params)
        assert _corrupt_latest(ck)

        out = degraded_restart(
            ck, state.params, model_param_specs(cfg), cfg, "tpu_v5e",
            surviving_chips=1, global_batch=64)
        assert out.step == 10
