"""Ridgeline model unit + property tests (the paper's §II math)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CLX, TPU_V5E, HardwareSpec, Resource, WorkUnit,
                        analyze, analyze_multilink, ascii_plot,
                        classify_by_quadrant, classify_by_times, region_at,
                        svg_plot)

HW = st.sampled_from([CLX, TPU_V5E,
                      HardwareSpec("toy", 1e12, 1e11, 1e10)])
POS = st.floats(min_value=1e-3, max_value=1e18, allow_nan=False,
                allow_infinity=False)
NONNEG = st.one_of(st.just(0.0), POS)


class TestBalancePoints:
    def test_clx_matches_paper(self):
        # §III: x* = 105/12, y* = 4200/105 = 40, k* = 4200/12 = 350
        assert CLX.ridge_memory == pytest.approx(105 / 12)
        assert CLX.ridge_arithmetic == pytest.approx(40.0)
        assert CLX.ridge_network == pytest.approx(350.0)

    def test_ridge_identity(self):
        for hw in (CLX, TPU_V5E):
            assert hw.ridge_network == pytest.approx(
                hw.ridge_memory * hw.ridge_arithmetic)


class TestIntensities:
    def test_table1_definitions(self):
        w = WorkUnit("w", flops=100.0, mem_bytes=20.0, net_bytes=5.0)
        assert w.arithmetic_intensity == pytest.approx(5.0)     # F/B_M
        assert w.memory_intensity == pytest.approx(4.0)         # B_M/B_N
        assert w.network_intensity == pytest.approx(20.0)       # F/B_N = x*y

    def test_xy_identity(self):
        w = WorkUnit("w", 123.0, 7.0, 3.0)
        assert w.network_intensity == pytest.approx(
            w.arithmetic_intensity * w.memory_intensity)

    def test_negative_rejected(self):
        with pytest.raises(ValueError):
            WorkUnit("w", -1.0, 1.0, 1.0)


class TestClassificationEquivalence:
    """The paper's quadrant construction == argmax of resource times.

    This is the central correctness claim of the 2D projection; we check it
    as a hypothesis property over 6 orders of magnitude, including zero
    traffic edge cases.
    """

    @given(f=NONNEG, bm=NONNEG, bn=NONNEG, hw=HW)
    @settings(max_examples=500, deadline=None)
    def test_quadrant_equals_argmax(self, f, bm, bn, hw):
        w = WorkUnit("w", f, bm, bn)
        assert classify_by_quadrant(w, hw) == classify_by_times(w, hw)

    @given(f=POS, bm=POS, bn=POS, hw=HW)
    @settings(max_examples=300, deadline=None)
    def test_runtime_is_max_of_times(self, f, bm, bn, hw):
        a = analyze(WorkUnit("w", f, bm, bn), hw)
        assert a.runtime == pytest.approx(
            max(a.t_compute, a.t_memory, a.t_network))
        # bound runtime >= every individual term
        assert a.runtime >= a.t_compute - 1e-18
        assert a.peak_fraction <= 1.0 + 1e-9

    @given(f=POS, bm=POS, bn=POS, hw=HW, scale=st.floats(1e-3, 1e3))
    @settings(max_examples=200, deadline=None)
    def test_scale_invariance(self, f, bm, bn, hw, scale):
        """Intensities (and hence the region) are invariant to unit scaling."""
        w1 = WorkUnit("a", f, bm, bn)
        w2 = WorkUnit("b", f * scale, bm * scale, bn * scale)
        assert classify_by_quadrant(w1, hw) == classify_by_quadrant(w2, hw)


class TestPaperCaseStudy:
    """Quantitative claims from §III reproduced analytically."""

    @staticmethod
    def mlp_unit(batch, width=4096, layers=1, dtype_bytes=4):
        from repro.models.mlp_dlrm import analytic_work_unit
        f, bm, bn = analytic_work_unit(batch, width, layers, dtype_bytes)
        return WorkUnit(f"mlp_b{batch}", f, bm, bn)

    def test_batch_512_near_ridge(self):
        # paper: "MLP with batch size 512 is indeed on the ridgeline"
        w = self.mlp_unit(512)
        # on the compute-network ridge x*y ~ k* = 350
        assert w.network_intensity == pytest.approx(384, rel=0.15)

    def test_1024_compute_bound_256_network_bound(self):
        assert classify_by_quadrant(self.mlp_unit(1024), CLX) == Resource.COMPUTE
        assert classify_by_quadrant(self.mlp_unit(256), CLX) == Resource.NETWORK

    def test_arithmetic_intensity_crosses_ridge_at_32(self):
        # paper Fig 4a/4b: batch >= 32 can reach peak flops (I_A >= 40)
        assert self.mlp_unit(32).arithmetic_intensity >= CLX.ridge_arithmetic
        assert self.mlp_unit(16).arithmetic_intensity < CLX.ridge_arithmetic

    def test_allreduce_dominates_until_512(self):
        # paper Fig 4c: all-reduce takes longer than compute up to batch 512
        for b in (32, 128, 256):
            a = analyze(self.mlp_unit(b), CLX)
            assert a.t_network > a.t_compute, b
        a = analyze(self.mlp_unit(1024), CLX)
        assert a.t_compute > a.t_network


class TestMultilink:
    def test_slowest_link_dominates(self):
        w_ici = WorkUnit("w", 1e12, 1e9, 1e9)
        w_dci = WorkUnit("w", 1e12, 1e9, 6e8)   # fewer bytes, slower link
        a = analyze_multilink({"ici": w_ici, "pod": w_dci}, TPU_V5E)
        # pod link: 6e8/25e9 = 24ms > ici 1e9/50e9 = 20ms
        assert a.t_network == pytest.approx(6e8 / 25e9)


class TestPlots:
    def test_ascii_plot_renders_regions_and_points(self):
        a = analyze(WorkUnit("pt", 1e12, 1e10, 1e8), CLX)
        s = ascii_plot([a], CLX)
        assert "pt" in s and "=" in s and "|" in s
        for glyph in (".", "-", "+"):
            assert glyph in s

    def test_svg_plot_is_valid_svg(self):
        a = analyze(WorkUnit("pt", 1e12, 1e10, 1e8), TPU_V5E)
        s = svg_plot([a], TPU_V5E)
        assert s.startswith("<svg") and s.endswith("</svg>")

    def test_region_at_corners(self):
        hw = CLX
        eps = 1e3
        assert region_at(hw.ridge_memory * eps, hw.ridge_arithmetic * eps,
                         hw) == Resource.COMPUTE
        assert region_at(hw.ridge_memory * eps, hw.ridge_arithmetic / eps,
                         hw) == Resource.MEMORY
        assert region_at(hw.ridge_memory / eps, hw.ridge_arithmetic / eps,
                         hw) == Resource.NETWORK
