"""Serving: decode-vs-forward equivalence per family + generation smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod
from repro.serve.engine import build_serve_step, greedy_generate, init_cache
from repro.train.loop import init_train_state
from repro.optim.optimizer import AdamW

KEY = jax.random.PRNGKey(7)

# archs whose decode must match teacher-forced forward exactly (capacity
# drops make MoE equality only approximate — tested separately)
EXACT = ["qwen2.5-3b", "smollm-135m", "minitron-8b", "qwen2-7b",
         "xlstm-125m", "hymba-1.5b"]


def _params(cfg):
    return init_train_state(KEY, cfg, AdamW()).params


@pytest.mark.slow
@pytest.mark.parametrize("arch", EXACT)
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch).replace(compute_dtype=jnp.float32)
    params = _params(cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    full, _ = lm_mod.forward(params, toks, cfg)
    serve = build_serve_step(cfg)
    cache = init_cache(params, cfg, 2, 12)
    outs = []
    for t in range(12):
        lg, cache = serve(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.slow
def test_whisper_decode_matches_forward():
    cfg = get_reduced("whisper-tiny").replace(compute_dtype=jnp.float32)
    params = _params(cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    frames = jax.random.normal(KEY, (2, cfg.encoder_seq, cfg.d_model))
    full, _ = encdec_mod.forward(params, toks, frames, cfg)
    serve = build_serve_step(cfg)
    cache = init_cache(params, cfg, 2, 8, frames=frames)
    outs = []
    for t in range(8):
        lg, cache = serve(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.slow
def test_moe_decode_matches_forward_without_drops():
    cfg = get_reduced("qwen2-moe-a2.7b").replace(
        compute_dtype=jnp.float32, capacity_factor=16.0)
    params = _params(cfg)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    full, _ = lm_mod.forward(params, toks, cfg)
    serve = build_serve_step(cfg)
    cache = init_cache(params, cfg, 2, 6)
    outs = []
    for t in range(6):
        lg, cache = serve(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.slow
def test_greedy_generate_is_deterministic_and_extends():
    cfg = get_reduced("smollm-135m").replace(compute_dtype=jnp.float32)
    params = _params(cfg)
    prompt = jax.random.randint(KEY, (2, 5), 0, cfg.vocab_size)
    out1 = greedy_generate(params, cfg, prompt, steps=4, max_len=16)
    out2 = greedy_generate(params, cfg, prompt, steps=4, max_len=16)
    assert out1.shape == (2, 9)
    np.testing.assert_array_equal(out1, out2)
    np.testing.assert_array_equal(out1[:, :5], prompt)


def test_sliding_window_cache_is_bounded():
    """Hymba local layers must hold only O(window) KV regardless of max_len."""
    cfg = get_reduced("hymba-1.5b").replace(compute_dtype=jnp.float32)
    cache = init_cache(None, cfg, 1, 4096)
    for i in range(cfg.n_layers):
        row = cache[f"layer{i}"]
        if i in cfg.global_attn_layers:
            assert row["k"].shape[1] == 4096
        else:
            assert row["k"].shape[1] == cfg.sliding_window
