"""Logical-axis sharding rules: dedupe, divisibility fallback, GQA rules."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, _drop_nondividing,
                                        gqa_safe_rules, logical_spec,
                                        shard_hint, use_sharding)
from repro.launch.mesh import make_host_mesh, make_mesh


def test_logical_spec_basic():
    rules = dict(DEFAULT_RULES)
    spec = logical_spec(("batch", "seq", "embed"), rules)
    assert spec == P(("pod", "data"), None, None)


def test_logical_spec_dedupes_mesh_axis():
    rules = dict(DEFAULT_RULES, seq="model")
    spec = logical_spec(("batch", "seq", "vocab"), rules)
    assert spec == P(("pod", "data"), "model", None)   # vocab dropped


def test_drop_nondividing():
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((2, 2), ("data", "model"))
    spec = _drop_nondividing(P("data", "model"), (10, 7), mesh)
    assert spec == P("data", None)    # 7 % 2 != 0


def test_gqa_safe_rules():
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((1, 4), ("data", "model"))
    rules = gqa_safe_rules(2, mesh)       # 2 kv heads % 4 != 0
    assert rules["kv_proj"] is None
    rules = gqa_safe_rules(4, mesh)
    assert rules["kv_proj"] == "model"


def test_shard_hint_identity_without_binding():
    x = jnp.ones((4, 4))
    assert shard_hint(x, ("batch", "embed")) is x


def test_shard_hint_inside_binding_single_device():
    mesh = make_host_mesh()
    with use_sharding(mesh):
        y = jax.jit(lambda x: shard_hint(x * 2, ("batch", "embed")))(
            jnp.ones((4, 4)))
    assert float(y[0, 0]) == 2.0


def test_use_sharding_filters_missing_axes():
    mesh = make_mesh((1, 1), ("data", "model"))   # no "pod" axis
    with use_sharding(mesh) as rules:
        assert rules["batch"] == ("data",)


def test_train_state_specs_zero1_adds_dp_shard():
    from repro.configs import get_reduced
    from repro.launch.specs import train_state_specs
    cfg = get_reduced("smollm-135m")
    specs = train_state_specs(cfg, zero1=True, fsdp=False)
    # params untouched, moments augmented
    flat_p = jax.tree_util.tree_leaves(
        specs.params, is_leaf=lambda x: isinstance(x, tuple))
    flat_m = jax.tree_util.tree_leaves(
        specs.opt_state.mu, is_leaf=lambda x: isinstance(x, tuple))
    assert not any("dp_shard" in t for t in flat_p if isinstance(t, tuple))
    assert any("dp_shard" in t for t in flat_m if isinstance(t, tuple))
