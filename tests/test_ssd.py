"""Chunked linear recurrence vs exact sequential oracle (property test)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.ssd import (chunked_linear_recurrence, decode_linear_step,
                              init_linear_state)


def _run_both(B, T, H, dk, dv, chunk, normalize, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, T, H, dk))
    k = jax.random.normal(ks[1], (B, T, H, dk))
    v = jax.random.normal(ks[2], (B, T, H, dv))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    y_chunk, (Mf, nf) = chunked_linear_recurrence(
        q, k, v, la, chunk=chunk, normalize=normalize)
    st_ = init_linear_state(B, H, dk, dv)
    ys = []
    for t in range(T):
        yt, st_ = decode_linear_step(st_, q[:, t], k[:, t], v[:, t],
                                     jnp.exp(la[:, t]), normalize=normalize)
        ys.append(yt)
    return y_chunk, Mf, jnp.stack(ys, 1), st_[0]


@pytest.mark.slow
@given(chunk=st.sampled_from([4, 8, 16, 32]), normalize=st.booleans(),
       h=st.integers(1, 3), seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_chunked_equals_sequential(chunk, normalize, h, seed):
    y_c, M_c, y_s, M_s = _run_both(2, 32, h, 6, 5, chunk, normalize, seed)
    np.testing.assert_allclose(y_c, y_s, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(M_c, M_s, atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_chunk_size_equal_to_T():
    y_c, M_c, y_s, M_s = _run_both(1, 16, 2, 4, 4, 16, True)
    np.testing.assert_allclose(y_c, y_s, atol=1e-4)


@pytest.mark.slow
def test_decay_bounds_state():
    """With decay -> 0, the state forgets: y_t depends only on step t."""
    B, T, H, dk, dv = 1, 8, 1, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, dk))
    k = jax.random.normal(ks[1], (B, T, H, dk))
    v = jax.random.normal(ks[2], (B, T, H, dv))
    la = jnp.full((B, T, H), -50.0)   # decay ~ 0
    y, _ = chunked_linear_recurrence(q, k, v, la, chunk=4)
    # each output should equal q_t . (k_t v_t^T) alone
    want = jnp.einsum("bthd,bthd,bthe->bthe",
                      q, k, jnp.ones_like(v)) * 0  # placeholder shape
    want = jnp.einsum("bthd,bthd->bth", q, k)[..., None] * v
    np.testing.assert_allclose(y, want, atol=1e-4)


@pytest.mark.slow
def test_indivisible_chunk_falls_back_to_divisor():
    # T=10, chunk=4 -> largest divisor <= 4 is 2; result must stay exact
    y_c, M_c, y_s, M_s = _run_both(1, 10, 1, 2, 2, 4, False)
    np.testing.assert_allclose(y_c, y_s, atol=1e-4)
