"""Sweep engine vs the scalar Ridgeline, and the parallelism planner."""
import math
import random

import numpy as np
import pytest

from repro.core import CLX, TPU_V5E, WorkUnit, analyze
from repro.core import sweep as sweep_mod
from repro.core.ridgeline import Resource


def _random_terms(n, seed=0):
    """(F, B_M, B_N) spanning 8 orders of magnitude, with zero edge cases."""
    rng = random.Random(seed)

    def draw():
        if rng.random() < 0.1:
            return 0.0
        return 10.0 ** rng.uniform(-2, 16)

    return (np.array([draw() for _ in range(n)]),
            np.array([draw() for _ in range(n)]),
            np.array([draw() for _ in range(n)]))


class TestAgainstScalarModel:
    """The vectorized classifier must agree elementwise with analyze()."""

    @pytest.mark.parametrize("hw", [CLX, TPU_V5E], ids=lambda h: h.name)
    @pytest.mark.parametrize("seed", range(4))
    def test_bottleneck_equals_scalar_argmax(self, hw, seed):
        f, bm, bn = _random_terms(200, seed)
        res = sweep_mod.sweep(f, bm, bn, hw)
        labels = res.labels()
        for i in range(len(f)):
            a = analyze(WorkUnit("w", f[i], bm[i], bn[i]), hw)
            assert labels[i] == a.bottleneck.value, (f[i], bm[i], bn[i])
            if math.isfinite(a.runtime):
                assert res.runtime[i] == pytest.approx(a.runtime)
            assert res.peak_fraction[i] == pytest.approx(
                a.peak_fraction, abs=1e-12)

    def test_zero_work_unit(self):
        res = sweep_mod.sweep(0.0, 0.0, 0.0, CLX)
        assert res.labels() == "compute"          # degenerate tie-break
        assert res.runtime == 0.0

    def test_resources_enum_view(self):
        res = sweep_mod.sweep([1e12, 1.0], [1.0, 1e12], [0.0, 0.0], CLX)
        assert list(res.resources()) == [Resource.COMPUTE, Resource.MEMORY]


class TestGridAndCrossings:
    def test_grid_broadcast_shapes(self):
        g = sweep_mod.grid(batch=[1, 2, 4], dp=[1, 2])
        assert g["batch"].shape == g["dp"].shape == (3, 2)
        assert g["dp"][0, 1] == 2

    def test_2d_sweep_shape(self):
        g = sweep_mod.grid(batch=[64, 512, 4096], dp=[1, 4, 16, 64])
        res = sweep_mod.sweep(6e6 * g["batch"] / g["dp"], 1e9,
                              1e8 * (1 - 1 / g["dp"]), CLX)
        assert res.shape == (3, 4)
        assert set(res.region_counts()) <= {"compute", "memory", "network"}

    def test_crossover_linear_exact(self):
        # constant vs linear: crossing at exactly x = 25
        xs = np.array([10.0, 20.0, 40.0, 80.0])
        assert sweep_mod.crossover(xs, np.full(4, 50.0), 2.0 * xs) == \
            pytest.approx(25.0)

    def test_crossover_none_when_no_crossing(self):
        xs = np.array([1.0, 2.0, 3.0])
        assert sweep_mod.crossover(xs, xs + 10.0, xs) is None

    def test_fig4c_crossover_is_4_3_kstar(self):
        """The paper-exact analytic crossover through the sweep engine."""
        from benchmarks.paper_case_study import BATCHES, batch_sweep
        res = batch_sweep(per_layer=False)
        b_star = sweep_mod.ridge_crossing(res, BATCHES, log_x=False)
        assert b_star == pytest.approx(4.0 / 3.0 * CLX.ridge_network)

    def test_fig6_transition_bracket(self):
        from benchmarks.paper_case_study import batch_sweep
        batches = (256, 512, 1024, 2048)
        trans = sweep_mod.transitions(batch_sweep(batches), batches)
        assert ("network", "compute") in [(f, t) for _, f, t in trans]

    def test_transitions_rejects_2d(self):
        g = sweep_mod.grid(a=[1, 2], b=[1, 2])
        res = sweep_mod.sweep(g["a"], g["b"], 1.0, CLX)
        with pytest.raises(ValueError, match="1-D"):
            sweep_mod.transitions(res)


class TestPlanner:
    @staticmethod
    def _cfg():
        from repro.configs import get_config
        return get_config("dlrm-mlp")

    def test_feasible_meshes_divisibility(self):
        from repro.launch.plan import feasible_meshes
        meshes = feasible_meshes(self._cfg(), 12, batch=8)
        assert all(dp * tp == 12 for dp, tp in meshes)
        assert all(8 % dp == 0 and 4096 % tp == 0 for dp, tp in meshes)
        assert (12, 1) not in meshes            # 8 % 12 != 0

    def test_ranked_by_runtime(self):
        from repro.launch.plan import plan
        plans = plan(self._cfg(), TPU_V5E, 16, batch=512,
                     algorithms=("ring", "bidir_ring", "tree"))
        times = [p.runtime for p in plans]
        assert times == sorted(times)
        assert all(p.runtime == pytest.approx(
            max(p.t_compute, p.t_memory, p.t_network)) for p in plans)

    def test_step_time_monotone_in_chips_for_dp_friendly_shape(self):
        """More chips never hurt a large-batch (DP-friendly) MLP."""
        from repro.launch.plan import best_step_time
        cfg = self._cfg()
        best = [best_step_time(cfg, CLX, chips, batch=4096)
                for chips in (1, 2, 4, 8, 16, 32, 64)]
        for a, b in zip(best, best[1:]):
            assert b <= a * (1 + 1e-9), best

    def test_cli_prints_ranked_table(self, capsys):
        from repro.launch.plan import main
        assert main(["--arch", "dlrm-mlp", "--chips", "16"]) == 0
        out = capsys.readouterr().out
        assert "rank" in out and "dp16xtp1" in out and "best:" in out
        assert "bottleneck" in out and "| arch |" in out   # report emitted
