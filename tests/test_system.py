"""End-to-end behaviour: real training runs on CPU with loss decrease,
fault-tolerant restart, straggler detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, make_stream
from repro.optim.optimizer import AdamW
from repro.train.fault_tolerance import (ResilientRunner, RunnerConfig,
                                         SimulatedFailure, StragglerEvent)
from repro.train.loop import TrainStepConfig, build_train_step, init_train_state

KEY = jax.random.PRNGKey(0)


def _setup(arch="smollm-135m", lr=3e-3, B=4, S=32):
    cfg = get_reduced(arch).replace(compute_dtype=jnp.float32)
    opt = AdamW(learning_rate=lr)
    step = jax.jit(build_train_step(cfg, opt, TrainStepConfig()))
    stream = make_stream(cfg, DataConfig(seed=11, global_batch=B, seq_len=S))
    state = init_train_state(KEY, cfg, opt)
    return cfg, opt, step, stream, state


@pytest.mark.slow
class TestLearning:
    def test_lm_loss_decreases(self):
        cfg, opt, step, stream, state = _setup(lr=1e-2, B=8, S=64)
        losses = []
        for s in range(60):
            state, m = step(state, jax.tree.map(jnp.asarray, stream.batch(s)))
            losses.append(float(m["ce"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.2, (first, last)

    def test_dlrm_loss_decreases(self):
        cfg, opt, step, stream, state = _setup("dlrm-mlp", lr=1e-3, B=64)
        losses = []
        for s in range(60):
            state, m = step(state, jax.tree.map(jnp.asarray, stream.batch(s)))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02

    def test_microbatched_step_matches_tokens(self):
        """n_micro=2 grad accumulation: same data -> similar loss trajectory."""
        cfg = get_reduced("smollm-135m").replace(compute_dtype=jnp.float32)
        opt = AdamW(learning_rate=1e-3)
        step1 = jax.jit(build_train_step(cfg, opt, TrainStepConfig(n_micro=1)))
        step2 = jax.jit(build_train_step(cfg, opt, TrainStepConfig(n_micro=2)))
        stream = make_stream(cfg, DataConfig(seed=1, global_batch=4, seq_len=16))
        batch = jax.tree.map(jnp.asarray, stream.batch(0))
        s1 = init_train_state(KEY, cfg, opt)
        s2 = init_train_state(KEY, cfg, opt)
        s1, m1 = step1(s1, batch)
        s2, m2 = step2(s2, batch)
        # losses agree (same tokens, mean-of-means for equal micro sizes)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3


@pytest.mark.slow
class TestFaultTolerance:
    def test_failure_mid_run_resumes_and_finishes(self, tmp_path):
        cfg, opt, step, stream, state = _setup(B=2, S=16)
        fail_at = {"armed": True}

        def failure_hook(s):
            if s == 7 and fail_at["armed"]:
                fail_at["armed"] = False
                raise SimulatedFailure("node lost")

        runner = ResilientRunner(
            step, Checkpointer(str(tmp_path), keep=5),
            RunnerConfig(ckpt_every=5, async_ckpt=False),
            failure_hook=failure_hook)
        final, hist = runner.run(state, stream, n_steps=12)
        assert int(final.step) == 12
        steps_run = [h["step"] for h in hist]
        # the failed attempt at 7 never reaches history; steps 5 and 6 are
        # REPLAYED after restoring the step-5 checkpoint
        assert steps_run.count(5) == 2 and steps_run.count(6) == 2
        assert steps_run.count(7) == 1
        assert steps_run[-1] == 11

    def test_resume_equals_straight_run(self, tmp_path):
        cfg, opt, step, stream, _ = _setup(B=2, S=16)
        straight = init_train_state(KEY, cfg, opt)
        for s in range(10):
            straight, _ = step(straight, jax.tree.map(
                jnp.asarray, stream.batch(s)))

        def fail_once(s, armed={"x": True}):
            if s == 6 and armed["x"]:
                armed["x"] = False
                raise SimulatedFailure()

        runner = ResilientRunner(
            step, Checkpointer(str(tmp_path)),
            RunnerConfig(ckpt_every=2, async_ckpt=False),
            failure_hook=fail_once)
        resumed, _ = runner.run(init_train_state(KEY, cfg, opt), stream,
                                n_steps=10)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), straight.params, resumed.params)

    def test_too_many_failures_raise(self, tmp_path):
        cfg, opt, step, stream, state = _setup(B=2, S=16)

        def always_fail(s):
            if s == 3:
                raise SimulatedFailure()

        runner = ResilientRunner(
            step, Checkpointer(str(tmp_path)),
            RunnerConfig(ckpt_every=100, async_ckpt=False, max_retries=2),
            failure_hook=always_fail)
        with pytest.raises(SimulatedFailure):
            runner.run(state, stream, n_steps=5)

    def test_straggler_detection(self, tmp_path):
        import time
        cfg, opt, step, stream, state = _setup(B=2, S=16)
        events = []

        def slow_hook(s):
            if s == 8:
                time.sleep(1.0)

        runner = ResilientRunner(
            step, Checkpointer(str(tmp_path)),
            RunnerConfig(ckpt_every=100, async_ckpt=False,
                         straggler_factor=5.0),
            on_straggler=events.append, failure_hook=slow_hook)
        runner.run(state, stream, n_steps=10)
        assert any(e.step == 8 for e in events), runner.stragglers
